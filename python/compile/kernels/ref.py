"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package is pytest-compared against the function of the same name here
(see python/tests/test_kernels.py, driven by hypothesis sweeps).

Semantics follow the paper's WAQ LUT-GEMM (Fig. 6):
  out[m, n] = a_scale[m] * w_scale[n] * sum_k LUT[a_idx[m,k] * 2^nW + w_idx[k,n]]
where LUT is the Cartesian-product table of activation x weight centroids
(but may be an arbitrary 2^(nA+nW)-entry table; the kernels must not assume
rank-1 structure except where explicitly documented).
"""

from __future__ import annotations

import jax.numpy as jnp


def waq_gemm(a_idx, w_idx, lut, a_scale, w_scale, n_w_bits: int):
    """Reference WAQ LUT-GEMM.

    a_idx:   (M, K) integer activation indices in [0, 2^nA)
    w_idx:   (K, N) integer weight indices in [0, 2^nW)
    lut:     (2^(nA+nW),) float Cartesian-product LUT, laid out
             lut[ia * 2^nW + iw]
    a_scale: (M,) per-token activation scales
    w_scale: (N,) per-output-channel weight scales
    """
    cat = a_idx[:, :, None] * (1 << n_w_bits) + w_idx[None, :, :]  # (M, K, N)
    vals = jnp.take(lut, cat.reshape(-1)).reshape(cat.shape)
    acc = vals.sum(axis=1)  # reduce over K
    return acc * a_scale[:, None] * w_scale[None, :]


def waq_gemm_histogram(a_idx, w_idx, lut, a_scale, w_scale, n_w_bits: int,
                       n_a_bits: int):
    """Same result computed the hardware way: Index-Counter histogram of the
    concatenated indices, then a weighted sum over LUT entries (MAC tree)."""
    n_entries = 1 << (n_a_bits + n_w_bits)
    cat = a_idx[:, :, None] * (1 << n_w_bits) + w_idx[None, :, :]  # (M, K, N)
    onehot = jnp.equal(cat[..., None], jnp.arange(n_entries)).astype(lut.dtype)
    counts = onehot.sum(axis=1)  # (M, N, 2^(nA+nW))
    acc = counts @ lut
    return acc * a_scale[:, None] * w_scale[None, :]


def cluster(x, centroids):
    """Reference Clustering Unit: nearest centroid by L2 (eq. 1 in the paper).

    x: any shape of floats; centroids: (C,) sorted ascending.
    Equivalent to boundary-based assignment with cells [b_{i-1}, b_i) where
    b_i = (c_i + c_{i+1}) / 2; argmin ties go to the lower index.
    """
    d = jnp.abs(x[..., None] - centroids)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def cluster_boundaries(centroids):
    """Midpoint decision boundaries b_i = (c_i + c_{i+1}) / 2 (paper SIV-C)."""
    return 0.5 * (centroids[:-1] + centroids[1:])


def dequant(idx, centroids, scale=None):
    """Codebook dequantization (the accelerator's Dequantization Unit)."""
    out = jnp.take(centroids, idx)
    if scale is not None:
        out = out * scale
    return out
