"""L1 Pallas kernel: the Clustering Unit (paper §IV-C).

Maps each activation to its nearest centroid. The ASIC uses a binary search
tree over the 2^n - 1 midpoint boundaries; the TPU re-expression does all
boundary comparisons per lane in parallel on the VPU:

    idx(x) = sum_i [x >= b_i],   b_i = (c_i + c_{i+1}) / 2

which is exactly nearest-centroid assignment for a sorted codebook (ties at
a boundary go to the upper cell, matching half-open [b_{i-1}, b_i) cells and
ref.cluster's argmin-lowest-index tie rule for exact midpoints... see
python/tests/test_kernels.py::test_cluster_matches_ref for the tolerance
discussion; boundaries are floats so exact ties are measure-zero and the
hypothesis sweep filters them).

Lowered with interpret=True (see waq_gemm.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cluster_kernel(x_ref, b_ref, idx_ref):
    x = x_ref[...]
    b = b_ref[...]
    # Parallel boundary compare: index = number of boundaries strictly below x.
    idx = (x[..., None] > b).sum(axis=-1)
    idx_ref[...] = idx.astype(jnp.int32)


def cluster(x, boundaries, *, block: int = 1024, interpret: bool = True):
    """Assign each element of x (flat or 2-D) to a centroid cell.

    boundaries: (C - 1,) sorted midpoint boundaries for C sorted centroids.
    Returns int32 indices with x's shape.
    """
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = min(block, n)
    if n % block != 0:  # pad to a whole number of blocks
        pad = block - n % block
        flat = jnp.pad(flat, (0, pad))
        n = flat.shape[0]

    out = pl.pallas_call(
        _cluster_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((boundaries.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(flat, boundaries)
    size = 1
    for d in orig_shape:
        size *= d
    return out[:size].reshape(orig_shape)


def cluster_jnp(x, boundaries):
    """Plain-jnp version used inside L2 model lowering (same math)."""
    return (x[..., None] > boundaries).sum(axis=-1).astype(jnp.int32)
