"""L1 Pallas kernels: WAQ LUT-GEMM (the paper's compute hot-spot).

Two kernels implement the paper's index-domain GEMM; both take the
Cartesian-product LUT (and, for the fused variant, the per-operand
codebooks) as VMEM-resident inputs so quantized operands never round-trip
through an FP dequantization buffer in HBM:

* ``waq_gemm_histogram`` — the bit-exact hardware-semantics kernel. It
  performs the Concat-Unit / Index-Counter / MAC-tree pipeline literally:
  concatenated indices -> one-hot decode -> per-(m, n) histogram ->
  ``counts @ lut`` weighted sum. The one-hot contraction is exactly the
  shape of computation the MXU systolic array executes at full utilization
  (a (K x 2^(nA+nW)) matmul), which is the TPU re-expression of the paper's
  4096 parallel Concat Units (DESIGN.md §1.4).

* ``waq_gemm_fused`` — the rank-1 fast path. Because the Cartesian LUT is
  the outer product of the two codebooks, the weighted sum collapses to a
  gather-from-VMEM-codebook followed by one MXU matmul. This is the
  production kernel: indices stream HBM->VMEM as int8 tiles (BlockSpec),
  centroids are gathered *inside* VMEM, and the MXU consumes the gathered
  tiles directly — the TPU analog of "no dequantization through HBM".

Both are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against ``ref.py`` in
python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Histogram (bit-exact LUT semantics) kernel
# ---------------------------------------------------------------------------

def _histogram_kernel(a_idx_ref, w_idx_ref, lut_ref, a_scale_ref, w_scale_ref,
                      out_ref, *, n_w_bits: int, n_entries: int):
    """One grid step computes a full (M, N_blk) output tile for a K block.

    Grid is (num_n_blocks, num_k_blocks); K is innermost so the output tile
    accumulates across K blocks (out_ref is indexed only by the N block).
    """
    k_step = pl.program_id(1)

    a_idx = a_idx_ref[...].astype(jnp.int32)      # (M, K_blk)
    w_idx = w_idx_ref[...].astype(jnp.int32)      # (K_blk, N_blk)
    lut = lut_ref[...]                            # (n_entries,)

    cat = a_idx[:, :, None] * (1 << n_w_bits) + w_idx[None, :, :]
    # One-hot decode (the Index Counter's decoder), then the bit-counter
    # row-sums: counts[m, n, e] = #{k : cat[m, k, n] == e}.
    onehot = jnp.equal(cat[..., None], jnp.arange(n_entries)).astype(lut.dtype)
    counts = onehot.sum(axis=1)                   # (M, N_blk, n_entries)
    partial = counts @ lut                        # MAC-tree weighted sum

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial * a_scale_ref[...][:, None] * w_scale_ref[...][None, :]


def waq_gemm_histogram(a_idx, w_idx, lut, a_scale, w_scale, *,
                       n_w_bits: int, n_a_bits: int,
                       block_n: int = 128, block_k: int = 128,
                       interpret: bool = True):
    """Bit-exact WAQ LUT-GEMM. Shapes: see ref.waq_gemm."""
    m, k = a_idx.shape
    k2, n = w_idx.shape
    assert k == k2, (k, k2)
    n_entries = 1 << (n_a_bits + n_w_bits)
    assert lut.shape == (n_entries,), (lut.shape, n_entries)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert n % block_n == 0 and k % block_k == 0, (n, block_n, k, block_k)

    kernel = functools.partial(
        _histogram_kernel, n_w_bits=n_w_bits, n_entries=n_entries)
    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda nb, kb: (0, kb)),
            pl.BlockSpec((block_k, block_n), lambda nb, kb: (kb, nb)),
            pl.BlockSpec((n_entries,), lambda nb, kb: (0,)),
            pl.BlockSpec((m,), lambda nb, kb: (0,)),
            pl.BlockSpec((block_n,), lambda nb, kb: (nb,)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda nb, kb: (0, nb)),
        out_shape=jax.ShapeDtypeStruct((m, n), lut.dtype),
        interpret=interpret,
    )(a_idx, w_idx, lut, a_scale, w_scale)


# ---------------------------------------------------------------------------
# Fused rank-1 (production) kernel
# ---------------------------------------------------------------------------

def _fused_kernel(a_idx_ref, w_idx_ref, cb_a_ref, cb_w_ref,
                  a_scale_ref, w_scale_ref, out_ref):
    """Gather centroids from VMEM-resident codebooks, one MXU matmul."""
    k_step = pl.program_id(1)

    a_val = jnp.take(cb_a_ref[...], a_idx_ref[...].astype(jnp.int32))
    w_val = jnp.take(cb_w_ref[...], w_idx_ref[...].astype(jnp.int32))
    partial = a_val @ w_val                       # (M, N_blk) on the MXU

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial * a_scale_ref[...][:, None] * w_scale_ref[...][None, :]


def waq_gemm_fused(a_idx, w_idx, cb_a, cb_w, a_scale, w_scale, *,
                   block_n: int = 256, block_k: int = 256,
                   interpret: bool = True):
    """Rank-1 WAQ GEMM: exploits lut = outer(cb_a, cb_w).

    Mathematically identical to waq_gemm_histogram with
    lut[ia * len(cb_w) + iw] = cb_a[ia] * cb_w[iw]; accumulation order
    differs (MXU dot vs histogram weighted sum), tolerance 1e-5 relative.
    """
    m, k = a_idx.shape
    k2, n = w_idx.shape
    assert k == k2, (k, k2)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert n % block_n == 0 and k % block_k == 0, (n, block_n, k, block_k)

    grid = (n // block_n, k // block_k)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_k), lambda nb, kb: (0, kb)),
            pl.BlockSpec((block_k, block_n), lambda nb, kb: (kb, nb)),
            pl.BlockSpec((cb_a.shape[0],), lambda nb, kb: (0,)),
            pl.BlockSpec((cb_w.shape[0],), lambda nb, kb: (0,)),
            pl.BlockSpec((m,), lambda nb, kb: (0,)),
            pl.BlockSpec((block_n,), lambda nb, kb: (nb,)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda nb, kb: (0, nb)),
        out_shape=jax.ShapeDtypeStruct((m, n), cb_a.dtype),
        interpret=interpret,
    )(a_idx, w_idx, cb_a, cb_w, a_scale, w_scale)
