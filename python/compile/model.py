"""L2: the JAX transformer used for all accuracy experiments and serving.

A GPT-style decoder (RMSNorm, causal MHA, GELU MLP, tied embedding head)
defined functionally over a *flat, deterministically ordered* parameter
list so the Rust runtime can construct inputs positionally from the
artifact manifest.

The quantization-method variants (Table III/IV baselines and the paper's
K-Means WAQ) are expressed as activation-quantization hooks applied at the
input of every linear GEMM; weight-side quantization is performed by the
Rust quant library (fake-quant: weights arrive already
quantize-dequantized), so one lowered artifact per (method, nA, outlier
fraction) covers the whole table. Python never runs at inference time —
every entry point here is AOT-lowered to HLO text by aot.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from .kernels.clustering import cluster_jnp

# Linear tap order within a layer (used by collect_acts and the quant hooks).
LINEARS_PER_LAYER = 4  # qkv, attn_out, mlp_up, mlp_down


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int = 2          # training/eval batch baked into artifacts
    decode_batch: int = 4   # serving decode slots

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def n_linears(self) -> int:
        return LINEARS_PER_LAYER * self.n_layers


PRESETS = {
    # Unit-test scale: traces + artifacts in seconds.
    "test": ModelConfig(vocab=256, d_model=64, n_layers=2, n_heads=4,
                        seq_len=32, batch=2, decode_batch=2),
    # Default end-to-end scale for this 1-core-CPU testbed (~21 M params).
    "gpt20m": ModelConfig(vocab=4096, d_model=512, n_layers=6, n_heads=8,
                          seq_len=128, batch=2, decode_batch=4),
    # Paper-scale driver (~109 M params); runnable but slow on 1 core.
    # d_model = 1024 (power of 2) so the QuaRot Hadamard applies uniformly.
    "gpt100m": ModelConfig(vocab=8192, d_model=1024, n_layers=8, n_heads=16,
                           seq_len=256, batch=2, decode_batch=4),
}


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[tuple]:
    """Deterministic (name, shape) list — the L3 runtime mirrors this order."""
    specs = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        specs += [
            (f"l{l}.ln1", (cfg.d_model,)),
            (f"l{l}.qkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{l}.attn_out", (cfg.d_model, cfg.d_model)),
            (f"l{l}.ln2", (cfg.d_model,)),
            (f"l{l}.mlp_up", (cfg.d_model, cfg.d_ff)),
            (f"l{l}.mlp_down", (cfg.d_ff, cfg.d_model)),
        ]
    specs.append(("lnf", (cfg.d_model,)))
    return specs


def init_params(cfg: ModelConfig, key) -> List[jnp.ndarray]:
    """Scaled-normal init (python-side tests only; Rust has its own init)."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".ln1", ".ln2")) or name == "lnf":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            std = 0.02 if "emb" in name else 1.0 / math.sqrt(shape[0])
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def _unpack(cfg: ModelConfig, params: Sequence[jnp.ndarray]) -> dict:
    names = [n for n, _ in param_specs(cfg)]
    assert len(params) == len(names), (len(params), len(names))
    return dict(zip(names, params))


# ---------------------------------------------------------------------------
# Activation-quantization hooks
# ---------------------------------------------------------------------------
# A hook is q(x, li) -> x_dequantized, where li in [0, 4 * n_layers) indexes
# the linear whose *input* x is (qkv, attn_out, mlp_up, mlp_down per layer).
# All hooks are fake-quant: they return float tensors carrying the
# quantization error so downstream math measures accuracy impact.

def q_identity(x, li):
    return x


def make_q_rtn(n_bits: int):
    """Per-token symmetric round-to-nearest integer quantization."""
    qmax = float(2 ** (n_bits - 1) - 1)

    def q(x, li):
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
        scale = jnp.maximum(scale, 1e-8)
        return jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale

    return q


def make_q_smooth(n_bits: int, smooth_vecs: Sequence[jnp.ndarray]):
    """SmoothQuant: divide activations by the per-channel smoothing vector
    (the matching multiply is folded into the weights by the Rust side),
    then per-token RTN."""
    rtn = make_q_rtn(n_bits)

    def q(x, li):
        return rtn(x / smooth_vecs[li], li)

    return q


def hadamard(x):
    """Fast Walsh-Hadamard transform over the last axis (power-of-2 dim),
    orthonormal (scaled by 1/sqrt(d))."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"hadamard dim {d} not a power of 2"
    orig = x.shape
    h = 1
    x = x.reshape(-1, d)
    while h < d:
        x = x.reshape(-1, d // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return (x.reshape(orig)) / jnp.sqrt(jnp.asarray(d, x.dtype))


def make_q_quarot(n_bits: int):
    """QuaRot: rotate activations by the Hadamard matrix (weights arrive
    pre-rotated by the Rust side), then per-token RTN. The rotation spreads
    outlier energy across channels."""
    rtn = make_q_rtn(n_bits)

    def q(x, li):
        return rtn(hadamard(x), li)

    return q


def make_q_atom(n_bits: int, perms: Sequence[jnp.ndarray]):
    """Atom: channel-reordered group-wise quantization; the trailing
    outlier-channel block (picked by calibration, applied via the per-linear
    permutation) is kept in INT8 while inlier groups use n_bits. Weights
    arrive row-permuted to match.

    Group size and outlier-block size are both d/32, the paper's ratio
    (group 128 and 128 outlier channels at d = 4096)."""
    rtn_in = make_q_rtn(n_bits)
    rtn_out = make_q_rtn(8)

    def q(x, li):
        perm = perms[li]
        d = x.shape[-1]
        g = max(1, d // 32)   # group size, scaled from the paper's 128@4096
        n_out = g             # outlier-channel block, 128@4096 scaled
        xp = jnp.take(x, perm, axis=-1)
        inl, outl = xp[..., : d - n_out], xp[..., d - n_out:]
        # group-wise RTN on inliers ((d - n_out) = 31 g divides evenly)
        lead = inl.shape[:-1]
        gi = inl.reshape(*lead, -1, g)
        gi = rtn_in(gi, li).reshape(*lead, d - n_out)
        go = rtn_out(outl, li)
        xq = jnp.concatenate([gi, go], axis=-1)
        inv = jnp.argsort(perm)
        return jnp.take(xq, inv, axis=-1)

    return q


def quantize_kmeans_token(x, codebook, outlier_mask):
    """K-Means per-token fake quant with FP-preserved outliers.

    x: (..., d); codebook: (2^nA,) sorted, normalized to [-1, 1];
    outlier_mask: (..., d) bool, True where the value stays FP.
    Per-token scale is the max-|inlier| (the paper's token-wise scaling).
    """
    inlier = jnp.where(outlier_mask, 0.0, x)
    scale = jnp.max(jnp.abs(inlier), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8)
    bounds = 0.5 * (codebook[:-1] + codebook[1:])
    idx = cluster_jnp(x / scale, bounds)
    deq = jnp.take(codebook, idx) * scale
    return jnp.where(outlier_mask, x, deq)


def topk_outlier_mask(x, k_per_side: int):
    """Dynamic outlier mask: top-k largest and bottom-k smallest per token
    (the job Orizuru does in hardware).

    Implemented via sort + threshold rather than jax.lax.top_k: the TopK
    HLO emitted by top_k uses a `largest` attribute that xla_extension
    0.5.1's HLO-text parser rejects, while `sort` round-trips. Exact ties
    at the threshold admit a few extra outliers (fake-quant only; the
    hardware path uses Orizuru's deterministic tie-breaking)."""
    d = x.shape[-1]
    sorted_x = jnp.sort(x, axis=-1)
    hi_thr = sorted_x[..., d - k_per_side][..., None]
    lo_thr = sorted_x[..., k_per_side - 1][..., None]
    return (x >= hi_thr) | (x <= lo_thr)


def make_q_kmeans(codebooks: Sequence[jnp.ndarray], outlier_frac: float):
    """The paper's scheme (OASIS/KLLM): offline-learned per-linear codebooks,
    dynamic top-k outlier preservation. outlier_frac is the TOTAL fraction
    (split half top / half bottom, matching 'top 0.5% + bottom 0.5%')."""

    def q(x, li):
        d = x.shape[-1]
        k = max(1, int(round(0.5 * outlier_frac * d)))
        mask = topk_outlier_mask(x, k)
        return quantize_kmeans_token(x, codebooks[li], mask)

    return q


def make_q_kmeans_static(codebooks: Sequence[jnp.ndarray],
                         thresholds: Sequence[jnp.ndarray]):
    """OASIS-S: outliers picked by *static* per-linear (lo, hi) thresholds
    learned offline instead of online top-k."""

    def q(x, li):
        lo, hi = thresholds[li][0], thresholds[li][1]
        mask = (x > hi) | (x < lo)
        return quantize_kmeans_token(x, codebooks[li], mask)

    return q


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def rms_norm(x, g, eps=1e-5):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _attention(q, k, v, mask):
    # q, k, v: (B, H, T, hd); mask: broadcastable to (B, H, Tq, Tk)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(q.shape[-1])
    scores = jnp.where(mask, scores, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(scores, axis=-1), v)


def forward(cfg: ModelConfig, params: Sequence[jnp.ndarray], tokens,
            act_q: Callable = q_identity, taps: Optional[dict] = None):
    """Full-sequence forward. tokens: (B, T) int32 -> logits (B, T, vocab).

    act_q is applied to the input of every linear GEMM. If `taps` is given,
    pre-GEMM activations are recorded into it (used by collect_acts).
    """
    p = _unpack(cfg, params)
    b, t = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x = jnp.take(p["tok_emb"], tokens, axis=0) + p["pos_emb"][None, :t]
    causal = jnp.tril(jnp.ones((t, t), bool))[None, None]

    def tap(name, val):
        if taps is not None:
            taps[name] = val
        return val

    for l in range(cfg.n_layers):
        li = LINEARS_PER_LAYER * l
        xn = rms_norm(x, p[f"l{l}.ln1"])
        xn = act_q(tap(f"l{l}.qkv_in", xn), li + 0)
        qkv = xn @ p[f"l{l}.qkv"]
        q_, k_, v_ = jnp.split(qkv, 3, axis=-1)
        q_ = q_.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
        k_ = k_.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
        v_ = v_.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
        att = _attention(q_, k_, v_, causal)
        att = att.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        att = act_q(tap(f"l{l}.attn_out_in", att), li + 1)
        x = x + att @ p[f"l{l}.attn_out"]

        xn = rms_norm(x, p[f"l{l}.ln2"])
        xn = act_q(tap(f"l{l}.mlp_up_in", xn), li + 2)
        hmid = jax.nn.gelu(xn @ p[f"l{l}.mlp_up"])
        hmid = act_q(tap(f"l{l}.mlp_down_in", hmid), li + 3)
        x = x + hmid @ p[f"l{l}.mlp_down"]

    x = rms_norm(x, p["lnf"])
    return x @ p["tok_emb"].T  # tied head (kept FP: paper quantizes GEMM layers)


def nll_loss(cfg: ModelConfig, params, tokens, targets, act_q=q_identity):
    """Mean next-token NLL. targets: (B, T) int32 (-1 entries are ignored)."""
    logits = forward(cfg, params, tokens, act_q)
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = targets >= 0
    tgt = jnp.where(valid, targets, 0)
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -(picked * valid).sum() / jnp.maximum(valid.sum(), 1)


# ---------------------------------------------------------------------------
# Training (AdamW)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS, WEIGHT_DECAY = 0.9, 0.95, 1e-8, 0.01


def train_step(cfg: ModelConfig, params, m, v, step, lr, tokens, targets):
    """One AdamW step. All states are flat lists matching param_specs order.

    step: scalar f32 (1-based) for bias correction; lr: scalar f32.
    Returns (params', m', v', loss).
    """
    loss, grads = jax.value_and_grad(
        lambda ps: nll_loss(cfg, ps, tokens, targets))(list(params))
    b1t = jnp.power(ADAM_B1, step)
    b2t = jnp.power(ADAM_B2, step)
    new_p, new_m, new_v = [], [], []
    for (name, _), pi, mi, vi, gi in zip(param_specs(cfg), params, m, v, grads):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * gi
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * gi * gi
        mhat = mi / (1 - b1t)
        vhat = vi / (1 - b2t)
        upd = mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        decay = 0.0 if (name.endswith(("ln1", "ln2")) or name == "lnf") else WEIGHT_DECAY
        pi = pi - lr * (upd + decay * pi)
        new_p.append(pi)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss


# ---------------------------------------------------------------------------
# KV-cache decode (the serving hot path)
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, k_cache, v_cache, tokens, pos):
    """Single-token decode over B slots.

    k_cache, v_cache: (L, B, H, S, hd); tokens: (B,) int32; pos: (B,) int32
    (the cache position this token is written to; slots past a request's
    length are garbage — the coordinator masks them out).
    Returns (logits (B, vocab), k_cache', v_cache').
    """
    p = _unpack(cfg, params)
    bsz = tokens.shape[0]
    h, hd, s = cfg.n_heads, cfg.head_dim, cfg.seq_len
    binds = jnp.arange(bsz)
    x = jnp.take(p["tok_emb"], tokens, axis=0) + jnp.take(p["pos_emb"], pos, axis=0)

    for l in range(cfg.n_layers):
        xn = rms_norm(x, p[f"l{l}.ln1"])
        qkv = xn @ p[f"l{l}.qkv"]
        q_, k_, v_ = jnp.split(qkv, 3, axis=-1)
        q_ = q_.reshape(bsz, h, hd)
        k_ = k_.reshape(bsz, h, hd)
        v_ = v_.reshape(bsz, h, hd)
        k_cache = k_cache.at[l, binds, :, pos, :].set(k_)
        v_cache = v_cache.at[l, binds, :, pos, :].set(v_)
        mask = (jnp.arange(s)[None, :] <= pos[:, None])[:, None, :]  # (B,1,S)
        scores = jnp.einsum("bhd,bhsd->bhs", q_, k_cache[l]) / math.sqrt(hd)
        scores = jnp.where(mask, scores, -1e30)
        att = jnp.einsum("bhs,bhsd->bhd", jax.nn.softmax(scores, axis=-1),
                         v_cache[l]).reshape(bsz, cfg.d_model)
        x = x + att @ p[f"l{l}.attn_out"]
        xn = rms_norm(x, p[f"l{l}.ln2"])
        x = x + jax.nn.gelu(xn @ p[f"l{l}.mlp_up"]) @ p[f"l{l}.mlp_down"]

    x = rms_norm(x, p["lnf"])
    return x @ p["tok_emb"].T, k_cache, v_cache


def prefill(cfg: ModelConfig, params, tokens, length):
    """Single-request prefill: tokens (1, S) padded, length scalar int32.

    Returns (logits_at_last (vocab,), k_cache, v_cache) with caches shaped
    (L, 1, H, S, hd) and positions >= length left as zeros/garbage.
    """
    p = _unpack(cfg, params)
    _, t = tokens.shape
    h, hd = cfg.n_heads, cfg.head_dim
    x = jnp.take(p["tok_emb"], tokens, axis=0) + p["pos_emb"][None, :t]
    valid = jnp.arange(t)[None, :] < length  # (1, T)
    causal = jnp.tril(jnp.ones((t, t), bool))[None, None]
    mask = causal & valid[:, None, None, :]
    ks, vs = [], []
    for l in range(cfg.n_layers):
        xn = rms_norm(x, p[f"l{l}.ln1"])
        qkv = xn @ p[f"l{l}.qkv"]
        q_, k_, v_ = jnp.split(qkv, 3, axis=-1)
        q_ = q_.reshape(1, t, h, hd).transpose(0, 2, 1, 3)
        k_ = k_.reshape(1, t, h, hd).transpose(0, 2, 1, 3)
        v_ = v_.reshape(1, t, h, hd).transpose(0, 2, 1, 3)
        ks.append(k_)
        vs.append(v_)
        att = _attention(q_, k_, v_, mask)
        att = att.transpose(0, 2, 1, 3).reshape(1, t, cfg.d_model)
        x = x + att @ p[f"l{l}.attn_out"]
        xn = rms_norm(x, p[f"l{l}.ln2"])
        x = x + jax.nn.gelu(xn @ p[f"l{l}.mlp_up"]) @ p[f"l{l}.mlp_down"]
    x = rms_norm(x, p["lnf"])
    logits = x @ p["tok_emb"].T  # (1, T, V)
    last = jnp.take_along_axis(
        logits, jnp.maximum(length - 1, 0)[None, None, None], axis=1)[0, 0]
    k_cache = jnp.stack(ks)  # (L, 1, H, S, hd)
    v_cache = jnp.stack(vs)
    return last, k_cache, v_cache


# ---------------------------------------------------------------------------
# Calibration: activations + their loss-gradients (Fisher weights)
# ---------------------------------------------------------------------------

def collect_acts(cfg: ModelConfig, params, tokens, targets):
    """Returns pre-GEMM activations and dL/d(activation) at every linear.

    Outputs:
      acts_d:  (3L, B, T, d)   inputs of qkv / attn_out / mlp_up
      acts_ff: (L,  B, T, 4d)  inputs of mlp_down
      grads_d, grads_ff: same shapes — squared by the Rust side to form the
      diagonal-Fisher weights for weighted K-Means centroid learning.
    """
    b, t = tokens.shape
    zd = jnp.zeros((3 * cfg.n_layers, b, t, cfg.d_model))
    zf = jnp.zeros((cfg.n_layers, b, t, cfg.d_ff))

    def loss_with_z(zd, zf):
        taps = {}

        def act_q(x, li):
            l, kind = divmod(li, LINEARS_PER_LAYER)
            if kind == 3:
                return x + zf[l]
            return x + zd[3 * l + kind]

        loss = nll_loss(cfg, params, tokens, targets, act_q=act_q)
        return loss, taps

    # Gradients w.r.t. the zero perturbations == dL/d(activation).
    (_, taps), (gd, gf) = jax.value_and_grad(loss_with_z, argnums=(0, 1),
                                             has_aux=True)(zd, zf)
    # Re-run forward with tap recording for the activations themselves.
    taps = {}
    forward(cfg, params, tokens, act_q=q_identity, taps=taps)
    acts_d = jnp.stack(
        [taps[f"l{l}.{nm}_in"] for l in range(cfg.n_layers)
         for nm in ("qkv", "attn_out", "mlp_up")])
    acts_ff = jnp.stack([taps[f"l{l}.mlp_down_in"] for l in range(cfg.n_layers)])
    return acts_d, acts_ff, gd, gf


# ---------------------------------------------------------------------------
# Quantized-eval entry points (one per Table III/IV method)
# ---------------------------------------------------------------------------

def loss_eval_quant(cfg: ModelConfig, method: str, n_bits: int,
                    outlier_frac: float, params, extras, tokens, targets):
    """Dispatch the fake-quant NLL for a method.

    `extras` is the method's flat list of extra inputs (see aot.py manifest):
      rtn:          []
      smooth:       [sm_d (3L, d), sm_ff (L, 4d)]
      quarot:       []
      atom:         [perm_d (3L, d) i32, perm_ff (L, 4d) i32]
      kmeans:       [cb (4L, 2^nA)]
      kmeans_static:[cb (4L, 2^nA), thr (4L, 2)]
    """
    nl = cfg.n_layers

    def per_linear_d(arr_d, arr_ff, li):
        l, kind = divmod(li, LINEARS_PER_LAYER)
        return arr_ff[l] if kind == 3 else arr_d[3 * l + kind]

    if method == "rtn":
        q = make_q_rtn(n_bits)
    elif method == "smooth":
        sm_d, sm_ff = extras
        vecs = [per_linear_d(sm_d, sm_ff, li) for li in range(cfg.n_linears)]
        q = make_q_smooth(n_bits, vecs)
    elif method == "quarot":
        q = make_q_quarot(n_bits)
    elif method == "atom":
        pd, pf = extras
        perms = [per_linear_d(pd, pf, li) for li in range(cfg.n_linears)]
        q = make_q_atom(n_bits, perms)
    elif method == "kmeans":
        (cb,) = extras
        q = make_q_kmeans([cb[li] for li in range(cfg.n_linears)], outlier_frac)
    elif method == "kmeans_static":
        cb, thr = extras
        q = make_q_kmeans_static([cb[li] for li in range(cfg.n_linears)],
                                 [thr[li] for li in range(cfg.n_linears)])
    else:
        raise ValueError(f"unknown method {method}")
    del nl
    return nll_loss(cfg, params, tokens, targets, act_q=q)


# gpt100m uses d_model = 1024 so the QuaRot Hadamard (power-of-2) applies
# uniformly; see aot.py for the preset table actually lowered.
