"""AOT lowering: JAX -> HLO *text* artifacts + manifest.json.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --preset test --out-dir ../artifacts
    python -m compile.aot --preset gpt20m --out-dir ../artifacts

Every artifact is listed in ``artifacts/<preset>/manifest.json`` with its
positional input/output shapes + dtypes so the Rust runtime can marshal
Literals without any Python at runtime.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import waq_gemm as KW
from .kernels import clustering as KC

F32, I32 = "f32", "i32"

# (method, extra-input builder) for the Table III/IV quantized-eval family.
QUANT_METHODS = ("rtn", "smooth", "quarot", "atom", "kmeans", "kmeans_static")
# Outlier-fraction sweep for Fig 15 (total fraction; default is 1%).
KMEANS_FRACS = (0.005, 0.01, 0.02, 0.05, 0.10)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32, name=""):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def sds(s):
    return jax.ShapeDtypeStruct(tuple(s["shape"]),
                                jnp.float32 if s["dtype"] == F32 else jnp.int32)


class Emitter:
    def __init__(self, out_dir: str, cfg: M.ModelConfig, preset: str):
        self.out_dir = out_dir
        self.cfg = cfg
        self.preset = preset
        self.manifest = {
            "preset": preset,
            "config": {
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
                "seq_len": cfg.seq_len, "batch": cfg.batch,
                "decode_batch": cfg.decode_batch, "head_dim": cfg.head_dim,
                "d_ff": cfg.d_ff, "n_linears": cfg.n_linears,
            },
            "params": [{"name": n, "shape": list(s)}
                       for n, s in M.param_specs(cfg)],
            "artifacts": {},
        }

    def emit(self, name, fn, inputs, meta=None):
        """Lower fn(*inputs-shaped-args) and write <name>.hlo.txt."""
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[sds(s) for s in inputs])
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_tree = jax.eval_shape(fn, *[sds(s) for s in inputs])
        flat, _ = jax.tree_util.tree_flatten(out_tree)
        outputs = [spec(o.shape, F32 if o.dtype == jnp.float32 else I32)
                   for o in flat]
        self.manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
            "meta": meta or {},
        }
        print(f"  {name:32s} {len(text) / 1e6:7.2f} MB  "
              f"{time.time() - t0:6.1f}s  ({len(inputs)} in / {len(outputs)} out)")

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"  manifest.json ({len(self.manifest['artifacts'])} artifacts)")


def param_inputs(cfg):
    return [spec(s, F32, n) for n, s in M.param_specs(cfg)]


def quant_extra_inputs(cfg, method, n_bits):
    L, d, dff = cfg.n_layers, cfg.d_model, cfg.d_ff
    if method == "smooth":
        return [spec((3 * L, d), F32, "smooth_d"),
                spec((L, dff), F32, "smooth_ff")]
    if method == "atom":
        return [spec((3 * L, d), I32, "perm_d"),
                spec((L, dff), I32, "perm_ff")]
    if method == "kmeans":
        return [spec((cfg.n_linears, 2 ** n_bits), F32, "codebooks")]
    if method == "kmeans_static":
        return [spec((cfg.n_linears, 2 ** n_bits), F32, "codebooks"),
                spec((cfg.n_linears, 2), F32, "thresholds")]
    return []


def emit_all(em: Emitter, fast: bool):
    cfg = em.cfg
    P = param_inputs(cfg)
    toks = spec((cfg.batch, cfg.seq_len), I32, "tokens")
    tgts = spec((cfg.batch, cfg.seq_len), I32, "targets")

    # --- plain forward / loss ------------------------------------------------
    em.emit("fwd", lambda *a: M.forward(cfg, a[:-1], a[-1]), P + [toks])
    em.emit("loss_eval",
            lambda *a: M.nll_loss(cfg, a[:-2], a[-2], a[-1]),
            P + [toks, tgts])

    # --- training step -------------------------------------------------------
    n = len(P)

    def _train(*a):
        params, m, v = a[:n], a[n:2 * n], a[2 * n:3 * n]
        step, lr, tokens, targets = a[3 * n], a[3 * n + 1], a[3 * n + 2], a[3 * n + 3]
        return M.train_step(cfg, params, m, v, step, lr, tokens, targets)

    m_in = [spec(s["shape"], F32, "m." + s["name"]) for s in P]
    v_in = [spec(s["shape"], F32, "v." + s["name"]) for s in P]
    em.emit("train_step", _train,
            P + m_in + v_in + [spec((), F32, "step"), spec((), F32, "lr"),
                               toks, tgts])

    # --- serving path --------------------------------------------------------
    kv_shape = (cfg.n_layers, cfg.decode_batch, cfg.n_heads, cfg.seq_len,
                cfg.head_dim)

    def _decode(*a):
        params = a[:n]
        kc, vc, tok, pos = a[n], a[n + 1], a[n + 2], a[n + 3]
        return M.decode_step(cfg, params, kc, vc, tok, pos)

    em.emit("decode_step", _decode,
            P + [spec(kv_shape, F32, "k_cache"), spec(kv_shape, F32, "v_cache"),
                 spec((cfg.decode_batch,), I32, "tokens"),
                 spec((cfg.decode_batch,), I32, "pos")])

    def _prefill(*a):
        return M.prefill(cfg, a[:n], a[n], a[n + 1])

    em.emit("prefill", _prefill,
            P + [spec((1, cfg.seq_len), I32, "tokens"),
                 spec((), I32, "length")])

    # --- calibration ---------------------------------------------------------
    def _collect(*a):
        return M.collect_acts(cfg, a[:-2], a[-2], a[-1])

    em.emit("collect_acts", _collect, P + [toks, tgts])

    # --- quantized eval family (Table III/IV, Fig 15/17) ---------------------
    bit_list = (4, 3)
    for method in QUANT_METHODS:
        for n_bits in bit_list:
            extras = quant_extra_inputs(cfg, method, n_bits)
            ne = len(extras)

            def _eval(*a, _m=method, _b=n_bits, _ne=ne):
                params = a[:n]
                ex = a[n:n + _ne]
                return M.loss_eval_quant(cfg, _m, _b, 0.01, params, ex,
                                         a[n + _ne], a[n + _ne + 1])

            em.emit(f"eval_{method}_a{n_bits}", _eval, P + extras + [toks, tgts],
                    meta={"method": method, "n_bits": n_bits,
                          "outlier_frac": 0.01})
        if fast:
            break

    # Fig 15: outlier-fraction sweep for the paper's method at A4.
    if not fast:
        for frac in KMEANS_FRACS:
            if frac == 0.01:
                continue  # already emitted as eval_kmeans_a4
            extras = quant_extra_inputs(cfg, "kmeans", 4)

            def _evalf(*a, _f=frac):
                return M.loss_eval_quant(cfg, "kmeans", 4, _f, a[:n],
                                         a[n:n + 1], a[n + 1], a[n + 2])

            tag = str(frac).replace("0.", "").rstrip("0") or "0"
            em.emit(f"eval_kmeans_a4_f{tag}", _evalf, P + extras + [toks, tgts],
                    meta={"method": "kmeans", "n_bits": 4,
                          "outlier_frac": frac})

    # --- standalone L1 kernels ----------------------------------------------
    mM, kK, nN, nb = 8, 256, 256, 4
    a_idx = spec((mM, kK), I32, "a_idx")
    w_idx = spec((kK, nN), I32, "w_idx")
    a_sc = spec((mM,), F32, "a_scale")
    w_sc = spec((nN,), F32, "w_scale")
    em.emit("waq_gemm",
            lambda ai, wi, ca, cw, sa, sw: KW.waq_gemm_fused(
                ai, wi, ca, cw, sa, sw),
            [a_idx, w_idx, spec((2 ** nb,), F32, "cb_a"),
             spec((2 ** nb,), F32, "cb_w"), a_sc, w_sc],
            meta={"M": mM, "K": kK, "N": nN, "n_a_bits": nb, "n_w_bits": nb,
                  "kind": "fused"})
    em.emit("waq_gemm_hist",
            lambda ai, wi, lut, sa, sw: KW.waq_gemm_histogram(
                ai, wi, lut, sa, sw, n_w_bits=nb, n_a_bits=nb),
            [a_idx, w_idx, spec((2 ** (2 * nb),), F32, "lut"), a_sc, w_sc],
            meta={"M": mM, "K": kK, "N": nN, "n_a_bits": nb, "n_w_bits": nb,
                  "kind": "histogram"})
    em.emit("quantize_act",
            lambda x, b: KC.cluster(x, b),
            [spec((128, 256), F32, "x"), spec((15,), F32, "boundaries")],
            meta={"n_bits": 4})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="test", choices=sorted(M.PRESETS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="emit only the first quant method (CI smoke)")
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    out_dir = os.path.join(args.out_dir, args.preset)
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] preset={args.preset} -> {out_dir}")
    t0 = time.time()
    em = Emitter(out_dir, cfg, args.preset)
    emit_all(em, fast=args.fast)
    em.write_manifest()
    print(f"[aot] done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
