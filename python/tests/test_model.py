"""L2 model tests: shapes, causality, training signal, quant hooks, decode
cache consistency. Uses the 'test' preset so everything runs in seconds."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["test"]
KEY = jax.random.PRNGKey(0)
PARAMS = M.init_params(CFG, KEY)


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq_len))
    return jnp.asarray(toks, jnp.int32)


def test_param_specs_deterministic():
    a = M.param_specs(CFG)
    b = M.param_specs(CFG)
    assert a == b
    assert a[0][0] == "tok_emb" and a[-1][0] == "lnf"
    assert len(a) == 2 + 6 * CFG.n_layers + 1


def test_forward_shape():
    logits = M.forward(CFG, PARAMS, _batch())
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_forward_is_causal():
    """Changing a future token must not affect earlier logits."""
    toks = _batch(1)
    l1 = M.forward(CFG, PARAMS, toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    l2 = M.forward(CFG, PARAMS, toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_nll_loss_near_uniform_at_init():
    toks = _batch(2)
    loss = M.nll_loss(CFG, PARAMS, toks, toks)
    assert 0.5 * math.log(CFG.vocab) < float(loss) < 2.0 * math.log(CFG.vocab)


def test_nll_ignores_masked_targets():
    toks = _batch(3)
    tgts = toks.at[:, : CFG.seq_len // 2].set(-1)
    loss = M.nll_loss(CFG, PARAMS, toks, tgts)
    assert bool(jnp.isfinite(loss))


def test_train_step_reduces_loss():
    toks = _batch(4)
    tgts = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    params = list(PARAMS)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    losses = []
    for step in range(8):
        params, m, v, loss = M.train_step(
            CFG, params, m, v, jnp.float32(step + 1), jnp.float32(3e-3),
            toks, tgts)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_decode_matches_forward():
    """Token-by-token decode over the KV cache must reproduce full forward."""
    toks = _batch(5)[:CFG.decode_batch]
    bsz = toks.shape[0]
    full = M.forward(CFG, PARAMS, toks)
    kv_shape = (CFG.n_layers, bsz, CFG.n_heads, CFG.seq_len, CFG.head_dim)
    kc = jnp.zeros(kv_shape)
    vc = jnp.zeros(kv_shape)
    for t in range(CFG.seq_len):
        logits, kc, vc = M.decode_step(
            CFG, PARAMS, kc, vc, toks[:, t],
            jnp.full((bsz,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_prefill_matches_forward():
    toks = _batch(6)[:1]
    length = CFG.seq_len - 3
    padded = toks.at[:, length:].set(0)
    last, kc, vc = M.prefill(CFG, PARAMS, padded, jnp.int32(length))
    full = M.forward(CFG, PARAMS, toks[:, :length])
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[0, -1]),
                               rtol=2e-4, atol=2e-4)
    assert kc.shape == (CFG.n_layers, 1, CFG.n_heads, CFG.seq_len, CFG.head_dim)


def test_prefill_then_decode_continues():
    """Serving invariant: prefill cache + decode_step = full forward."""
    toks = _batch(7)[:1]
    length = CFG.seq_len - 4
    padded = toks.at[:, length:].set(0)
    _, kc, vc = M.prefill(CFG, PARAMS, padded, jnp.int32(length))
    bsz = CFG.decode_batch
    kv_shape = (CFG.n_layers, bsz, CFG.n_heads, CFG.seq_len, CFG.head_dim)
    kcb = jnp.zeros(kv_shape).at[:, 0].set(kc[:, 0])
    vcb = jnp.zeros(kv_shape).at[:, 0].set(vc[:, 0])
    nxt = toks[0, length]
    logits, _, _ = M.decode_step(
        CFG, PARAMS, kcb, vcb,
        jnp.full((bsz,), nxt, jnp.int32),
        jnp.full((bsz,), length, jnp.int32))
    full = M.forward(CFG, PARAMS, toks[:, : length + 1])
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(full[0, -1]),
                               rtol=2e-4, atol=2e-4)


def test_hadamard_is_orthonormal():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
    hx = M.hadamard(x)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(hx), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(M.hadamard(hx)), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


def test_topk_outlier_mask_counts():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    mask = M.topk_outlier_mask(x, 3)
    assert mask.shape == x.shape
    counts = np.asarray(mask).sum(axis=-1)
    assert (counts == 6).all()  # 3 largest + 3 smallest, distinct w.p. 1


def test_kmeans_quant_outliers_pass_through():
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
    cb = jnp.asarray(np.sort(rng.uniform(-1, 1, size=16)), jnp.float32)
    mask = M.topk_outlier_mask(x, 2)
    xq = M.quantize_kmeans_token(x, cb, mask)
    np.testing.assert_array_equal(np.asarray(xq)[np.asarray(mask)],
                                  np.asarray(x)[np.asarray(mask)])
    # inliers are on the codebook grid (up to per-token scale)
    inl = ~np.asarray(mask)
    scale = np.abs(np.where(np.asarray(mask), 0, np.asarray(x))).max(
        axis=-1, keepdims=True)
    normed = np.asarray(xq) / scale
    dist = np.abs(normed[inl][:, None] - np.asarray(cb)[None, :]).min(axis=1)
    assert dist.max() < 1e-5


@pytest.mark.parametrize("method", M.PRESETS and
                         ["rtn", "smooth", "quarot", "atom", "kmeans",
                          "kmeans_static"])
def test_quant_eval_runs_and_degrades_gracefully(method):
    toks = _batch(11)
    tgts = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    L, d, dff, nl = CFG.n_layers, CFG.d_model, CFG.d_ff, CFG.n_linears
    extras = {
        "rtn": [],
        "smooth": [jnp.ones((3 * L, d)), jnp.ones((L, dff))],
        "quarot": [],
        "atom": [jnp.tile(jnp.arange(d, dtype=jnp.int32), (3 * L, 1)),
                 jnp.tile(jnp.arange(dff, dtype=jnp.int32), (L, 1))],
        "kmeans": [jnp.tile(jnp.linspace(-1, 1, 16), (nl, 1))],
        "kmeans_static": [jnp.tile(jnp.linspace(-1, 1, 16), (nl, 1)),
                          jnp.tile(jnp.asarray([-3.0, 3.0]), (nl, 1))],
    }[method]
    fp = float(M.nll_loss(CFG, PARAMS, toks, tgts))
    q = float(M.loss_eval_quant(CFG, method, 4, 0.01, PARAMS, extras,
                                toks, tgts))
    assert math.isfinite(q)
    # 4-bit fake-quant on an untrained tiny model should not explode
    assert q < fp + 5.0


def test_collect_acts_shapes_and_grad_signal():
    toks = _batch(12)
    tgts = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
    ad, af, gd, gf = M.collect_acts(CFG, PARAMS, toks, tgts)
    L, B, T, d, dff = (CFG.n_layers, CFG.batch, CFG.seq_len, CFG.d_model,
                       CFG.d_ff)
    assert ad.shape == (3 * L, B, T, d)
    assert af.shape == (L, B, T, dff)
    assert gd.shape == ad.shape and gf.shape == af.shape
    assert float(jnp.abs(gd).sum()) > 0 and float(jnp.abs(gf).sum()) > 0
