"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

hypothesis sweeps shapes, bit-widths, block sizes, and value distributions;
every property asserts allclose against the reference. These tests are the
core correctness signal for the artifacts the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import clustering as KC
from compile.kernels import ref as R
from compile.kernels import waq_gemm as KW

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def _rand_case(seed, m, k, n, n_a_bits, n_w_bits):
    rng = np.random.default_rng(seed)
    a_idx = rng.integers(0, 2 ** n_a_bits, size=(m, k)).astype(np.int32)
    w_idx = rng.integers(0, 2 ** n_w_bits, size=(k, n)).astype(np.int32)
    cb_a = np.sort(rng.normal(size=2 ** n_a_bits)).astype(np.float32)
    cb_w = np.sort(rng.normal(size=2 ** n_w_bits)).astype(np.float32)
    lut = np.outer(cb_a, cb_w).reshape(-1).astype(np.float32)
    a_scale = (0.5 + rng.random(m)).astype(np.float32)
    w_scale = (0.5 + rng.random(n)).astype(np.float32)
    return a_idx, w_idx, cb_a, cb_w, lut, a_scale, w_scale


# ---------------------------------------------------------------------------
# WAQ LUT-GEMM kernels
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 31 - 1),
       m=st.sampled_from([1, 2, 4, 8]),
       k=st.sampled_from([16, 32, 64, 128]),
       n=st.sampled_from([16, 32, 64]),
       bits=st.sampled_from([(4, 4), (3, 4), (4, 3), (2, 2), (1, 1)]))
def test_histogram_kernel_matches_ref(seed, m, k, n, bits):
    n_a, n_w = bits
    a_idx, w_idx, _, _, lut, a_sc, w_sc = _rand_case(seed, m, k, n, n_a, n_w)
    got = KW.waq_gemm_histogram(a_idx, w_idx, lut, a_sc, w_sc,
                                n_w_bits=n_w, n_a_bits=n_a,
                                block_n=min(32, n), block_k=min(32, k))
    want = R.waq_gemm(a_idx, w_idx, lut, a_sc, w_sc, n_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 31 - 1),
       m=st.sampled_from([1, 4, 8]),
       k=st.sampled_from([32, 64, 256]),
       n=st.sampled_from([32, 128]),
       bits=st.sampled_from([(4, 4), (3, 3)]))
def test_fused_kernel_matches_ref(seed, m, k, n, bits):
    n_a, n_w = bits
    a_idx, w_idx, cb_a, cb_w, lut, a_sc, w_sc = _rand_case(
        seed, m, k, n, n_a, n_w)
    got = KW.waq_gemm_fused(a_idx, w_idx, cb_a, cb_w, a_sc, w_sc,
                            block_n=min(64, n), block_k=min(64, k))
    want = R.waq_gemm(a_idx, w_idx, lut, a_sc, w_sc, n_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_histogram_matches_fused_on_cartesian_lut():
    """The two kernels agree whenever the LUT is the codebook outer product."""
    a_idx, w_idx, cb_a, cb_w, lut, a_sc, w_sc = _rand_case(7, 4, 128, 64, 4, 4)
    hist = KW.waq_gemm_histogram(a_idx, w_idx, lut, a_sc, w_sc,
                                 n_w_bits=4, n_a_bits=4)
    fused = KW.waq_gemm_fused(a_idx, w_idx, cb_a, cb_w, a_sc, w_sc)
    np.testing.assert_allclose(np.asarray(hist), np.asarray(fused),
                               rtol=1e-4, atol=1e-4)


def test_histogram_supports_non_rank1_lut():
    """The histogram kernel must not assume the LUT factors (the fused one
    may): perturb one entry and check the result moves by count * delta."""
    a_idx, w_idx, _, _, lut, a_sc, w_sc = _rand_case(3, 1, 64, 16, 4, 4)
    base = np.asarray(R.waq_gemm(a_idx, w_idx, lut, a_sc, w_sc, 4))
    lut2 = lut.copy()
    lut2[37] += 1.0
    got = np.asarray(KW.waq_gemm_histogram(a_idx, w_idx, lut2, a_sc, w_sc,
                                           n_w_bits=4, n_a_bits=4))
    cat = a_idx[:, :, None] * 16 + w_idx[None, :, :]
    counts = (cat == 37).sum(axis=1)  # (1, N)
    want = base + counts * a_sc[:, None] * w_sc[None, :]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_reduction_is_lut_weighted_sum():
    """Table I property: the reduction does 2^(nA+nW) FLOP-pairs per output,
    independent of K — verified by checking the histogram sums to K."""
    m, k, n = 2, 96, 8
    a_idx, w_idx, _, _, lut, a_sc, w_sc = _rand_case(11, m, k, n, 4, 4)
    cat = a_idx[:, :, None] * 16 + w_idx[None, :, :]
    onehot = cat[..., None] == np.arange(256)
    counts = onehot.sum(axis=1)
    assert (counts.sum(axis=-1) == k).all()


# ---------------------------------------------------------------------------
# Clustering Unit kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 31 - 1),
       n_bits=st.sampled_from([2, 3, 4]),
       size=st.sampled_from([17, 64, 100, 1024, 2048]))
def test_cluster_matches_ref(seed, n_bits, size):
    rng = np.random.default_rng(seed)
    centroids = np.sort(rng.normal(size=2 ** n_bits)).astype(np.float32)
    # keep x away from exact boundary midpoints (measure-zero tie cells)
    x = rng.normal(size=size).astype(np.float32)
    bounds = np.asarray(R.cluster_boundaries(jnp.asarray(centroids)))
    near = np.abs(x[:, None] - bounds[None, :]).min(axis=1) < 1e-6
    x = np.where(near, x + 1e-3, x).astype(np.float32)

    got = KC.cluster(jnp.asarray(x), jnp.asarray(bounds))
    want = R.cluster(jnp.asarray(x), jnp.asarray(centroids))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cluster_2d_shape_preserved():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(13, 7)),
                    dtype=jnp.float32)
    c = jnp.sort(jnp.asarray(np.linspace(-2, 2, 16), dtype=jnp.float32))
    got = KC.cluster(x, R.cluster_boundaries(c))
    assert got.shape == (13, 7)
    want = R.cluster(x, c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cluster_assigns_centroids_to_themselves():
    c = jnp.asarray(np.sort(np.random.default_rng(5).normal(size=16)),
                    dtype=jnp.float32)
    got = KC.cluster(c, R.cluster_boundaries(c))
    np.testing.assert_array_equal(np.asarray(got), np.arange(16))


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------

def test_ref_histogram_equals_ref_direct():
    a_idx, w_idx, _, _, lut, a_sc, w_sc = _rand_case(23, 3, 48, 24, 3, 4)
    d = R.waq_gemm(a_idx, w_idx, lut, a_sc, w_sc, 4)
    h = R.waq_gemm_histogram(a_idx, w_idx, lut, a_sc, w_sc, 4, 3)
    np.testing.assert_allclose(np.asarray(d), np.asarray(h),
                               rtol=1e-5, atol=1e-5)


def test_ref_waq_gemm_equals_dequant_matmul():
    """With a Cartesian LUT the whole scheme is exactly dequant-then-matmul
    (the paper's mathematical-equivalence claim in §III-B)."""
    a_idx, w_idx, cb_a, cb_w, lut, a_sc, w_sc = _rand_case(29, 4, 64, 32, 4, 4)
    lut_out = R.waq_gemm(a_idx, w_idx, lut, a_sc, w_sc, 4)
    a_deq = cb_a[a_idx] * a_sc[:, None]
    w_deq = cb_w[w_idx] * w_sc[None, :]
    np.testing.assert_allclose(np.asarray(lut_out), a_deq @ w_deq,
                               rtol=1e-4, atol=1e-4)
