//! Quickstart: the paper's WAQ LUT-GEMM end to end on one GEMM.
//!
//!   cargo run --release --example quickstart
//!
//! 1. K-Means-quantize a weight matrix (4-bit, per-output-channel scales)
//! 2. learn an activation codebook from calibration tokens
//! 3. build the Cartesian-product LUT (256 entries — Table I)
//! 4. run the dual-branch GEMM (look-ahead + error compensation)
//! 5. compare against the FP32 reference and print the modeled
//!    accelerator cycles/energy for the same GEMM.

use kllm::gemm::{self, CartesianLut};
use kllm::quant::{self, OutlierCfg};
use kllm::sim::{self, HwConfig};
use kllm::tensor::Matrix;
use kllm::util::rng::Rng;

fn main() {
    let (k, n) = (1024usize, 1024usize);
    let mut rng = Rng::new(7);

    // --- weights: 4-bit K-Means, per-output-channel scales ---------------
    let w = Matrix::random_normal(k, n, 0.04, &mut rng);
    let qw = quant::quantize_weights(&w, 4);
    println!(
        "weights {k}x{n}: 4-bit K-Means, rel err {:.4}, {} KB ({}x smaller)",
        qw.dequantize().rel_err(&w),
        qw.storage_bytes() / 1024,
        k * n * 4 / qw.storage_bytes()
    );

    // --- activations: offline codebook + dynamic outliers ----------------
    let calib: Vec<Vec<f32>> = (0..16)
        .map(|_| rng.heavy_tailed_vec(k, 0.01, 12.0))
        .collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let cfg = OutlierCfg { total_frac: 0.01 };
    let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
    let x = rng.heavy_tailed_vec(k, 0.01, 12.0);
    let tok = quant::quantize_token(&x, &cb_a, cfg);
    println!(
        "activation token: {} outliers preserved in FP ({}%)",
        tok.outliers.len(),
        100.0 * tok.outliers.len() as f64 / k as f64
    );

    // --- the Cartesian-product LUT (fits in 2 KB on-chip) ----------------
    let lut = CartesianLut::build(&cb_a, &qw.codebook);
    println!(
        "LUT: {} entries, {} bytes on-chip (WOQ inner-product LUT would need {} entries)",
        lut.entries(),
        lut.storage_bytes(),
        kllm::gemm::lut::analytics::woq_lut_entries(k, 4)
    );

    // --- dual-branch GEMM vs FP32 reference ------------------------------
    let exact = Matrix::from_vec(1, k, x.clone()).matmul(&w);
    let lookahead = gemm::execute_direct(&tok, &qw, &lut);
    let dual = gemm::execute_dual_branch(&tok, &qw, &lut);
    let err = |v: &[f32]| -> f64 {
        let num: f64 = v
            .iter()
            .zip(exact.row(0))
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        num / exact.frob_norm()
    };
    println!("look-ahead only        rel err {:.4}", err(&lookahead));
    println!("with error compensation rel err {:.4}  <- outlier branch pays off", err(&dual));

    // --- packed fast backend (nibble indices + fused pair-LUT) -----------
    let pw = qw.pack();
    let packed = gemm::execute_packed(&tok, &pw, &lut);
    assert_eq!(packed, lookahead, "packed backend is bit-exact with direct");
    println!(
        "packed backend: bit-exact with direct at {} KB of weight indices (vs {} KB unpacked)",
        pw.index_bytes() / 1024,
        qw.idx.len() / 1024
    );

    // --- modeled accelerator cost (Table II config) -----------------------
    let hw = HwConfig::default();
    let c = sim::gemm_cost(&hw, 1, k, n, 4, cfg.total_frac);
    let e = sim::energy::gemm_energy(&hw, &c, 4);
    println!(
        "modeled on OASIS: {} cycles look-ahead ({} critical-path), {:.2} uJ on-chip",
        c.total_lookahead(),
        c.total_critical_path(),
        e.total() * 1e6
    );
    println!("done — see `kllm experiment table1` and DESIGN.md §3 for the full reproduction");
}
