//! Serving demo: batched request workload against the coordinator, with a
//! policy comparison (decode-priority vs fill-all admission).
//!
//!   cargo run --release --example serve -- [--preset test] [--requests 16]
//!       [--max-new 12] [--tcp]

use std::sync::Arc;

use kllm::coordinator::{serve_tcp, AdmitPolicy, Coordinator, EngineConfig};
use kllm::runtime::{artifacts_dir, Manifest, ParamSet};
use kllm::util::cli::Args;
use kllm::util::rng::Rng;
use kllm::util::stats::LatencyStats;
use kllm::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "test");
    let n_requests = args.usize_or("requests", 16).map_err(anyhow::Error::msg)?;
    let max_new = args.usize_or("max-new", 12).map_err(anyhow::Error::msg)?;

    let manifest = Manifest::load(&artifacts_dir(&preset)).map_err(anyhow::Error::msg)?;
    let cfg = manifest.model;
    let params = ParamSet::init(&manifest, &mut Rng::new(42));

    let mut table = Table::new(
        &format!("serving policies ({n_requests} requests x {max_new} tokens, B={})", cfg.decode_batch),
        &["Policy", "tok/s", "p50 lat (ms)", "p99 lat (ms)", "mean occupancy", "decode steps"],
    );
    for (name, policy) in [
        ("decode-priority (1/step)", AdmitPolicy::OnePerStep),
        ("prefill-priority (fill)", AdmitPolicy::FillAll),
    ] {
        let coord = Coordinator::start(
            preset.clone(),
            ParamSet { tensors: params.tensors.clone() },
            EngineConfig { policy, ..Default::default() },
        )?;
        let mut rng = Rng::new(0xBEEF);
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..n_requests {
            let plen = 2 + rng.below(cfg.seq_len / 4);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
            rxs.push(coord.submit_async(prompt, max_new, 0.0)?.1);
        }
        let mut lat = LatencyStats::default();
        let mut tokens = 0usize;
        for rx in rxs {
            let r = rx.recv()?;
            tokens += r.tokens.len();
            lat.record_us(r.total_s * 1e6);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (stats, _) = coord.stats()?;
        let s = lat.summary();
        table.row(&[
            name.to_string(),
            format!("{:.1}", tokens as f64 / wall),
            format!("{:.1}", s.p50_us / 1e3),
            format!("{:.1}", s.p99_us / 1e3),
            format!("{:.2}", stats.mean_occupancy()),
            stats.decode_steps.to_string(),
        ]);
        coord.shutdown()?;
    }
    table.print();

    if args.flag("tcp") {
        let coord = Arc::new(Coordinator::start(
            preset,
            params,
            EngineConfig::default(),
        )?);
        let port = serve_tcp(coord, 0)?;
        println!("TCP front-end on 127.0.0.1:{port} — ctrl-c to stop");
        println!("try: echo '{{\"prompt\": [1,2,3], \"max_new_tokens\": 8}}' | nc 127.0.0.1 {port}");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    Ok(())
}
