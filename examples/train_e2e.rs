//! END-TO-END driver (DESIGN.md deliverable (b)/"end-to-end validation"):
//! proves all three layers compose on a real workload.
//!
//!   cargo run --release --example train_e2e -- [--preset test|gpt20m|gpt100m]
//!       [--steps N] [--eval-batches N] [--requests N] [--max-new N]
//!
//! 1. TRAIN the L2 transformer for a few hundred steps by driving the
//!    `train_step` HLO artifact from Rust (loss curve logged).
//! 2. CALIBRATE on a held-out corpus (collect_acts artifact) and quantize
//!    weights+activations with the paper's K-Means WAQ.
//! 3. EVALUATE perplexity FP32 vs KLLM-A4/A3 vs RTN through the quantized
//!    eval artifacts.
//! 4. SERVE batched decode requests through the coordinator, reporting
//!    measured latency/throughput and the modeled OASIS latency/energy.
//!
//! Default preset is `test` (seconds on this 1-core box); `gpt20m` is the
//! ~21M-parameter run and `gpt100m` the paper-scale ~109M configuration
//! (see DESIGN.md §1.3 on the 1-core scaling substitution).

use kllm::coordinator::{Coordinator, EngineConfig};
use kllm::eval::methods::Method;
use kllm::eval::ppl::{eval_method, eval_nll, ppl, train};
use kllm::eval::{calibrate, Corpus};
use kllm::quant::OutlierCfg;
use kllm::runtime::{artifacts_dir, Runtime};
use kllm::util::cli::Args;
use kllm::util::stats::LatencyStats;
use kllm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let preset = args.str_or("preset", "test");
    let steps = args.usize_or("steps", 300).map_err(anyhow::Error::msg)?;
    let eval_batches = args.usize_or("eval-batches", 8).map_err(anyhow::Error::msg)?;
    let n_requests = args.usize_or("requests", 8).map_err(anyhow::Error::msg)?;
    let max_new = args.usize_or("max-new", 16).map_err(anyhow::Error::msg)?;

    let dir = artifacts_dir(&preset);
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/{preset} missing — run `make artifacts` (and `make artifacts-{preset}` for non-test presets)"
    );
    let mut rt = Runtime::new(&dir)?;
    let m = rt.manifest.model;
    let n_params: usize = rt.manifest.param_elems();
    println!(
        "== train_e2e: preset {preset} ({} params, d={}, L={}, V={}, S={}) ==",
        n_params, m.d_model, m.n_layers, m.vocab, m.seq_len
    );

    // ---- 1. training ------------------------------------------------------
    println!("\n[1/4] training for {steps} steps on wiki2-syn (train_step artifact)");
    let t0 = std::time::Instant::now();
    let log_every = (steps / 20).max(1);
    let (params, losses) = train(&mut rt, Corpus::Wiki2, steps, 3e-3, 0x7121, &mut |s, l| {
        if s % log_every == 0 || s + 1 == steps {
            println!("  step {s:>5}  loss {l:.4}");
        }
    })?;
    let train_s = t0.elapsed().as_secs_f64();
    println!(
        "  loss {:.3} -> {:.3} in {:.1}s ({:.0} tok/s trained)",
        losses[0],
        losses[losses.len() - 1],
        train_s,
        (steps * m.batch * m.seq_len) as f64 / train_s
    );
    assert!(
        losses[losses.len() - 1] < losses[0] * 0.8,
        "training failed to reduce loss"
    );

    // ---- 2. calibration + quantization ------------------------------------
    println!("\n[2/4] calibrating on c4-syn + K-Means quantizing (W4)");
    let calib = calibrate(&mut rt, &params, Corpus::C4, 16, OutlierCfg::default())?;

    // ---- 3. quantized evaluation ------------------------------------------
    println!("\n[3/4] held-out PPL (wiki2-syn, {eval_batches} batches)");
    let fp_nll = eval_nll(&mut rt, None, &params, &[], Corpus::Wiki2, eval_batches, 0xE7A1)?;
    println!("  FP32 baseline   PPL {:.3}", ppl(fp_nll));
    for (method, bits) in [(Method::Rtn, 4u32), (Method::Kmeans, 4), (Method::Kmeans, 3)] {
        let (p, qs) = eval_method(&mut rt, &params, &calib, method, bits, Corpus::Wiki2, eval_batches)?;
        println!(
            "  {:16} W4A{bits}  PPL {:.3}  (dPPL {:+.3}, quantized in {:.1}s)",
            method.label(),
            p,
            p - ppl(fp_nll),
            qs
        );
    }

    // ---- 4. serving --------------------------------------------------------
    println!("\n[4/4] serving {n_requests} batched decode requests (coordinator)");
    let pset = kllm::runtime::ParamSet { tensors: params.tensors.clone() };
    drop(rt); // engine thread owns its own runtime
    let coord = Coordinator::start(preset.clone(), pset, EngineConfig::default())?;
    let mut rng = Rng::new(0x5E12);
    let mut rxs = Vec::new();
    let t0 = std::time::Instant::now();
    for _ in 0..n_requests {
        let plen = 4 + rng.below(m.seq_len / 4);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(m.vocab) as i32).collect();
        rxs.push(coord.submit_async(prompt, max_new, 0.8)?.1);
    }
    let mut lat = LatencyStats::default();
    let mut ttft = LatencyStats::default();
    let mut total_tokens = 0usize;
    for rx in rxs {
        let r = rx.recv()?;
        total_tokens += r.tokens.len();
        lat.record_us(r.total_s * 1e6);
        ttft.record_us(r.ttft_s * 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (stats, sim) = coord.stats()?;
    println!("  measured:  {:.1} tok/s, latency {}", total_tokens as f64 / wall, lat.summary());
    println!("  ttft:      {}", ttft.summary());
    println!(
        "  batching:  {} decode steps, mean occupancy {:.2}",
        stats.decode_steps,
        stats.mean_occupancy()
    );
    println!(
        "  modeled OASIS: {:.2} ms total, {:.2} mJ, {:.0} tok/s-equivalent",
        sim.seconds * 1e3,
        sim.energy_j * 1e3,
        total_tokens as f64 / sim.seconds
    );
    coord.shutdown()?;
    println!("\ntrain_e2e complete — record in EXPERIMENTS.md");
    Ok(())
}
