//! Orizuru demo: dynamic top-k outlier detection on transformer
//! activations, vs the sort / heap / SpAtten-6N baselines.
//!
//!   cargo run --release --example outlier_detect
//!
//! Uses real activations from the `collect_acts` artifact when
//! artifacts/test is built, otherwise synthetic heavy-tailed tokens.

use kllm::orizuru::{baseline, detect_outliers, Orizuru};
use kllm::quant::outlier::topk_outliers;
use kllm::util::bench::Bencher;
use kllm::util::rng::Rng;

fn activation_tokens() -> Vec<Vec<f32>> {
    // try the artifact path first (real model activations)
    let dir = kllm::runtime::artifacts_dir("test");
    if dir.join("manifest.json").exists() {
        if let Ok(mut rt) = kllm::runtime::Runtime::new(&dir) {
            let m = rt.manifest.model;
            let manifest = rt.manifest.clone();
            let params =
                kllm::runtime::ParamSet::init(&manifest, &mut Rng::new(3));
            let mut gen =
                kllm::eval::Generator::new(kllm::eval::Corpus::Wiki2, m.vocab, 9);
            let (t, y) = gen.batch(m.batch, m.seq_len);
            let mut inputs = params.tensors.clone();
            inputs.push(kllm::runtime::HostTensor::i32(t, &[m.batch, m.seq_len]));
            inputs.push(kllm::runtime::HostTensor::i32(y, &[m.batch, m.seq_len]));
            if let Ok(out) = rt.run("collect_acts", &inputs) {
                let acts = out[1].as_f32().unwrap(); // mlp_down inputs (ff dim)
                let dff = m.d_ff;
                println!("using real activations from collect_acts (d_ff={dff})");
                return acts.chunks(dff).take(32).map(|c| c.to_vec()).collect();
            }
        }
    }
    println!("artifacts/test not built; using synthetic heavy-tailed tokens");
    let mut rng = Rng::new(5);
    (0..32).map(|_| rng.heavy_tailed_vec(4096, 0.01, 15.0)).collect()
}

fn main() {
    let tokens = activation_tokens();
    let n = tokens[0].len();
    let k = (n / 100).max(1); // ~1% per side

    // correctness vs the sort oracle
    for tok in &tokens {
        assert_eq!(detect_outliers(tok, k), topk_outliers(tok, k));
    }
    println!("orizuru == sort-oracle on {} tokens (n={n}, k={k})", tokens.len());

    // comparison counts
    let mut o = Orizuru::new(&tokens[0]);
    o.top_k(k);
    let (_, _, heap_cmp) = baseline::HeapTopK::run(&tokens[0], k);
    let (_, _, sort_cmp) = baseline::sort_topk(&tokens[0], k);
    println!("comparisons:  orizuru {:>8}  (model {:.0})", o.comparisons(), Orizuru::paper_cost_model(n, k));
    println!("              spatten  {:>8}  (6N model)", baseline::spatten_cost_model(n) as u64);
    println!("              heap     {:>8}", heap_cmp);
    println!("              sort     {:>8}", sort_cmp);

    // wallclock
    let b = Bencher::default();
    b.run("orizuru top-k", || {
        let mut o = Orizuru::new(&tokens[0]);
        kllm::util::bench::black_box(o.top_k(k));
    });
    b.run("sort top-k", || {
        kllm::util::bench::black_box(baseline::sort_topk(&tokens[0], k));
    });
    b.run("heap top-k", || {
        kllm::util::bench::black_box(baseline::HeapTopK::run(&tokens[0], k));
    });
}
