//! LLM architecture descriptors (shape-accurate layer dimensions for every
//! model in the paper's evaluation). Weight *values* are not needed for the
//! hardware experiments — throughput/energy of a GEMM-dominated workload
//! depends on the shapes (DESIGN.md §1.3).

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LlmSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// gated MLP (SwiGLU: up + gate + down) vs classic (up + down)
    pub gated_mlp: bool,
}

impl LlmSpec {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Linear-layer GEMM shapes of one decoder layer as (K, N) pairs for
    /// y(1xN) = x(1xK) @ W(KxN) during decode.
    pub fn layer_gemms(&self) -> Vec<(usize, usize)> {
        let d = self.d_model;
        let kv = self.n_kv_heads * self.head_dim();
        let mut v = vec![
            (d, d),      // q proj
            (d, kv),     // k proj
            (d, kv),     // v proj
            (d, d),      // o proj
            (d, self.d_ff), // up
        ];
        if self.gated_mlp {
            v.push((d, self.d_ff)); // gate
        }
        v.push((self.d_ff, d)); // down
        v
    }

    /// Total linear-weight parameter count (embeddings excluded, matching
    /// what streams from HBM every decode step).
    pub fn linear_params(&self) -> usize {
        self.n_layers
            * self
                .layer_gemms()
                .iter()
                .map(|&(k, n)| k * n)
                .sum::<usize>()
    }

    /// KV-cache bytes per token at the given per-element byte size.
    pub fn kv_bytes_per_token(&self, bytes_per_elem: f64) -> f64 {
        (2 * self.n_layers * self.n_kv_heads * self.head_dim()) as f64 * bytes_per_elem
    }

    pub fn params_b(&self) -> f64 {
        (self.linear_params() + 2 * self.vocab * self.d_model) as f64 / 1e9
    }
}

/// All models in the paper's evaluation (Table III / Figs 11-13, 16).
pub const ZOO: &[LlmSpec] = &[
    LlmSpec { name: "OPT-6.7B", n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32, d_ff: 16384, vocab: 50272, gated_mlp: false },
    LlmSpec { name: "OPT-13B", n_layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40, d_ff: 20480, vocab: 50272, gated_mlp: false },
    LlmSpec { name: "OPT-30B", n_layers: 48, d_model: 7168, n_heads: 56, n_kv_heads: 56, d_ff: 28672, vocab: 50272, gated_mlp: false },
    LlmSpec { name: "LLaMA-7B", n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32, d_ff: 11008, vocab: 32000, gated_mlp: true },
    LlmSpec { name: "LLaMA-13B", n_layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40, d_ff: 13824, vocab: 32000, gated_mlp: true },
    LlmSpec { name: "LLaMA-30B", n_layers: 60, d_model: 6656, n_heads: 52, n_kv_heads: 52, d_ff: 17920, vocab: 32000, gated_mlp: true },
    LlmSpec { name: "LLaMA-2-7B", n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 32, d_ff: 11008, vocab: 32000, gated_mlp: true },
    LlmSpec { name: "LLaMA-2-13B", n_layers: 40, d_model: 5120, n_heads: 40, n_kv_heads: 40, d_ff: 13824, vocab: 32000, gated_mlp: true },
    LlmSpec { name: "LLaMA-2-70B", n_layers: 80, d_model: 8192, n_heads: 64, n_kv_heads: 8, d_ff: 28672, vocab: 32000, gated_mlp: true },
    LlmSpec { name: "LLaMA-3-8B", n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8, d_ff: 14336, vocab: 128256, gated_mlp: true },
    LlmSpec { name: "Mistral-7B", n_layers: 32, d_model: 4096, n_heads: 32, n_kv_heads: 8, d_ff: 14336, vocab: 32000, gated_mlp: true },
];

pub fn by_name(name: &str) -> Option<&'static LlmSpec> {
    ZOO.iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_land_near_nameplate() {
        for (name, lo, hi) in [
            ("LLaMA-7B", 6.2, 7.2),
            ("LLaMA-2-13B", 12.0, 13.5),
            ("LLaMA-2-70B", 63.0, 72.0),
            ("LLaMA-3-8B", 7.0, 8.6),
            ("Mistral-7B", 6.5, 7.8),
            ("OPT-6.7B", 6.0, 7.2),
        ] {
            let m = by_name(name).unwrap();
            let p = m.params_b();
            assert!(p > lo && p < hi, "{name}: {p}B");
        }
    }

    #[test]
    fn gqa_models_have_small_kv() {
        let l3 = by_name("LLaMA-3-8B").unwrap();
        let l2 = by_name("LLaMA-2-7B").unwrap();
        assert!(l3.kv_bytes_per_token(2.0) < l2.kv_bytes_per_token(2.0) / 2.0);
    }

    #[test]
    fn layer_gemm_shapes() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let g = m.layer_gemms();
        assert!(g.contains(&(4096, 11008)) && g.contains(&(11008, 4096)));
        assert_eq!(g.len(), 7); // q k v o up gate down
    }

    #[test]
    fn zoo_covers_the_paper_table() {
        assert_eq!(ZOO.len(), 11);
    }
}
