//! Fixed-size block allocator: a free list over a bounded pool of block
//! ids, with per-block reference counts so one physical block can be
//! aliased into several `(slot, layer)` block tables at once (prefix
//! sharing). Releasing is O(blocks) pointer pushes — the payload is
//! never copied or zeroed (reads are bounded by written counts, so stale
//! payloads are unobservable).
//!
//! # Refcount protocol
//!
//! * [`BlockAllocator::alloc`] mints a block at refcount 1.
//! * [`BlockAllocator::retain`] adds a holder (a second slot table or the
//!   prefix index aliasing the block).
//! * [`BlockAllocator::release`] drops a holder; the block returns to the
//!   free list only when the count reaches 0 (the `bool` return tells the
//!   caller whether the payload actually died, i.e. whether side tables
//!   such as outlier accounting must be cleared).
//!
//! Releasing a block that is not live panics — a refcount underflow would
//! silently alias one physical block into two logical owners.

/// Free-list allocator over block ids `0..capacity`.
///
/// Ids are minted lazily (`high_water` tracks how many ever existed), so
/// backing storage can grow on demand and the peak footprint reflects
/// actual usage rather than the worst case.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    /// released ids available for reuse (LIFO: hot blocks are reused first)
    free: Vec<u32>,
    /// next never-used id
    next: u32,
    capacity: u32,
    /// per-minted-id reference count; 0 = free (guards double-release)
    refs: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(capacity: usize) -> BlockAllocator {
        BlockAllocator {
            free: Vec::new(),
            next: 0,
            capacity: capacity as u32,
            refs: Vec::new(),
        }
    }

    /// Hand out a block id at refcount 1, reusing released ids before
    /// minting new ones. `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<u32> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                if self.next >= self.capacity {
                    return None;
                }
                let id = self.next;
                self.next += 1;
                self.refs.push(0);
                id
            }
        };
        debug_assert_eq!(self.refs[id as usize], 0, "allocated a live block {id}");
        self.refs[id as usize] = 1;
        Some(id)
    }

    /// Add a holder to a live block (aliasing it into another table or
    /// into the prefix index).
    pub fn retain(&mut self, id: u32) {
        assert!(
            self.refs.get(id as usize).copied().unwrap_or(0) > 0,
            "retain of non-live block {id}"
        );
        self.refs[id as usize] += 1;
    }

    /// Drop a holder. Returns `true` when this was the last reference and
    /// the block went back on the free list (payload side tables should be
    /// cleared by the caller). Releasing a non-live block is a caller bug
    /// and panics (it would alias one block into two tables).
    pub fn release(&mut self, id: u32) -> bool {
        assert!(
            self.refs.get(id as usize).copied().unwrap_or(0) > 0,
            "release of non-live block {id}"
        );
        self.refs[id as usize] -= 1;
        if self.refs[id as usize] == 0 {
            self.free.push(id);
            true
        } else {
            false
        }
    }

    /// Current reference count (0 = free).
    pub fn ref_count(&self, id: u32) -> u32 {
        self.refs.get(id as usize).copied().unwrap_or(0)
    }

    pub fn capacity(&self) -> usize {
        self.capacity as usize
    }

    /// Blocks currently assigned to at least one table.
    pub fn in_use(&self) -> usize {
        self.next as usize - self.free.len()
    }

    /// Blocks ever minted — the backing-storage high-water mark.
    pub fn high_water(&self) -> usize {
        self.next as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_reuse() {
        let mut a = BlockAllocator::new(2);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        assert_ne!(b0, b1);
        assert_eq!(a.alloc(), None, "pool exhausted");
        assert_eq!(a.in_use(), 2);
        assert!(a.release(b0), "last holder frees the block");
        assert_eq!(a.in_use(), 1);
        // released id is reused; high-water stays at 2
        assert_eq!(a.alloc(), Some(b0));
        assert_eq!(a.high_water(), 2);
    }

    #[test]
    fn retain_defers_the_free() {
        let mut a = BlockAllocator::new(1);
        let b = a.alloc().unwrap();
        a.retain(b);
        assert_eq!(a.ref_count(b), 2);
        assert!(!a.release(b), "one holder remains");
        assert_eq!(a.in_use(), 1, "still live while aliased");
        assert_eq!(a.alloc(), None, "aliased block is not reusable");
        assert!(a.release(b), "last holder frees");
        assert_eq!(a.ref_count(b), 0);
        assert_eq!(a.alloc(), Some(b));
    }

    #[test]
    #[should_panic(expected = "non-live")]
    fn double_release_panics() {
        let mut a = BlockAllocator::new(1);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }

    #[test]
    #[should_panic(expected = "non-live")]
    fn retain_of_free_block_panics() {
        let mut a = BlockAllocator::new(1);
        let b = a.alloc().unwrap();
        a.release(b);
        a.retain(b);
    }
}
