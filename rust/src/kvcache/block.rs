//! Fixed-size block allocator: a free list over a bounded pool of block
//! ids. Blocks are handed to `(slot, layer)` block tables by
//! [`super::PagedKvCache`]; releasing is O(blocks) pointer pushes — the
//! payload is never copied or zeroed (reads are bounded by written
//! counts, so stale payloads are unobservable).

/// Free-list allocator over block ids `0..capacity`.
///
/// Ids are minted lazily (`high_water` tracks how many ever existed), so
/// backing storage can grow on demand and the peak footprint reflects
/// actual usage rather than the worst case.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    /// released ids available for reuse (LIFO: hot blocks are reused first)
    free: Vec<u32>,
    /// next never-used id
    next: u32,
    capacity: u32,
    /// liveness bitmap over minted ids (guards double-release)
    live: Vec<bool>,
}

impl BlockAllocator {
    pub fn new(capacity: usize) -> BlockAllocator {
        BlockAllocator {
            free: Vec::new(),
            next: 0,
            capacity: capacity as u32,
            live: Vec::new(),
        }
    }

    /// Hand out a block id, reusing released ids before minting new ones.
    /// `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<u32> {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                if self.next >= self.capacity {
                    return None;
                }
                let id = self.next;
                self.next += 1;
                self.live.push(false);
                id
            }
        };
        debug_assert!(!self.live[id as usize], "allocated a live block {id}");
        self.live[id as usize] = true;
        Some(id)
    }

    /// Return a block to the free list. Double-release is a caller bug and
    /// panics (it would alias one block into two tables).
    pub fn release(&mut self, id: u32) {
        assert!(
            self.live.get(id as usize).copied().unwrap_or(false),
            "release of non-live block {id}"
        );
        self.live[id as usize] = false;
        self.free.push(id);
    }

    pub fn capacity(&self) -> usize {
        self.capacity as usize
    }

    /// Blocks currently assigned to a table.
    pub fn in_use(&self) -> usize {
        self.next as usize - self.free.len()
    }

    /// Blocks ever minted — the backing-storage high-water mark.
    pub fn high_water(&self) -> usize {
        self.next as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_reuse() {
        let mut a = BlockAllocator::new(2);
        let b0 = a.alloc().unwrap();
        let b1 = a.alloc().unwrap();
        assert_ne!(b0, b1);
        assert_eq!(a.alloc(), None, "pool exhausted");
        assert_eq!(a.in_use(), 2);
        a.release(b0);
        assert_eq!(a.in_use(), 1);
        // released id is reused; high-water stays at 2
        assert_eq!(a.alloc(), Some(b0));
        assert_eq!(a.high_water(), 2);
    }

    #[test]
    #[should_panic(expected = "non-live")]
    fn double_release_panics() {
        let mut a = BlockAllocator::new(1);
        let b = a.alloc().unwrap();
        a.release(b);
        a.release(b);
    }
}
