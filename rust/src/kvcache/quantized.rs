//! K-Means row quantizer for the paged KV cache.
//!
//! Each appended `(token, head)` K or V row is quantized independently:
//! max-|inlier| scale, nearest-centroid assignment against a
//! per-layer/per-head codebook, and `quant::packed` index streams — the
//! same [`PackedStream`] byte layout the GEMM weights use (nibbles at
//! 3/4 bits, crumbs at 2 bits). Codebooks are learned from
//! calibration rows when a backend has them (SKIM-style: K-Means holds
//! accuracy at any bit-width) or fall back to a uniform grid over the
//! normalized range (RTN-like). The outlier escape hatch routes the most
//! extreme channels of a row — detected by the Orizuru engine — around
//! the codebook entirely, storing `(channel, fp_value)` pairs.

use crate::orizuru;
use crate::quant::kmeans::kmeans_1d;
use crate::quant::packed::idx_per_byte;
use crate::quant::{Codebook, PackedStream};

/// Which side of the cache a row belongs to (separate codebooks: K rows
/// feed dot products with queries, V rows feed the weighted mix — their
/// distributions differ).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvSide {
    Key,
    Val,
}

/// One quantized cache row, ready for pool insertion.
pub struct QuantRow {
    /// per-row max-|inlier| scale
    pub scale: f32,
    /// packed index stream: `ceil(hd / idx_per_byte)` bytes
    pub bytes: Vec<u8>,
    /// FP-preserved extreme channels: `(channel, original value)`
    pub outliers: Vec<(u16, f32)>,
}

/// Per-layer/per-head codebooks + packing geometry for an n-bit cache.
#[derive(Clone, Debug)]
pub struct KvQuantizer {
    bits: u32,
    n_heads: usize,
    head_dim: usize,
    /// `[layer * n_heads + head]`, normalized centroids
    k_books: Vec<Codebook>,
    v_books: Vec<Codebook>,
    /// Orizuru escape hatch: FP-preserved channels per row per side.
    /// Defaults to 0 — at small head_dim the 6-byte-per-outlier cost
    /// outweighs the accuracy win; [`KvQuantizer::with_outliers`] opts in.
    outliers_per_side: usize,
}

impl KvQuantizer {
    /// Uniform fallback codebooks: `2^bits` centroids at the midpoints of
    /// an even partition of `[-1, 1]` (rows are scale-normalized into that
    /// range). This is the "online" construction — no calibration needed.
    pub fn uniform(n_layers: usize, n_heads: usize, head_dim: usize, bits: u32) -> KvQuantizer {
        assert!((2..=4).contains(&bits), "kv quantizer supports 2..=4 bits");
        let n = 1usize << bits;
        let grid: Vec<f32> = (0..n)
            .map(|i| -1.0 + (2 * i + 1) as f32 / n as f32)
            .collect();
        let book = Codebook::new(grid);
        KvQuantizer {
            bits,
            n_heads,
            head_dim,
            k_books: vec![book.clone(); n_layers * n_heads],
            v_books: vec![book; n_layers * n_heads],
            outliers_per_side: 0,
        }
    }

    /// Learn per-layer/per-head codebooks from calibration rows.
    /// `k_rows[layer * n_heads + head]` holds that head's calibration K
    /// rows (each of length `head_dim`); likewise `v_rows`. Heads with no
    /// calibration rows fall back to the uniform grid.
    pub fn from_calibration(
        n_heads: usize,
        head_dim: usize,
        bits: u32,
        k_rows: &[Vec<Vec<f32>>],
        v_rows: &[Vec<Vec<f32>>],
    ) -> KvQuantizer {
        assert!((2..=4).contains(&bits), "kv quantizer supports 2..=4 bits");
        assert_eq!(k_rows.len(), v_rows.len());
        assert!(n_heads > 0 && k_rows.len() % n_heads == 0, "rows not head-aligned");
        let n_layers = k_rows.len() / n_heads;
        let fallback = KvQuantizer::uniform(n_layers, n_heads, head_dim, bits);
        let learn = |rows: &Vec<Vec<f32>>, fb: &Codebook| -> Codebook {
            let mut samples = Vec::new();
            for row in rows {
                let scale = row
                    .iter()
                    .fold(0.0f32, |m, &v| m.max(v.abs()))
                    .max(1e-12);
                samples.extend(row.iter().map(|&v| v / scale));
            }
            if samples.is_empty() {
                fb.clone()
            } else {
                Codebook::new(kmeans_1d(&samples, 1 << bits, 40))
            }
        };
        let k_books: Vec<Codebook> = k_rows
            .iter()
            .zip(&fallback.k_books)
            .map(|(rows, fb)| learn(rows, fb))
            .collect();
        let v_books: Vec<Codebook> = v_rows
            .iter()
            .zip(&fallback.v_books)
            .map(|(rows, fb)| learn(rows, fb))
            .collect();
        KvQuantizer {
            bits,
            n_heads,
            head_dim,
            k_books,
            v_books,
            outliers_per_side: 0,
        }
    }

    /// Enable the Orizuru outlier escape hatch: keep the `per_side` most
    /// extreme channels per side of each row in FP32.
    pub fn with_outliers(mut self, per_side: usize) -> KvQuantizer {
        self.outliers_per_side = per_side.min(self.head_dim / 2);
        self
    }

    /// Derive the escape-hatch width from a total outlier fraction (the
    /// serving path's knob, mirroring `quant::OutlierCfg`): `floor(frac *
    /// head_dim / 2)` channels per side. Unlike the activation path there
    /// is no 1-minimum — at small head dims a 6-byte FP outlier per row
    /// costs more memory than it saves accuracy (and would break the 4x
    /// bytes/token target), so the hatch engages only once `frac * hd / 2
    /// >= 1` (e.g. `hd >= 200` at the paper's 1% fraction).
    pub fn with_outlier_frac(self, frac: f64) -> KvQuantizer {
        let per_side = (frac * 0.5 * self.head_dim as f64).floor() as usize;
        self.with_outliers(per_side)
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn outliers_per_side(&self) -> usize {
        self.outliers_per_side
    }

    /// Packed indices per byte: nibbles (2) at 3/4 bits, crumbs (4) at 2
    /// — the one density rule, shared with the GEMM weight streams.
    pub fn idx_per_byte(&self) -> usize {
        idx_per_byte(self.bits)
    }

    /// Packed bytes per cache row.
    pub fn row_bytes(&self) -> usize {
        self.head_dim.div_ceil(self.idx_per_byte())
    }

    pub fn book(&self, layer: usize, head: usize, side: KvSide) -> &Codebook {
        let books = match side {
            KvSide::Key => &self.k_books,
            KvSide::Val => &self.v_books,
        };
        &books[layer * self.n_heads + head]
    }

    /// Quantize one `head_dim`-length row straight into a pooled packed
    /// slice (`out_bytes` must be `row_bytes()` long): Orizuru outlier
    /// detection (when enabled), max-|inlier| scaling, codebook
    /// assignment, `quant::packed` in-place index writes. Allocation-free
    /// on the no-outlier path — this is the decode-hot write primitive.
    /// Returns the row scale and the FP-preserved outlier channels.
    pub fn quantize_row_into(
        &self,
        layer: usize,
        head: usize,
        side: KvSide,
        row: &[f32],
        out_bytes: &mut [u8],
    ) -> (f32, Vec<(u16, f32)>) {
        debug_assert_eq!(row.len(), self.head_dim);
        debug_assert_eq!(out_bytes.len(), self.row_bytes());
        let outs = if self.outliers_per_side > 0 {
            orizuru::detect_outliers(row, self.outliers_per_side)
        } else {
            Vec::new()
        };
        // inlier scale: |max| over non-outlier channels (outliers are
        // FP-preserved, so they must not stretch the codebook range)
        let mut oi = 0usize;
        let mut m = 0.0f32;
        for (c, &v) in row.iter().enumerate() {
            if oi < outs.len() && outs[oi] as usize == c {
                oi += 1;
                continue;
            }
            m = m.max(v.abs());
        }
        let scale = m.max(1e-12);
        let book = self.book(layer, head, side);
        for (ch, &v) in row.iter().enumerate() {
            PackedStream::set_in(out_bytes, self.bits, ch, book.assign(v / scale));
        }
        // zero any tail padding in the final byte (reused pool slices may
        // hold a previous tenant's bits there)
        if self.head_dim % self.idx_per_byte() != 0 {
            for ch in self.head_dim..out_bytes.len() * self.idx_per_byte() {
                PackedStream::set_in(out_bytes, self.bits, ch, 0);
            }
        }
        let outliers = outs.iter().map(|&c| (c as u16, row[c as usize])).collect();
        (scale, outliers)
    }

    /// Allocating convenience wrapper over [`KvQuantizer::quantize_row_into`]
    /// (tests and one-off callers).
    pub fn quantize_row(&self, layer: usize, head: usize, side: KvSide, row: &[f32]) -> QuantRow {
        let mut bytes = vec![0u8; self.row_bytes()];
        let (scale, outliers) = self.quantize_row_into(layer, head, side, row, &mut bytes);
        QuantRow { scale, bytes, outliers }
    }
}

/// Read one logical index from a packed row — thin alias of the
/// `quant::packed` layout contract ([`PackedStream::get_in`]), so the bit
/// layout lives in exactly one place.
#[inline]
pub(crate) fn read_idx(bytes: &[u8], bits: u32, ch: usize) -> u8 {
    PackedStream::get_in(bytes, bits, ch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_grid_covers_unit_range() {
        let q = KvQuantizer::uniform(2, 2, 16, 4);
        let b = q.book(1, 1, KvSide::Key);
        assert_eq!(b.len(), 16);
        assert!(b.centroids.iter().all(|c| c.abs() < 1.0));
        assert_eq!(q.row_bytes(), 8);
        assert_eq!(KvQuantizer::uniform(1, 1, 16, 2).row_bytes(), 4);
        assert_eq!(KvQuantizer::uniform(1, 1, 17, 3).row_bytes(), 9);
    }

    #[test]
    fn quantize_row_roundtrip_error_bounded() {
        let mut rng = Rng::new(7);
        for bits in [4u32, 3, 2] {
            let q = KvQuantizer::uniform(1, 1, 32, bits);
            let row = rng.normal_vec(32, 1.0);
            let qr = q.quantize_row(0, 0, KvSide::Key, &row);
            assert_eq!(qr.bytes.len(), q.row_bytes());
            let book = q.book(0, 0, KvSide::Key);
            let max_cell = 2.0 * qr.scale / (1u32 << bits) as f32 + 1e-5;
            for (ch, &v) in row.iter().enumerate() {
                let deq = book.value(read_idx(&qr.bytes, q.bits(), ch)) * qr.scale;
                assert!(
                    (v - deq).abs() <= max_cell,
                    "bits {bits} ch {ch}: {v} vs {deq}"
                );
            }
        }
    }

    #[test]
    fn calibrated_books_beat_uniform_on_calibration_distribution() {
        let mut rng = Rng::new(9);
        // heavy-tailed rows: k-means places centroids where the mass is
        let rows: Vec<Vec<f32>> = (0..48).map(|_| rng.heavy_tailed_vec(16, 0.05, 6.0)).collect();
        let cal = KvQuantizer::from_calibration(1, 16, 3, &[rows.clone()], &[rows.clone()]);
        let uni = KvQuantizer::uniform(1, 1, 16, 3);
        let err = |q: &KvQuantizer, layer_head_rows: &[Vec<f32>]| -> f64 {
            let mut e = 0f64;
            for row in layer_head_rows {
                let qr = q.quantize_row(0, 0, KvSide::Key, row);
                let book = q.book(0, 0, KvSide::Key);
                for (ch, &v) in row.iter().enumerate() {
                    let deq =
                        book.value(read_idx(&qr.bytes, q.bits(), ch)) * qr.scale;
                    e += ((v - deq) as f64).powi(2);
                }
            }
            e
        };
        assert!(
            err(&cal, &rows) < err(&uni, &rows),
            "calibrated {} !< uniform {}",
            err(&cal, &rows),
            err(&uni, &rows)
        );
    }

    #[test]
    fn quantize_row_into_matches_pack_and_clears_reused_slices() {
        let mut rng = Rng::new(13);
        for (hd, bits) in [(16usize, 4u32), (15, 3), (10, 2)] {
            let q = KvQuantizer::uniform(1, 1, hd, bits);
            let row = rng.normal_vec(hd, 1.0);
            // a dirty pooled slice (reused block) must come out identical
            // to a fresh pack of the same indices
            let mut dirty = vec![0xFFu8; q.row_bytes()];
            let (scale, _) = q.quantize_row_into(0, 0, KvSide::Key, &row, &mut dirty);
            let book = q.book(0, 0, KvSide::Key);
            let idx: Vec<u8> = row.iter().map(|&v| book.assign(v / scale)).collect();
            let packed = PackedStream::pack(&idx, bits).bytes;
            assert_eq!(dirty, packed, "hd {hd} bits {bits}");
            assert_eq!(q.quantize_row(0, 0, KvSide::Key, &row).bytes, packed);
        }
    }

    #[test]
    fn outlier_frac_engages_only_at_large_head_dim() {
        // paper's 1% total fraction: zero on small heads (preserves the
        // 4x bytes/token target), >= 1 per side once frac * hd / 2 >= 1
        assert_eq!(KvQuantizer::uniform(1, 1, 16, 4).with_outlier_frac(0.01).outliers_per_side(), 0);
        assert_eq!(KvQuantizer::uniform(1, 1, 128, 4).with_outlier_frac(0.01).outliers_per_side(), 0);
        assert_eq!(KvQuantizer::uniform(1, 1, 256, 4).with_outlier_frac(0.01).outliers_per_side(), 1);
        assert_eq!(KvQuantizer::uniform(1, 1, 16, 4).with_outlier_frac(0.25).outliers_per_side(), 2);
    }

    #[test]
    fn outlier_escape_hatch_preserves_extremes() {
        let mut rng = Rng::new(3);
        let mut row = rng.normal_vec(16, 0.5);
        row[3] = 40.0;
        row[11] = -35.0;
        let q = KvQuantizer::uniform(1, 1, 16, 4).with_outliers(1);
        assert_eq!(q.outliers_per_side(), 1);
        let qr = q.quantize_row(0, 0, KvSide::Val, &row);
        let chans: Vec<u16> = qr.outliers.iter().map(|&(c, _)| c).collect();
        assert_eq!(chans, vec![3, 11]);
        for &(c, v) in &qr.outliers {
            assert_eq!(v, row[c as usize]);
        }
        // the scale reflects inliers, not the planted spikes
        assert!(qr.scale < 5.0, "scale {} stretched by outliers", qr.scale);
    }
}
