//! Paged, precision-pluggable KV-cache subsystem.
//!
//! The serving stack's last FP32 hole was the KV cache: both decode
//! backends kept dense `(L, B, H, S, hd)` float tensors, so at long
//! context the dominant activation traffic — attention's K/V reads —
//! stayed at full precision while weights and activations ran through the
//! K-Means WAQ datapath. This module brings the cache into the index
//! domain: storage is organized in fixed-size *blocks* handed out by a
//! free-list allocator, and each block's payload is either raw FP32 or
//! per-layer/per-head K-Means-quantized index streams.
//!
//! # Block layout
//!
//! A block holds `block_tokens` consecutive token positions of one
//! `(layer, slot)` pair, K and V together, head-major:
//!
//! ```text
//! block = [ K: head 0 [tok 0..BT][hd] | head 1 [..] | ... |
//!           V: head 0 [tok 0..BT][hd] | head 1 [..] | ... ]
//! ```
//!
//! Per `(slot, layer)` a block table maps position `p` to
//! `blocks[p / block_tokens]`; writes are append-only (position `p` must
//! equal the written count), so a slot at context length `n` owns exactly
//! `ceil(n / block_tokens)` blocks per layer. Releasing a slot pushes its
//! block ids back on the free list — **copy-free**: no zero-fill, because
//! reads are bounded by the written count and dense materialization only
//! visits written positions (stale block contents are unobservable).
//!
//! # Storage precisions
//!
//! * [`KvPrecision::Fp32`] — raw `f32` payloads, bit-exact with the dense
//!   cache it replaces (the gather/mix primitives reproduce the exact
//!   accumulation order of the previous attention loops).
//! * [`KvPrecision::Quant`] — nA-bit K-Means storage: each `(token, head)`
//!   row is max-|inlier|-scaled, assigned against a per-layer/per-head
//!   [`crate::quant::Codebook`] (learned from calibration rows or a
//!   uniform fallback grid), and packed via `quant::packed` — the same
//!   [`crate::quant::PackedStream`] byte layout the GEMM weight streams
//!   use (nibbles for 3/4-bit, crumbs for 2-bit). An
//!   Orizuru-detected outlier escape hatch keeps the most extreme
//!   channels of a row in FP32 (`(channel, value)` pairs applied on top
//!   of the index stream at read time).
//!
//! # Bytes/token math
//!
//! Per token position, across all `L` layers and both K and V
//! (`ob = outliers_per_side`, scale stored as one `f32` per row):
//!
//! ```text
//! fp32 :  L * 2 * H *  hd * 4                                  bytes
//! n-bit:  L * 2 * H * (ceil(hd / idx_per_byte) + 4 + ob*2*6)   bytes
//! ```
//!
//! with `idx_per_byte = 2` (nibbles, 3/4-bit) or `4` (crumbs, 2-bit). For
//! the test preset (`L=2, H=4, hd=16`) that is 1024 bytes/token at FP32
//! vs 192 at 4-bit — a 5.3x reduction (>= the 4x target), and 96 at
//! 2-bit. [`PagedKvCache::bytes_per_token`] reports this figure;
//! [`PagedKvCache::peak_bytes`] reports the high-water mark of actually
//! reserved block storage.
//!
//! # Prefix sharing (`--prefix-cache on`)
//!
//! Because block tables are indirection, requests that share a prompt
//! head can share *physical* blocks. [`prefix::PrefixIndex`] is a radix
//! trie over prompt-token chunks at block granularity: admission walks
//! it and aliases every matched block into the new slot's tables
//! (refcount +1 per block per layer in [`BlockAllocator`]), so only the
//! uncached prompt tail is ever prefilled; after prefill the prompt's
//! chunks are registered so later requests hit them. Shared blocks keep
//! their stored payloads — quantized or FP32 — so a hit never
//! requantizes and never dequantizes outside the fused attention
//! gathers, which is what keeps hit-path decode bit-exact with a cold
//! run at every `--kv-bits`.
//!
//! The refcount / copy-on-write / eviction protocol:
//!
//! * every holder of a block (each `(slot, layer)` table entry, plus the
//!   index itself for registered chunks) owns one reference; the block
//!   returns to the free list only when the last holder releases it —
//!   no leaks, no double frees (underflow panics);
//! * an append into a block with refcount > 1 first copies the shared
//!   token rows `[0, ti)` into a private block (**copy-on-write**), so
//!   divergent continuations never corrupt a shared prefix;
//! * when the pool is exhausted, LRU **eviction** walks the index for
//!   the coldest leaf whose blocks the index holds alone (refcount ==
//!   1) and frees it — blocks aliased into any live slot are never
//!   evicted, and with the index disabled behavior is exactly the
//!   pre-prefix-cache error path.

pub mod block;
pub mod paged;
pub mod prefix;
pub mod quantized;

pub use block::BlockAllocator;
pub use paged::{KvPrecision, PagedKvCache, PrefixMatch};
pub use prefix::PrefixIndex;
pub use quantized::{KvQuantizer, KvSide};

/// KV-cache storage precision selector (the `--kv-bits {32,4,3,2}` knob).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvBits {
    /// Dense FP32 payloads (bit-exact with the pre-paged cache).
    #[default]
    Fp32,
    /// 4-bit K-Means indices, nibble-packed.
    B4,
    /// 3-bit K-Means indices, nibble-packed (byte-aligned streaming).
    B3,
    /// 2-bit K-Means indices, crumb-packed.
    B2,
}

impl KvBits {
    pub const ALL: [KvBits; 4] = [KvBits::Fp32, KvBits::B4, KvBits::B3, KvBits::B2];

    /// Parse the CLI bit-width (`32 | 4 | 3 | 2`).
    pub fn from_bits(bits: u32) -> Result<KvBits, String> {
        match bits {
            32 => Ok(KvBits::Fp32),
            4 => Ok(KvBits::B4),
            3 => Ok(KvBits::B3),
            2 => Ok(KvBits::B2),
            other => Err(format!("unsupported --kv-bits {other} (expected 32|4|3|2)")),
        }
    }

    /// The stored bits label (32 for FP32, else the codebook bit-width).
    pub fn bits(self) -> u32 {
        match self {
            KvBits::Fp32 => 32,
            KvBits::B4 => 4,
            KvBits::B3 => 3,
            KvBits::B2 => 2,
        }
    }

    pub fn is_quantized(self) -> bool {
        self != KvBits::Fp32
    }
}

impl std::fmt::Display for KvBits {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // f.pad honors width/alignment specifiers (bench column layout)
        f.pad(&self.bits().to_string())
    }
}

impl std::str::FromStr for KvBits {
    type Err = String;

    fn from_str(s: &str) -> Result<KvBits, String> {
        let bits: u32 = s
            .parse()
            .map_err(|_| format!("unsupported --kv-bits '{s}' (expected 32|4|3|2)"))?;
        KvBits::from_bits(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bits_roundtrip() {
        for b in KvBits::ALL {
            assert_eq!(KvBits::from_bits(b.bits()), Ok(b));
            assert_eq!(b.to_string().parse::<KvBits>(), Ok(b));
        }
        assert!(KvBits::from_bits(8).is_err());
        assert!("16".parse::<KvBits>().is_err());
        assert!("fp32".parse::<KvBits>().is_err());
        assert_eq!(KvBits::default(), KvBits::Fp32);
        assert!(!KvBits::Fp32.is_quantized());
        assert!(KvBits::B2.is_quantized());
    }
}
