//! Radix (trie) index over prompt-token prefixes at block granularity —
//! the sharing half of the prefix-cache subsystem.
//!
//! # Structure
//!
//! Each node covers a *chunk* of `1..=block_tokens` consecutive prompt
//! tokens and owns one physical block id **per layer** (the same token
//! positions exist in every layer's table, so a chunk pins `n_layers`
//! blocks). Children hang only off *full* (`block_tokens`-sized) nodes:
//! a partial node is always the last hop of a path, mirroring the fact
//! that only the final block of a prompt can be partially filled.
//!
//! # Protocol (see [`super::paged::PagedKvCache`] for the other half)
//!
//! * **Lookup / aliasing** — [`PrefixIndex::lookup`] walks the trie,
//!   descending through exact full-chunk matches and finishing with the
//!   child sharing the longest partial prefix. The caller aliases every
//!   matched node's blocks into the admitted slot's tables
//!   ([`super::BlockAllocator::retain`] per block), so a hit costs
//!   pointer pushes, not prefill compute. Matched tokens are capped by
//!   the caller so at least one prompt token is always computed (logits
//!   must exist for sampling).
//! * **Registration** — after prefill, [`PrefixIndex::register`] inserts
//!   the prompt's chunks, retaining the slot's blocks for every *newly
//!   created* node; chunks that already have an exact-token node are
//!   deduplicated (descend, no second copy). The index is a first-class
//!   block holder: a node's blocks stay live after every slot using them
//!   is released.
//! * **Eviction** — [`PrefixIndex::evict_lru`] removes the
//!   least-recently-used *leaf* whose blocks are held by the index alone
//!   (refcount == 1 on every layer's block), returning the block ids for
//!   the cache to free. Interior nodes become evictable once their
//!   children go; blocks aliased into any live slot are never evicted.
//!
//! # Invariants
//!
//! 1. A node's `blocks` has exactly one entry per model layer.
//! 2. Only full nodes have children (partial nodes are leaves).
//! 3. Every node's blocks carry one index-owned reference; eviction is
//!    the only operation that drops it.
//! 4. `last_used` of a matched node's ancestors is always >= as fresh as
//!    the match (a child match implies a full parent match on the same
//!    walk), so LRU leaf eviction never strands a hot interior path.

use super::block::BlockAllocator;

/// One matched hop of a lookup walk: the node's per-layer block ids and
/// how many of its tokens matched (== chunk length except for the final
/// partial hop).
pub struct MatchSeg {
    pub blocks: Vec<u32>,
    pub tokens: usize,
}

struct Node {
    /// the token chunk this node covers (`1..=block_tokens` tokens)
    tokens: Vec<i32>,
    /// one physical block id per layer
    blocks: Vec<u32>,
    children: Vec<usize>,
    /// arena id of the parent; `None` for top-level (root) nodes
    parent: Option<usize>,
    /// LRU clock value of the last lookup/registration touching this node
    last_used: u64,
}

/// Block-granularity radix index over prompt-token prefixes.
pub struct PrefixIndex {
    block_tokens: usize,
    n_layers: usize,
    /// node arena; `None` = freed entry (reused via `free`)
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// top-level nodes (children of the conceptual root)
    roots: Vec<usize>,
    /// monotone LRU clock, bumped once per lookup/register call
    tick: u64,
}

impl PrefixIndex {
    pub fn new(block_tokens: usize, n_layers: usize) -> PrefixIndex {
        PrefixIndex {
            block_tokens,
            n_layers,
            nodes: Vec::new(),
            free: Vec::new(),
            roots: Vec::new(),
            tick: 0,
        }
    }

    /// Live node count (introspection for tests and stats).
    pub fn node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    /// Every block id the index holds a reference on, with multiplicity
    /// (one entry per node per layer). Introspection for refcount audits:
    /// summing these against slot tables must reproduce the allocator's
    /// per-block reference counts exactly.
    pub fn block_refs(&self) -> Vec<u32> {
        self.nodes
            .iter()
            .flatten()
            .flat_map(|n| n.blocks.iter().copied())
            .collect()
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("freed node id")
    }

    fn insert_node(&mut self, n: Node) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = Some(n);
                i
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    /// Walk the trie along `prompt`, matching at most `max_tokens`
    /// positions. Descends through exact full-chunk matches; the final
    /// hop may match only a prefix of a node's chunk (the caller aliases
    /// that block partially and copy-on-write fires on its first
    /// divergent append). Touches every matched node's LRU stamp.
    pub fn lookup(&mut self, prompt: &[i32], max_tokens: usize) -> Vec<MatchSeg> {
        self.tick += 1;
        let tick = self.tick;
        let mut path = Vec::new();
        let mut children = self.roots.clone();
        let mut consumed = 0usize;
        loop {
            let budget = max_tokens.saturating_sub(consumed);
            if budget == 0 || children.is_empty() {
                break;
            }
            let remaining = &prompt[consumed..prompt.len().min(max_tokens)];
            // best child = longest shared token prefix with the remainder
            let mut best: Option<(usize, usize)> = None;
            for &c in &children {
                let node = self.node(c);
                let k = node
                    .tokens
                    .iter()
                    .zip(remaining)
                    .take_while(|(a, b)| a == b)
                    .count();
                if k > best.map_or(0, |(_, bk)| bk) {
                    best = Some((c, k));
                }
            }
            let Some((c, k)) = best else { break };
            let (full, blocks, kids) = {
                let node = self.node(c);
                (k == node.tokens.len(), node.blocks.clone(), node.children.clone())
            };
            self.nodes[c].as_mut().unwrap().last_used = tick;
            path.push(MatchSeg { blocks, tokens: k });
            consumed += k;
            if !full {
                break; // partial hop is always terminal
            }
            children = kids;
        }
        path
    }

    /// Insert `tokens` (a prefilled prompt prefix) into the trie.
    /// `chunk_blocks[i]` holds the admitted slot's per-layer block ids
    /// covering chunk `i`; blocks of newly created nodes are retained in
    /// `alloc` (the index becomes a holder), while chunks with an exact
    /// existing node are deduplicated against it.
    pub fn register(
        &mut self,
        tokens: &[i32],
        chunk_blocks: &[Vec<u32>],
        alloc: &mut BlockAllocator,
    ) {
        self.tick += 1;
        let tick = self.tick;
        let bt = self.block_tokens;
        let mut parent: Option<usize> = None;
        for (ci, chunk) in tokens.chunks(bt).enumerate() {
            let children = match parent {
                None => self.roots.clone(),
                Some(p) => self.node(p).children.clone(),
            };
            let found = children
                .iter()
                .copied()
                .find(|&c| self.node(c).tokens == chunk);
            let id = match found {
                Some(c) => {
                    self.nodes[c].as_mut().unwrap().last_used = tick;
                    c
                }
                None => {
                    let blocks = chunk_blocks[ci].clone();
                    debug_assert_eq!(blocks.len(), self.n_layers);
                    for &b in &blocks {
                        alloc.retain(b);
                    }
                    let id = self.insert_node(Node {
                        tokens: chunk.to_vec(),
                        blocks,
                        children: Vec::new(),
                        parent,
                        last_used: tick,
                    });
                    match parent {
                        None => self.roots.push(id),
                        Some(p) => self.nodes[p].as_mut().unwrap().children.push(id),
                    }
                    id
                }
            };
            // invariant 2: only full chunks can take children — a partial
            // chunk is by construction the prompt's last
            debug_assert!(chunk.len() == bt || ci == tokens.chunks(bt).count() - 1);
            parent = Some(id);
        }
    }

    /// Evict the least-recently-used leaf whose blocks the index holds
    /// alone (refcount == 1 on every layer), returning its block ids for
    /// the cache to free. `None` when nothing is evictable (every indexed
    /// block is aliased into a live slot, or the index is empty).
    pub fn evict_lru(&mut self, alloc: &BlockAllocator) -> Option<Vec<u32>> {
        let mut best: Option<(usize, u64)> = None;
        for (i, slot) in self.nodes.iter().enumerate() {
            if let Some(n) = slot {
                if n.children.is_empty()
                    && n.blocks.iter().all(|&b| alloc.ref_count(b) == 1)
                    && best.map_or(true, |(_, t)| n.last_used < t)
                {
                    best = Some((i, n.last_used));
                }
            }
        }
        let (i, _) = best?;
        let node = self.nodes[i].take().unwrap();
        match node.parent {
            None => self.roots.retain(|&c| c != i),
            Some(p) => self.nodes[p].as_mut().unwrap().children.retain(|&c| c != i),
        }
        self.free.push(i);
        Some(node.blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Stand-in allocator state: every node's blocks get one index ref.
    fn index_with(alloc: &mut BlockAllocator) -> PrefixIndex {
        let _ = alloc;
        PrefixIndex::new(4, 2)
    }

    fn fresh_blocks(alloc: &mut BlockAllocator, n: usize) -> Vec<u32> {
        (0..n).map(|_| alloc.alloc().unwrap()).collect()
    }

    #[test]
    fn lookup_matches_full_and_partial_chunks() {
        let mut alloc = BlockAllocator::new(64);
        let mut idx = index_with(&mut alloc);
        // register [1,2,3,4 | 5,6] — one full node, one partial leaf
        let tokens = [1, 2, 3, 4, 5, 6];
        let b0 = fresh_blocks(&mut alloc, 2);
        let b1 = fresh_blocks(&mut alloc, 2);
        idx.register(&tokens, &[b0.clone(), b1.clone()], &mut alloc);
        assert_eq!(idx.node_count(), 2);
        for &b in b0.iter().chain(&b1) {
            assert_eq!(alloc.ref_count(b), 2, "slot + index");
        }
        // exact walk: full chunk + 2 of the partial node's tokens
        let m = idx.lookup(&[1, 2, 3, 4, 5, 6, 9, 9], 7);
        assert_eq!(m.len(), 2);
        assert_eq!((m[0].tokens, m[1].tokens), (4, 2));
        assert_eq!(m[0].blocks, b0);
        assert_eq!(m[1].blocks, b1);
        // divergence inside the first chunk: partial hop, walk stops
        let m = idx.lookup(&[1, 2, 9, 9, 9], 5);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].tokens, 2);
        // budget cap: max_tokens bounds the match even on identical tokens
        let m = idx.lookup(&[1, 2, 3, 4, 5, 6], 3);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].tokens, 3);
        // no shared prefix at all
        assert!(idx.lookup(&[7, 7, 7], 3).is_empty());
    }

    #[test]
    fn register_dedups_exact_chunks_and_branches_on_divergence() {
        let mut alloc = BlockAllocator::new(64);
        let mut idx = index_with(&mut alloc);
        let head = fresh_blocks(&mut alloc, 2);
        let tail_a = fresh_blocks(&mut alloc, 2);
        idx.register(&[1, 2, 3, 4, 10, 11], &[head.clone(), tail_a], &mut alloc);
        // second prompt shares the full head chunk, diverges after it:
        // the head node is reused (no extra ref), the tail becomes a sibling
        let head_dup = fresh_blocks(&mut alloc, 2);
        let tail_b = fresh_blocks(&mut alloc, 2);
        idx.register(
            &[1, 2, 3, 4, 20, 21],
            &[head_dup.clone(), tail_b.clone()],
            &mut alloc,
        );
        assert_eq!(idx.node_count(), 3, "head shared, two tails");
        for &b in &head {
            assert_eq!(alloc.ref_count(b), 2, "deduped chunk not re-retained");
        }
        for &b in &head_dup {
            assert_eq!(alloc.ref_count(b), 1, "duplicate head block stays slot-private");
        }
        for &b in &tail_b {
            assert_eq!(alloc.ref_count(b), 2);
        }
        // both tails reachable under the shared head
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 20, 21], 6).len(), 2);
        assert_eq!(idx.lookup(&[1, 2, 3, 4, 10, 11], 6).len(), 2);
    }

    #[test]
    fn evict_lru_takes_cold_leaves_and_skips_aliased_blocks() {
        let mut alloc = BlockAllocator::new(64);
        let mut idx = index_with(&mut alloc);
        let head = fresh_blocks(&mut alloc, 2);
        let tail_a = fresh_blocks(&mut alloc, 2);
        let tail_b = fresh_blocks(&mut alloc, 2);
        idx.register(&[1, 2, 3, 4, 10], &[head.clone(), tail_a.clone()], &mut alloc);
        idx.register(&[1, 2, 3, 4, 20], &[head.clone(), tail_b.clone()], &mut alloc);
        // drop the registering slots' own refs: index becomes sole holder
        for &b in head.iter().chain(&tail_a).chain(&tail_b) {
            alloc.release(b);
        }
        // head was deduped on the second register (one index ref only)
        assert_eq!(alloc.ref_count(head[0]), 1);
        assert_eq!(alloc.ref_count(tail_a[0]), 1);
        // touch tail_b so tail_a is the LRU leaf
        idx.lookup(&[1, 2, 3, 4, 20], 5);
        let evicted = idx.evict_lru(&alloc).expect("tail_a evictable");
        assert_eq!(evicted, tail_a);
        for b in evicted {
            alloc.release(b);
        }
        // head is interior (tail_b remains) — next LRU victim is tail_b
        let evicted = idx.evict_lru(&alloc).expect("tail_b evictable");
        assert_eq!(evicted, tail_b);
        for b in evicted {
            alloc.release(b);
        }
        // now the head is a leaf and goes last
        let evicted = idx.evict_lru(&alloc).expect("head evictable");
        assert_eq!(evicted, head);
        for b in evicted {
            alloc.release(b);
        }
        assert_eq!(idx.node_count(), 0);
        assert!(idx.evict_lru(&alloc).is_none(), "empty index");
        assert_eq!(alloc.in_use(), 0, "no leaked blocks");
    }

    #[test]
    fn aliased_leaf_is_not_evictable() {
        let mut alloc = BlockAllocator::new(8);
        let mut idx = index_with(&mut alloc);
        let blocks = fresh_blocks(&mut alloc, 2);
        idx.register(&[1, 2, 3], &[blocks.clone()], &mut alloc);
        // slot still holds its ref (refcount 2): nothing evictable
        assert!(idx.evict_lru(&alloc).is_none());
        for &b in &blocks {
            alloc.release(b);
        }
        assert_eq!(idx.evict_lru(&alloc), Some(blocks));
    }
}
