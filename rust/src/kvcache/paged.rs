//! The paged KV cache: block tables over a free-list allocator, with a
//! pluggable payload store (FP32 or n-bit K-Means). See the module docs
//! in [`super`] for the block layout and bytes/token math.
//!
//! The attention-facing surface is deliberately *fused*: [`key_scores`]
//! computes `q . K[pos]` and [`value_mix`] accumulates `w[pos] * V[pos]`
//! straight off the stored representation — for quantized payloads the
//! centroid lookup happens inside the dot/mix loops, so no FP32 copy of
//! the cache is ever materialized on the decode path. For FP32 payloads
//! both primitives reproduce the exact accumulation order of the dense
//! attention loops they replaced, keeping `--kv-bits 32` bit-exact.
//!
//! [`key_scores`]: PagedKvCache::key_scores
//! [`value_mix`]: PagedKvCache::value_mix

use super::block::BlockAllocator;
use super::prefix::PrefixIndex;
use super::quantized::{read_idx, KvQuantizer, KvSide};
use crate::runtime::artifacts::ModelCfg;

/// Storage precision of a [`PagedKvCache`].
pub enum KvPrecision {
    /// Raw f32 payloads — bit-exact with the dense cache it replaces.
    Fp32,
    /// n-bit K-Means index streams driven by the given quantizer.
    Quant(KvQuantizer),
}

/// Result of a prefix-index admission: how many prompt tokens were
/// served from aliased blocks and how many per-layer block aliases that
/// took (`tokens > 0` counts as one prefix hit).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixMatch {
    pub tokens: usize,
    pub blocks: usize,
}

/// Bytes per stored outlier entry: u16 channel + f32 value (accounted,
/// not byte-packed — outliers live in a side table).
const OUTLIER_BYTES: usize = 6;

/// Shared per-block geometry.
#[derive(Clone, Copy)]
struct Geom {
    block_tokens: usize,
    n_heads: usize,
    head_dim: usize,
}

impl Geom {
    /// Row index of `(head, tok_in_block)` within a block.
    #[inline]
    fn row(&self, block: u32, head: usize, ti: usize) -> usize {
        block as usize * self.block_tokens * self.n_heads + head * self.block_tokens + ti
    }
}

struct Fp32Store {
    geom: Geom,
    /// per block: `block_tokens * n_heads * head_dim` f32, head-major
    k: Vec<f32>,
    v: Vec<f32>,
}

struct QuantStore {
    geom: Geom,
    quantizer: KvQuantizer,
    /// packed index pools: `row_bytes` bytes per `(head, tok)` row
    k_idx: Vec<u8>,
    v_idx: Vec<u8>,
    /// per-row scales
    k_scale: Vec<f32>,
    v_scale: Vec<f32>,
    /// FP-preserved channels per row (empty unless the escape hatch is on)
    k_out: Vec<Vec<(u16, f32)>>,
    v_out: Vec<Vec<(u16, f32)>>,
    row_bytes: usize,
    /// running count of live outlier entries across all rows (kept by
    /// `write_token`/`release_block`, so byte accounting is O(1) on the
    /// per-step stats path instead of an all-rows walk)
    outlier_entries: usize,
    /// high-water mark of `outlier_entries` (keeps `peak_bytes` monotone)
    peak_outlier_entries: usize,
}

enum Store {
    Fp32(Fp32Store),
    Quant(QuantStore),
}

/// Paged, precision-pluggable KV cache for `decode_batch` slots.
pub struct PagedKvCache {
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    seq_len: usize,
    n_slots: usize,
    block_tokens: usize,
    alloc: BlockAllocator,
    /// `[slot * n_layers + layer]` -> ordered block ids covering positions
    /// `[0, written)`
    tables: Vec<Vec<u32>>,
    /// `[slot * n_layers + layer]` -> written position count
    written: Vec<usize>,
    store: Store,
    /// prompt-prefix radix index (`--prefix-cache on`); `None` = disabled
    prefix: Option<PrefixIndex>,
    /// blocks freed by LRU eviction (prefix-index-only blocks dropped to
    /// make room for allocations)
    evictions: u64,
}

impl PagedKvCache {
    /// Block granularity: 16 token positions (or the whole context when
    /// the model's window is smaller).
    pub const DEFAULT_BLOCK_TOKENS: usize = 16;

    pub fn new(m: &ModelCfg, precision: KvPrecision) -> PagedKvCache {
        Self::new_with_prefix(m, precision, false)
    }

    /// Build the cache with the prompt-prefix radix index enabled or
    /// disabled. With it off, behavior is identical to pre-prefix-cache
    /// builds (every refcount stays at 1, so copy-on-write never fires
    /// and nothing is ever evictable).
    pub fn new_with_prefix(
        m: &ModelCfg,
        precision: KvPrecision,
        prefix_cache: bool,
    ) -> PagedKvCache {
        let block_tokens = Self::DEFAULT_BLOCK_TOKENS.min(m.seq_len.max(1));
        let blocks_per = m.seq_len.div_ceil(block_tokens);
        let capacity = m.decode_batch * m.n_layers * blocks_per;
        let geom = Geom { block_tokens, n_heads: m.n_heads, head_dim: m.head_dim };
        let store = match precision {
            KvPrecision::Fp32 => Store::Fp32(Fp32Store { geom, k: Vec::new(), v: Vec::new() }),
            KvPrecision::Quant(quantizer) => {
                assert_eq!(
                    quantizer.head_dim(),
                    m.head_dim,
                    "quantizer head_dim mismatch"
                );
                Store::Quant(QuantStore {
                    geom,
                    row_bytes: quantizer.row_bytes(),
                    quantizer,
                    k_idx: Vec::new(),
                    v_idx: Vec::new(),
                    k_scale: Vec::new(),
                    v_scale: Vec::new(),
                    k_out: Vec::new(),
                    v_out: Vec::new(),
                    outlier_entries: 0,
                    peak_outlier_entries: 0,
                })
            }
        };
        PagedKvCache {
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            seq_len: m.seq_len,
            n_slots: m.decode_batch,
            block_tokens,
            alloc: BlockAllocator::new(capacity),
            tables: vec![Vec::new(); m.decode_batch * m.n_layers],
            written: vec![0; m.decode_batch * m.n_layers],
            store,
            prefix: prefix_cache.then(|| PrefixIndex::new(block_tokens, m.n_layers)),
            evictions: 0,
        }
    }

    /// Whether the prompt-prefix radix index is enabled.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Live prefix-index node count (stats/introspection).
    pub fn prefix_nodes(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.node_count())
    }

    /// Blocks freed by LRU eviction so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Allocator reference count for one block id (refcount audits).
    pub fn block_ref_count(&self, id: u32) -> usize {
        self.alloc.ref_count(id)
    }

    /// Every block id the prefix index holds a reference on, with
    /// multiplicity (empty when the index is disabled). Together with the
    /// slot tables this enumerates every holder the allocator knows of.
    pub fn prefix_block_refs(&self) -> Vec<u32> {
        self.prefix.as_ref().map_or_else(Vec::new, |p| p.block_refs())
    }

    /// Stored bits per cache element: 32 for FP32, else the codebook
    /// bit-width.
    pub fn bits(&self) -> u32 {
        match &self.store {
            Store::Fp32(_) => 32,
            Store::Quant(q) => q.quantizer.bits(),
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    #[inline]
    fn entry(&self, layer: usize, slot: usize) -> usize {
        debug_assert!(layer < self.n_layers && slot < self.n_slots);
        slot * self.n_layers + layer
    }

    /// Written position count for `(layer, slot)`.
    pub fn written(&self, layer: usize, slot: usize) -> usize {
        self.written[self.entry(layer, slot)]
    }

    /// The `(layer, slot)` block table (introspection for invariants and
    /// property tests).
    pub fn slot_blocks(&self, layer: usize, slot: usize) -> &[u32] {
        &self.tables[self.entry(layer, slot)]
    }

    /// Blocks currently assigned across all tables.
    pub fn in_use_blocks(&self) -> usize {
        self.alloc.in_use()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.alloc.capacity()
    }

    /// Append one token's K and V rows (each `n_heads * head_dim`,
    /// head-major) for `(layer, slot)` at position `pos`. Writes are
    /// strictly append-only: `pos` must equal the written count.
    pub fn append(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), String> {
        if layer >= self.n_layers || slot >= self.n_slots {
            return Err(format!("append out of range: layer {layer} slot {slot}"));
        }
        if pos >= self.seq_len {
            return Err(format!("append pos {pos} beyond context {}", self.seq_len));
        }
        let d = self.n_heads * self.head_dim;
        if k_row.len() != d || v_row.len() != d {
            return Err(format!("append row length {} != {d}", k_row.len()));
        }
        let e = self.entry(layer, slot);
        if pos != self.written[e] {
            return Err(format!(
                "append out of order: pos {pos}, written {}",
                self.written[e]
            ));
        }
        let bi = pos / self.block_tokens;
        if bi == self.tables[e].len() {
            let id = self.alloc_with_evict()?;
            self.store.ensure(id);
            self.tables[e].push(id);
        }
        let mut block = self.tables[e][bi];
        let ti = pos % self.block_tokens;
        if self.alloc.ref_count(block) > 1 {
            // copy-on-write: the block is aliased (other slots and/or the
            // prefix index hold it), so this slot's first divergent
            // append lands in a private copy of the shared rows [0, ti)
            let id = self.alloc_with_evict()?;
            self.store.ensure(id);
            self.store.copy_rows(block, id, ti);
            self.tables[e][bi] = id;
            if self.alloc.release(block) {
                self.store.release_block(block);
            }
            block = id;
        }
        self.store.write_token(block, ti, layer, k_row, v_row);
        self.written[e] = pos + 1;
        Ok(())
    }

    /// Allocate a block, evicting LRU prefix-index-only blocks when the
    /// pool is exhausted. Without the index (or with nothing evictable)
    /// exhaustion is an error, exactly as before.
    fn alloc_with_evict(&mut self) -> Result<u32, String> {
        if let Some(id) = self.alloc.alloc() {
            return Ok(id);
        }
        let Some(mut idx) = self.prefix.take() else {
            return Err("kv block pool exhausted".to_string());
        };
        let got = loop {
            match idx.evict_lru(&self.alloc) {
                Some(blocks) => {
                    for b in blocks {
                        if self.alloc.release(b) {
                            self.store.release_block(b);
                        }
                        self.evictions += 1;
                    }
                    if let Some(id) = self.alloc.alloc() {
                        break Ok(id);
                    }
                }
                None => {
                    break Err(
                        "kv block pool exhausted (no evictable prefix blocks)".to_string()
                    )
                }
            }
        };
        self.prefix = Some(idx);
        got
    }

    /// Consult the prefix index for `prompt` and alias every matched
    /// block into `slot`'s tables (refcount +1 per block per layer). The
    /// slot must be empty. At most `max_match` tokens are served from the
    /// cache — the caller passes `plen - 1` so at least one prompt token
    /// is always computed (sampling needs logits). A no-op returning zero
    /// when the index is disabled.
    pub fn admit_prefix(
        &mut self,
        slot: usize,
        prompt: &[i32],
        max_match: usize,
    ) -> PrefixMatch {
        let Some(mut idx) = self.prefix.take() else {
            return PrefixMatch::default();
        };
        debug_assert!(
            (0..self.n_layers).all(|l| self.written[self.entry(l, slot)] == 0),
            "prefix admission into a non-empty slot"
        );
        let path = idx.lookup(prompt, max_match);
        let mut matched = 0usize;
        let mut blocks = 0usize;
        for seg in &path {
            for (layer, &b) in seg.blocks.iter().enumerate() {
                self.alloc.retain(b);
                let e = self.entry(layer, slot);
                self.tables[e].push(b);
            }
            matched += seg.tokens;
            blocks += seg.blocks.len();
        }
        for layer in 0..self.n_layers {
            let e = self.entry(layer, slot);
            self.written[e] = matched;
        }
        self.prefix = Some(idx);
        PrefixMatch { tokens: matched, blocks }
    }

    /// Register `slot`'s first `tokens.len()` positions (a prefilled
    /// prompt) in the prefix index. Newly indexed chunks retain the
    /// slot's blocks (the index becomes a holder, so they outlive the
    /// slot); chunks already indexed are deduplicated. A no-op when the
    /// index is disabled.
    pub fn register_prefix(&mut self, slot: usize, tokens: &[i32]) {
        let Some(mut idx) = self.prefix.take() else { return };
        debug_assert!(
            tokens.is_empty() || self.written[self.entry(0, slot)] >= tokens.len(),
            "registering unwritten positions"
        );
        let n_chunks = tokens.len().div_ceil(self.block_tokens);
        let mut chunk_blocks = Vec::with_capacity(n_chunks);
        for ci in 0..n_chunks {
            let per_layer: Vec<u32> = (0..self.n_layers)
                .map(|l| self.tables[self.entry(l, slot)][ci])
                .collect();
            chunk_blocks.push(per_layer);
        }
        idx.register(tokens, &chunk_blocks, &mut self.alloc);
        self.prefix = Some(idx);
    }

    /// Forcibly evict up to `n` LRU index-only blocks (chaos injection:
    /// deterministic allocation pressure on the prefix cache). Returns
    /// how many blocks were actually freed.
    pub fn evict_cached(&mut self, n: usize) -> usize {
        let Some(mut idx) = self.prefix.take() else { return 0 };
        let mut freed = 0usize;
        while freed < n {
            match idx.evict_lru(&self.alloc) {
                Some(blocks) => {
                    for b in blocks {
                        if self.alloc.release(b) {
                            self.store.release_block(b);
                        }
                        self.evictions += 1;
                        freed += 1;
                    }
                }
                None => break,
            }
        }
        self.prefix = Some(idx);
        freed
    }

    /// Fused-dequant key gather: `scores[j] = q . K[layer, slot, head, j]`
    /// for `j in 0..n` (raw dot products — the caller applies its own
    /// softmax scale). `n` must not exceed the written count.
    pub fn key_scores(
        &self,
        layer: usize,
        slot: usize,
        head: usize,
        n: usize,
        q: &[f32],
        scores: &mut [f32],
    ) {
        let e = self.entry(layer, slot);
        assert!(n <= self.written[e], "key gather beyond written positions");
        let table = &self.tables[e];
        for (j, sc) in scores.iter_mut().enumerate().take(n) {
            let block = table[j / self.block_tokens];
            let ti = j % self.block_tokens;
            *sc = self.store.key_dot(block, ti, layer, head, q);
        }
    }

    /// Fused-dequant value mix: `out[c] += w[j] * V[layer, slot, head, j][c]`
    /// for `j in 0..n`, accumulating in position order (bit-identical to
    /// the dense loop for FP32 payloads).
    pub fn value_mix(
        &self,
        layer: usize,
        slot: usize,
        head: usize,
        n: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        let e = self.entry(layer, slot);
        assert!(n <= self.written[e], "value gather beyond written positions");
        let table = &self.tables[e];
        for (j, &wj) in w.iter().enumerate().take(n) {
            let block = table[j / self.block_tokens];
            let ti = j % self.block_tokens;
            self.store.value_mix_into(block, ti, layer, head, wj, out);
        }
    }

    /// Dequantize one written position into head-major `n_heads * head_dim`
    /// rows (dense materialization and tests).
    pub fn read_row(
        &self,
        layer: usize,
        slot: usize,
        pos: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let e = self.entry(layer, slot);
        assert!(pos < self.written[e], "read of unwritten position {pos}");
        let block = self.tables[e][pos / self.block_tokens];
        let ti = pos % self.block_tokens;
        self.store.read_token(block, ti, layer, k_out, v_out);
    }

    /// Release every block of `slot` back to the free list — copy-free:
    /// no payload is touched. Unwritten (and now unmapped) positions
    /// materialize as zeros, so stale keys cannot leak into the slot's
    /// next tenant. Blocks aliased elsewhere (prefix index, other slots)
    /// only lose this slot's reference and stay live. Only the outlier
    /// *side table* of each actually-freed block is cleared (accounting
    /// metadata, not payload): otherwise `allocated_bytes`/`peak_bytes`
    /// would keep counting freed rows' FP-preserved channels.
    pub fn release(&mut self, slot: usize) {
        for layer in 0..self.n_layers {
            let e = self.entry(layer, slot);
            let blocks = std::mem::take(&mut self.tables[e]);
            for id in blocks {
                if self.alloc.release(id) {
                    self.store.release_block(id);
                }
            }
            self.written[e] = 0;
        }
    }

    /// Roll back `slot` to `new_len` written positions (speculative-decode
    /// rejection): per layer, pop every table block that lies entirely
    /// beyond the new length and release that reference. Truncation only
    /// drops references — no payload is ever written — so a block shared
    /// with the prefix index or another slot survives untouched, and a
    /// later re-append into a retained aliased partial block fires the
    /// ordinary copy-on-write in [`append`](Self::append). Stale rows
    /// beyond `new_len` in the retained tail block are unreachable (every
    /// read asserts against the written count) and are overwritten by the
    /// next append. `new_len` must not exceed the written count.
    pub fn truncate(&mut self, slot: usize, new_len: usize) -> Result<(), String> {
        if slot >= self.n_slots {
            return Err(format!("truncate out of range: slot {slot}"));
        }
        for layer in 0..self.n_layers {
            let e = self.entry(layer, slot);
            if new_len > self.written[e] {
                return Err(format!(
                    "truncate to {new_len} beyond written {} (layer {layer})",
                    self.written[e]
                ));
            }
        }
        let keep = new_len.div_ceil(self.block_tokens);
        for layer in 0..self.n_layers {
            let e = self.entry(layer, slot);
            while self.tables[e].len() > keep {
                let id = self.tables[e].pop().expect("table longer than keep");
                if self.alloc.release(id) {
                    self.store.release_block(id);
                }
            }
            self.written[e] = new_len;
        }
        Ok(())
    }

    /// Materialize the dense `(L, B, H, S, hd)` cache pair, zeros at
    /// unwritten positions (the PJRT artifact contract). The buffers are
    /// zeroed here, so reused scratch space can never leak a released
    /// slot's stale rows into the dense view.
    pub fn fill_dense(&self, k_out: &mut [f32], v_out: &mut [f32]) {
        let (h, hd, s) = (self.n_heads, self.head_dim, self.seq_len);
        let total = self.n_layers * self.n_slots * h * s * hd;
        assert!(k_out.len() == total && v_out.len() == total, "dense size mismatch");
        k_out.fill(0.0);
        v_out.fill(0.0);
        let mut krow = vec![0f32; h * hd];
        let mut vrow = vec![0f32; h * hd];
        for slot in 0..self.n_slots {
            for layer in 0..self.n_layers {
                for pos in 0..self.written(layer, slot) {
                    self.read_row(layer, slot, pos, &mut krow, &mut vrow);
                    for head in 0..h {
                        let dst =
                            ((layer * self.n_slots + slot) * h + head) * s * hd + pos * hd;
                        k_out[dst..dst + hd]
                            .copy_from_slice(&krow[head * hd..(head + 1) * hd]);
                        v_out[dst..dst + hd]
                            .copy_from_slice(&vrow[head * hd..(head + 1) * hd]);
                    }
                }
            }
        }
    }

    /// Fixed bytes per block (K + V payloads; excludes the outlier side
    /// table, which is accounted separately).
    fn block_bytes(&self) -> usize {
        let rows = self.block_tokens * self.n_heads;
        match &self.store {
            Store::Fp32(_) => 2 * rows * self.head_dim * 4,
            Store::Quant(s) => 2 * rows * (s.row_bytes + 4),
        }
    }

    /// Live outlier side-table bytes — O(1) via the store's running
    /// counter (this sits on the engine's per-step stats path).
    fn outlier_bytes(&self) -> usize {
        match &self.store {
            Store::Fp32(_) => 0,
            Store::Quant(s) => s.outlier_entries * OUTLIER_BYTES,
        }
    }

    /// Bytes currently assigned to live blocks.
    pub fn allocated_bytes(&self) -> usize {
        self.alloc.in_use() * self.block_bytes() + self.outlier_bytes()
    }

    /// High-water mark of reserved cache storage — monotone: block-pool
    /// growth is lazy (reflects actual peak usage, not the worst case)
    /// and the outlier term is its own tracked maximum.
    pub fn peak_bytes(&self) -> usize {
        let peak_outliers = match &self.store {
            Store::Fp32(_) => 0,
            Store::Quant(s) => s.peak_outlier_entries * OUTLIER_BYTES,
        };
        self.alloc.high_water() * self.block_bytes() + peak_outliers
    }

    /// Ideal storage bytes per appended token position across all layers,
    /// K + V (see the module docs for the formula).
    pub fn bytes_per_token(&self) -> f64 {
        let per_row = match &self.store {
            Store::Fp32(_) => (self.head_dim * 4) as f64,
            Store::Quant(s) => {
                (s.row_bytes + 4) as f64
                    + (s.quantizer.outliers_per_side() * 2 * OUTLIER_BYTES) as f64
            }
        };
        (self.n_layers * 2 * self.n_heads) as f64 * per_row
    }
}

impl Store {
    /// Grow backing pools so block `id` is addressable.
    fn ensure(&mut self, id: u32) {
        let n = id as usize + 1;
        match self {
            Store::Fp32(s) => {
                let elems = s.geom.block_tokens * s.geom.n_heads * s.geom.head_dim;
                s.k.resize(n * elems, 0.0);
                s.v.resize(n * elems, 0.0);
            }
            Store::Quant(s) => {
                let rows = s.geom.block_tokens * s.geom.n_heads;
                s.k_idx.resize(n * rows * s.row_bytes, 0);
                s.v_idx.resize(n * rows * s.row_bytes, 0);
                s.k_scale.resize(n * rows, 0.0);
                s.v_scale.resize(n * rows, 0.0);
                s.k_out.resize(n * rows, Vec::new());
                s.v_out.resize(n * rows, Vec::new());
            }
        }
    }

    /// Drop per-row accounting metadata of a freed block (outlier side
    /// table). Payloads are deliberately left as-is — release stays
    /// copy-free.
    fn release_block(&mut self, block: u32) {
        if let Store::Quant(s) = self {
            let rows = s.geom.block_tokens * s.geom.n_heads;
            let base = block as usize * rows;
            for row in base..base + rows {
                s.outlier_entries -= s.k_out[row].len() + s.v_out[row].len();
                s.k_out[row] = Vec::new();
                s.v_out[row] = Vec::new();
            }
        }
    }

    /// Copy token rows `[0, n_tok)` of every head (K and V payloads,
    /// scales, and outlier side tables) from block `src` to block `dst` —
    /// the copy half of copy-on-write. Rows of one head are contiguous
    /// across token index, so each head is one `copy_within`.
    fn copy_rows(&mut self, src: u32, dst: u32, n_tok: usize) {
        match self {
            Store::Fp32(s) => {
                let hd = s.geom.head_dim;
                for head in 0..s.geom.n_heads {
                    let a = s.geom.row(src, head, 0) * hd;
                    let b = s.geom.row(dst, head, 0) * hd;
                    let len = n_tok * hd;
                    s.k.copy_within(a..a + len, b);
                    s.v.copy_within(a..a + len, b);
                }
            }
            Store::Quant(s) => {
                let rb = s.row_bytes;
                for head in 0..s.geom.n_heads {
                    let ra = s.geom.row(src, head, 0);
                    let rd = s.geom.row(dst, head, 0);
                    s.k_idx.copy_within(ra * rb..(ra + n_tok) * rb, rd * rb);
                    s.v_idx.copy_within(ra * rb..(ra + n_tok) * rb, rd * rb);
                    s.k_scale.copy_within(ra..ra + n_tok, rd);
                    s.v_scale.copy_within(ra..ra + n_tok, rd);
                    for t in 0..n_tok {
                        let (a, b) = (ra + t, rd + t);
                        let ko = s.k_out[a].clone();
                        let vo = s.v_out[a].clone();
                        let old = s.k_out[b].len() + s.v_out[b].len();
                        s.outlier_entries =
                            s.outlier_entries + ko.len() + vo.len() - old;
                        s.peak_outlier_entries =
                            s.peak_outlier_entries.max(s.outlier_entries);
                        s.k_out[b] = ko;
                        s.v_out[b] = vo;
                    }
                }
            }
        }
    }

    fn write_token(&mut self, block: u32, ti: usize, layer: usize, k_row: &[f32], v_row: &[f32]) {
        match self {
            Store::Fp32(s) => {
                let hd = s.geom.head_dim;
                for head in 0..s.geom.n_heads {
                    let off = s.geom.row(block, head, ti) * hd;
                    s.k[off..off + hd].copy_from_slice(&k_row[head * hd..(head + 1) * hd]);
                    s.v[off..off + hd].copy_from_slice(&v_row[head * hd..(head + 1) * hd]);
                }
            }
            Store::Quant(s) => {
                // quantize straight into the pooled slices — no per-row
                // allocation on the decode-hot write path
                let hd = s.geom.head_dim;
                for head in 0..s.geom.n_heads {
                    let row = s.geom.row(block, head, ti);
                    let (k_scale, k_outs) = s.quantizer.quantize_row_into(
                        layer,
                        head,
                        KvSide::Key,
                        &k_row[head * hd..(head + 1) * hd],
                        &mut s.k_idx[row * s.row_bytes..(row + 1) * s.row_bytes],
                    );
                    let (v_scale, v_outs) = s.quantizer.quantize_row_into(
                        layer,
                        head,
                        KvSide::Val,
                        &v_row[head * hd..(head + 1) * hd],
                        &mut s.v_idx[row * s.row_bytes..(row + 1) * s.row_bytes],
                    );
                    s.k_scale[row] = k_scale;
                    s.v_scale[row] = v_scale;
                    let old = s.k_out[row].len() + s.v_out[row].len();
                    s.k_out[row] = k_outs;
                    s.v_out[row] = v_outs;
                    s.outlier_entries = s.outlier_entries + s.k_out[row].len()
                        + s.v_out[row].len()
                        - old;
                    s.peak_outlier_entries =
                        s.peak_outlier_entries.max(s.outlier_entries);
                }
            }
        }
    }

    fn read_token(&self, block: u32, ti: usize, layer: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        match self {
            Store::Fp32(s) => {
                let hd = s.geom.head_dim;
                for head in 0..s.geom.n_heads {
                    let off = s.geom.row(block, head, ti) * hd;
                    k_out[head * hd..(head + 1) * hd].copy_from_slice(&s.k[off..off + hd]);
                    v_out[head * hd..(head + 1) * hd].copy_from_slice(&s.v[off..off + hd]);
                }
            }
            Store::Quant(s) => {
                let hd = s.geom.head_dim;
                let bits = s.quantizer.bits();
                for head in 0..s.geom.n_heads {
                    let row = s.geom.row(block, head, ti);
                    let kb = s.quantizer.book(layer, head, KvSide::Key);
                    let vb = s.quantizer.book(layer, head, KvSide::Val);
                    let kbytes = &s.k_idx[row * s.row_bytes..(row + 1) * s.row_bytes];
                    let vbytes = &s.v_idx[row * s.row_bytes..(row + 1) * s.row_bytes];
                    let ko = &mut k_out[head * hd..(head + 1) * hd];
                    let vo = &mut v_out[head * hd..(head + 1) * hd];
                    for (ch, o) in ko.iter_mut().enumerate() {
                        *o = kb.value(read_idx(kbytes, bits, ch)) * s.k_scale[row];
                    }
                    for (ch, o) in vo.iter_mut().enumerate() {
                        *o = vb.value(read_idx(vbytes, bits, ch)) * s.v_scale[row];
                    }
                    for &(c, val) in &s.k_out[row] {
                        ko[c as usize] = val;
                    }
                    for &(c, val) in &s.v_out[row] {
                        vo[c as usize] = val;
                    }
                }
            }
        }
    }

    /// `q . K[block, head, ti]` with dequant fused into the dot loop.
    fn key_dot(&self, block: u32, ti: usize, layer: usize, head: usize, q: &[f32]) -> f32 {
        match self {
            // identical accumulation to `dot(q, &cache[off..off+hd])` in
            // the dense attention loop this replaced (bit-exactness)
            Store::Fp32(s) => {
                let hd = s.geom.head_dim;
                let off = s.geom.row(block, head, ti) * hd;
                q.iter()
                    .zip(&s.k[off..off + hd])
                    .map(|(&x, &y)| x * y)
                    .sum()
            }
            Store::Quant(s) => {
                let row = s.geom.row(block, head, ti);
                let book = s.quantizer.book(layer, head, KvSide::Key);
                let bytes = &s.k_idx[row * s.row_bytes..(row + 1) * s.row_bytes];
                let scale = s.k_scale[row];
                let bits = s.quantizer.bits();
                let mut acc = 0f32;
                for (ch, &qv) in q.iter().enumerate() {
                    acc += qv * book.value(read_idx(bytes, bits, ch)) * scale;
                }
                for &(c, val) in &s.k_out[row] {
                    let base = book.value(read_idx(bytes, bits, c as usize)) * scale;
                    acc += q[c as usize] * (val - base);
                }
                acc
            }
        }
    }

    /// `out[c] += w * V[block, head, ti][c]` with dequant fused in.
    fn value_mix_into(
        &self,
        block: u32,
        ti: usize,
        layer: usize,
        head: usize,
        w: f32,
        out: &mut [f32],
    ) {
        match self {
            // identical accumulation to the dense `*o += wn * vv` loop
            Store::Fp32(s) => {
                let hd = s.geom.head_dim;
                let off = s.geom.row(block, head, ti) * hd;
                for (o, &vv) in out.iter_mut().zip(&s.v[off..off + hd]) {
                    *o += w * vv;
                }
            }
            Store::Quant(s) => {
                let row = s.geom.row(block, head, ti);
                let book = s.quantizer.book(layer, head, KvSide::Val);
                let bytes = &s.v_idx[row * s.row_bytes..(row + 1) * s.row_bytes];
                let scale = s.v_scale[row];
                let bits = s.quantizer.bits();
                for (ch, o) in out.iter_mut().enumerate() {
                    *o += w * book.value(read_idx(bytes, bits, ch)) * scale;
                }
                for &(c, val) in &s.v_out[row] {
                    let base = book.value(read_idx(bytes, bits, c as usize)) * scale;
                    out[c as usize] += w * (val - base);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            seq_len: 40, // > one block: exercises block-boundary crossing
            batch: 1,
            decode_batch: 2,
            head_dim: 16,
            d_ff: 64,
            n_linears: 8,
        }
    }

    fn rand_row(rng: &mut Rng, d: usize) -> Vec<f32> {
        rng.normal_vec(d, 1.0)
    }

    #[test]
    fn fp32_gather_is_bit_exact_with_dense_reference() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut cache = PagedKvCache::new(&m, KvPrecision::Fp32);
        let mut rng = Rng::new(1);
        let n = 37; // crosses into the third block
        let mut dense_k: Vec<Vec<f32>> = Vec::new();
        let mut dense_v: Vec<Vec<f32>> = Vec::new();
        for pos in 0..n {
            let (kr, vr) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
            cache.append(1, 0, pos, &kr, &vr).unwrap();
            dense_k.push(kr);
            dense_v.push(vr);
        }
        let q = rand_row(&mut rng, m.head_dim);
        let w: Vec<f32> = (0..n).map(|i| 1.0 / (i as f32 + 2.0)).collect();
        let hd = m.head_dim;
        for head in 0..m.n_heads {
            let mut scores = vec![0f32; n];
            cache.key_scores(1, 0, head, n, &q, &mut scores);
            let mut out = vec![0f32; hd];
            cache.value_mix(1, 0, head, n, &w, &mut out);
            let mut want_out = vec![0f32; hd];
            for (j, sc) in scores.iter().enumerate() {
                let krow = &dense_k[j][head * hd..(head + 1) * hd];
                let want: f32 = q.iter().zip(krow).map(|(&x, &y)| x * y).sum();
                assert_eq!(*sc, want, "head {head} pos {j}");
                let vrow = &dense_v[j][head * hd..(head + 1) * hd];
                for (o, &vv) in want_out.iter_mut().zip(vrow) {
                    *o += w[j] * vv;
                }
            }
            assert_eq!(out, want_out, "head {head} value mix");
        }
    }

    #[test]
    fn append_protocol_enforced() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut cache = PagedKvCache::new(&m, KvPrecision::Fp32);
        let row = vec![1.0f32; d];
        assert!(cache.append(0, 0, 1, &row, &row).is_err(), "out of order");
        cache.append(0, 0, 0, &row, &row).unwrap();
        assert!(cache.append(0, 0, 0, &row, &row).is_err(), "rewind");
        assert!(cache.append(0, 0, m.seq_len, &row, &row).is_err(), "beyond ctx");
        assert!(cache.append(0, 0, 1, &row[..d - 1], &row).is_err(), "short row");
        assert_eq!(cache.written(0, 0), 1);
        assert_eq!(cache.slot_blocks(0, 0).len(), 1);
    }

    #[test]
    fn release_is_copy_free_and_reuse_never_leaks_stale_rows() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut cache = PagedKvCache::new(&m, KvPrecision::Fp32);
        let hot = vec![7.5f32; d];
        for pos in 0..20 {
            cache.append(0, 0, pos, &hot, &hot).unwrap();
        }
        cache.release(0);
        assert_eq!(cache.in_use_blocks(), 0);
        assert_eq!(cache.written(0, 0), 0);
        // new tenant writes 3 positions into a reused block; dense
        // materialization must show zeros beyond them
        let cold = vec![-1.0f32; d];
        for pos in 0..3 {
            cache.append(0, 0, pos, &cold, &cold).unwrap();
        }
        let total = m.n_layers * m.decode_batch * m.n_heads * m.seq_len * m.head_dim;
        let mut kd = vec![0f32; total];
        let mut vd = vec![0f32; total];
        cache.fill_dense(&mut kd, &mut vd);
        assert!(!kd.iter().any(|&x| x == 7.5), "stale key leaked");
        assert_eq!(kd.iter().filter(|&&x| x == -1.0).count(), 3 * d);
    }

    #[test]
    fn quantized_roundtrip_close_and_bytes_ratio_holds() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut rng = Rng::new(5);
        let fp = PagedKvCache::new(&m, KvPrecision::Fp32);
        for bits in [4u32, 3, 2] {
            let quant = KvQuantizer::uniform(m.n_layers, m.n_heads, m.head_dim, bits);
            let mut cache = PagedKvCache::new(&m, KvPrecision::Quant(quant));
            assert_eq!(cache.bits(), bits);
            let n = 20;
            let mut rows = Vec::new();
            for pos in 0..n {
                let (kr, vr) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
                cache.append(0, 1, pos, &kr, &vr).unwrap();
                rows.push((kr, vr));
            }
            let mut kout = vec![0f32; d];
            let mut vout = vec![0f32; d];
            let tol = 2.0 / (1u32 << bits) as f32 + 1e-5; // one scaled cell
            for (pos, (kr, vr)) in rows.iter().enumerate() {
                cache.read_row(0, 1, pos, &mut kout, &mut vout);
                let kmax = kr.iter().fold(0f32, |a, &x| a.max(x.abs()));
                let vmax = vr.iter().fold(0f32, |a, &x| a.max(x.abs()));
                for (a, b) in kr.iter().zip(&kout) {
                    assert!((a - b).abs() <= tol * kmax, "bits {bits} K row {pos}");
                }
                for (a, b) in vr.iter().zip(&vout) {
                    assert!((a - b).abs() <= tol * vmax, "bits {bits} V row {pos}");
                }
            }
            // the 4x memory target: >= 4x lower bytes/token than FP32
            assert!(
                fp.bytes_per_token() >= 4.0 * cache.bytes_per_token(),
                "bits {bits}: {} vs fp32 {}",
                cache.bytes_per_token(),
                fp.bytes_per_token()
            );
            assert!(cache.peak_bytes() > 0);
            assert!(cache.allocated_bytes() <= cache.peak_bytes());
        }
    }

    #[test]
    fn quantized_gather_matches_read_row_reference() {
        // key_scores / value_mix must agree with dot/mix over read_row's
        // dequantized rows (same math, fused vs materialized)
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut rng = Rng::new(6);
        let quant =
            KvQuantizer::uniform(m.n_layers, m.n_heads, m.head_dim, 4).with_outliers(1);
        let mut cache = PagedKvCache::new(&m, KvPrecision::Quant(quant));
        let n = 19;
        for pos in 0..n {
            let mut kr = rand_row(&mut rng, d);
            kr[3] = 25.0; // planted outlier exercises the escape hatch
            let vr = rand_row(&mut rng, d);
            cache.append(1, 0, pos, &kr, &vr).unwrap();
        }
        let q = rand_row(&mut rng, m.head_dim);
        let w: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).sin()).collect();
        let hd = m.head_dim;
        let (mut kout, mut vout) = (vec![0f32; d], vec![0f32; d]);
        for head in 0..m.n_heads {
            let mut scores = vec![0f32; n];
            cache.key_scores(1, 0, head, n, &q, &mut scores);
            let mut mixed = vec![0f32; hd];
            cache.value_mix(1, 0, head, n, &w, &mut mixed);
            let mut want_mix = vec![0f32; hd];
            for (j, sc) in scores.iter().enumerate() {
                cache.read_row(1, 0, j, &mut kout, &mut vout);
                let want: f32 = q
                    .iter()
                    .zip(&kout[head * hd..(head + 1) * hd])
                    .map(|(&x, &y)| x * y)
                    .sum();
                assert!((sc - want).abs() < 1e-4, "head {head} pos {j}: {sc} vs {want}");
                for (o, &vv) in want_mix.iter_mut().zip(&vout[head * hd..(head + 1) * hd]) {
                    *o += w[j] * vv;
                }
            }
            for (a, b) in mixed.iter().zip(&want_mix) {
                assert!((a - b).abs() < 1e-4, "head {head} mix");
            }
        }
    }

    #[test]
    fn release_clears_outlier_accounting() {
        // regression: freed slots' FP-preserved channels must not keep
        // inflating allocated/peak bytes
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let quant =
            KvQuantizer::uniform(m.n_layers, m.n_heads, m.head_dim, 4).with_outliers(2);
        let mut cache = PagedKvCache::new(&m, KvPrecision::Quant(quant));
        let mut rng = Rng::new(8);
        for pos in 0..10 {
            let (kr, vr) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
            cache.append(0, 0, pos, &kr, &vr).unwrap();
        }
        let with_outliers = cache.allocated_bytes();
        let pool_only = cache.in_use_blocks() * 2 * 16 * m.n_heads * (8 + 4);
        assert!(with_outliers > pool_only, "hatch produced no outliers");
        assert_eq!(cache.peak_bytes(), with_outliers);
        cache.release(0);
        assert_eq!(cache.in_use_blocks(), 0);
        assert_eq!(cache.allocated_bytes(), 0, "freed outliers still counted");
        // peak is a true high-water mark: it neither shrinks on release
        // nor keeps counting freed rows as live
        assert_eq!(cache.peak_bytes(), with_outliers);
    }

    #[test]
    fn truncate_pops_tail_blocks_and_reopens_append() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut cache = PagedKvCache::new(&m, KvPrecision::Fp32);
        let mut rng = Rng::new(3);
        let mut rows = Vec::new();
        for pos in 0..37 {
            let (kr, vr) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
            for layer in 0..m.n_layers {
                cache.append(layer, 0, pos, &kr, &vr).unwrap();
            }
            rows.push((kr, vr));
        }
        assert_eq!(cache.slot_blocks(0, 0).len(), 3);
        // beyond-written truncation is an error, state untouched
        assert!(cache.truncate(0, 38).is_err());
        assert_eq!(cache.written(0, 0), 37);
        // mid-block rollback: 20 positions keep ceil(20/16) = 2 blocks
        cache.truncate(0, 20).unwrap();
        for layer in 0..m.n_layers {
            assert_eq!(cache.written(layer, 0), 20);
            assert_eq!(cache.slot_blocks(layer, 0).len(), 2);
        }
        assert_eq!(cache.in_use_blocks(), 2 * m.n_layers);
        // surviving rows are untouched by the rollback
        let (mut kout, mut vout) = (vec![0f32; d], vec![0f32; d]);
        for pos in 0..20 {
            cache.read_row(0, 0, pos, &mut kout, &mut vout);
            assert_eq!(kout, rows[pos].0, "pos {pos}");
        }
        // append-only protocol resumes at the truncated length
        assert!(cache.append(0, 0, 21, &rows[0].0, &rows[0].1).is_err());
        cache.append(0, 0, 20, &rows[0].0, &rows[0].1).unwrap();
        assert_eq!(cache.written(0, 0), 21);
        // truncate-to-zero behaves like release
        cache.truncate(0, 0).unwrap();
        assert_eq!(cache.in_use_blocks(), 0);
    }

    #[test]
    fn truncate_never_mutates_shared_prefix_blocks() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut cache = PagedKvCache::new_with_prefix(&m, KvPrecision::Fp32, true);
        let mut rng = Rng::new(4);
        let prompt: Vec<i32> = (0..32).collect();
        let mut rows = Vec::new();
        for pos in 0..prompt.len() {
            let (kr, vr) = (rand_row(&mut rng, d), rand_row(&mut rng, d));
            for layer in 0..m.n_layers {
                cache.append(layer, 0, pos, &kr, &vr).unwrap();
            }
            rows.push((kr, vr));
        }
        cache.register_prefix(0, &prompt);
        // slot 1 aliases both shared blocks (the second partially: the
        // match is capped at plen - 1 so one token always computes); each
        // is now held by slot 0, the index, and slot 1
        let matched = cache.admit_prefix(1, &prompt, prompt.len() - 1);
        assert_eq!(matched.tokens, 31);
        let shared: Vec<u32> = cache.slot_blocks(0, 1).to_vec();
        assert_eq!(shared.len(), 2);
        for &b in &shared {
            assert_eq!(cache.block_ref_count(b), 3);
        }
        // speculative rollback into the shared region: only this slot's
        // references drop; the index keeps the blocks and their payloads
        cache.truncate(1, 10).unwrap();
        assert_eq!(cache.slot_blocks(0, 1), &shared[..1]);
        assert_eq!(cache.block_ref_count(shared[1]), 2, "slot 0 + index hold it");
        let (mut kout, mut vout) = (vec![0f32; d], vec![0f32; d]);
        for pos in 0..prompt.len() {
            cache.read_row(0, 0, pos, &mut kout, &mut vout);
            assert_eq!(kout, rows[pos].0, "shared payload mutated at {pos}");
        }
        // re-append at the truncated position copy-on-writes off the
        // still-aliased partial block instead of corrupting it
        for layer in 0..m.n_layers {
            cache.append(layer, 1, 10, &rows[0].0, &rows[0].1).unwrap();
        }
        cache.read_row(0, 0, 10, &mut kout, &mut vout);
        assert_eq!(kout, rows[10].0, "COW failed: shared row overwritten");
        cache.release(1);
        cache.release(0);
        cache.evict_cached(usize::MAX);
        assert_eq!(cache.in_use_blocks(), 0, "rollback leaked blocks");
    }

    #[test]
    fn pool_capacity_covers_full_occupancy() {
        let m = cfg();
        let d = m.n_heads * m.head_dim;
        let mut cache = PagedKvCache::new(&m, KvPrecision::Fp32);
        let row = vec![0.5f32; d];
        for slot in 0..m.decode_batch {
            for layer in 0..m.n_layers {
                for pos in 0..m.seq_len {
                    cache.append(layer, slot, pos, &row, &row).unwrap();
                }
            }
        }
        assert_eq!(cache.in_use_blocks(), cache.capacity_blocks());
    }
}
