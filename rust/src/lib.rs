//! KLLM/OASIS: outlier-aware LUT-based GEMM with dual-side K-Means
//! quantization — a three-layer Rust + JAX + Pallas reproduction.
//!
//! Layer map (see DESIGN.md):
//! * L3 (this crate): quantization algorithms, the bit-exact OASIS datapath
//!   model, the Orizuru top-k engine, a cycle-level accelerator simulator
//!   with baselines, the PJRT runtime, and the serving coordinator.
//! * L2/L1 (python/, build-time only): the JAX transformer + Pallas WAQ
//!   LUT-GEMM kernels, AOT-lowered to `artifacts/<preset>/*.hlo.txt`.

pub mod util;
pub mod tensor;
pub mod quant;
pub mod gemm;
pub mod orizuru;
pub mod models;
pub mod sim;
pub mod baselines;
pub mod runtime;
pub mod kvcache;
pub mod coordinator;
pub mod eval;
