//! Tiny CLI argument parser (no clap offline): subcommand + `--key value` /
//! `--flag` options with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse() -> Result<Args, String> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Reject unknown options: call with the full allowlist once parsing is
    /// done so typos fail loudly instead of being ignored.
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                return Err(format!("unknown option --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_opts() {
        let a = parse(&["experiment", "fig11", "--preset", "test", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig11"]);
        assert_eq!(a.opt("preset"), Some("test"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn eq_form_and_typed() {
        let a = parse(&["x", "--steps=300", "--lr", "0.003"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 300);
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.003).abs() < 1e-12);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn typo_detection() {
        let a = parse(&["x", "--stpes", "3"]);
        assert!(a.check_known(&["steps"]).is_err());
        assert!(a.check_known(&["stpes"]).is_ok());
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["x", "--lo", "-1.5"]);
        assert_eq!(a.f64_or("lo", 0.0).unwrap(), -1.5);
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["x", "--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }
}
