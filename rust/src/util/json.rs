//! Minimal JSON parser + writer (no serde offline).
//!
//! The parser exists to read `artifacts/<preset>/manifest.json` emitted by
//! aot.py; the writer serializes experiment results. It supports the full
//! JSON grammar minus exotic number forms (handles ints, floats, exponents)
//! and decodes the common escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_list(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
    }

    // -- writer ---------------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |o: &mut String, n: usize| {
            if pretty {
                o.push('\n');
                o.push_str(&" ".repeat(n));
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Escape `s` as a complete JSON string literal (including the quotes).
/// The single escaping implementation for every hand-rolled JSON reply in
/// the repo — interpolating raw strings into JSON (e.g. error messages
/// containing `"` or `\`) produces malformed output; use this instead.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    write_escaped(&mut out, s);
    out
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("short \\u")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of unescaped bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("bad array sep {:?}", other.map(|c| c as char))),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("bad object sep {:?}", other.map(|c| c as char))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_produces_parseable_literals() {
        for s in [
            "plain",
            "with \"quotes\" and \\backslash\\",
            "newline\nand\ttab\rand\u{1}control",
            "", // empty string still gets quotes
        ] {
            let lit = escape(s);
            let back = Json::parse(&lit).expect("escaped literal must parse");
            assert_eq!(back.as_str(), Some(s), "roundtrip of {s:?}");
        }
        assert_eq!(escape("a\"b"), "\"a\\\"b\"");
    }

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"preset":"test","config":{"d_model":64,"n_layers":2},
            "params":[{"name":"tok_emb","shape":[256,64]}],
            "ok":true,"none":null,"fr":0.01}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("preset").unwrap().as_str(), Some("test"));
        assert_eq!(
            j.get("config").unwrap().get("d_model").unwrap().as_usize(),
            Some(64)
        );
        let shape = j.get("params").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .usize_list()
            .unwrap();
        assert_eq!(shape, vec![256, 64]);
        assert_eq!(j.get("fr").unwrap().as_f64(), Some(0.01));
        // round-trip through the writer
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }
}
