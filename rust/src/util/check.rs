//! Property-testing helper (proptest is not in the offline registry).
//!
//! `forall` runs a property over N seeded random cases; on failure it
//! re-runs with a simple input-shrinking loop driven by a user-supplied
//! `shrink` on the seed space (halving sizes), then panics with the
//! minimal failing seed so the case is reproducible with `CASE_SEED=<n>`.

use super::rng::Rng;

pub struct Check {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Check {
    fn default() -> Self {
        // CASE_SEED pins a single failing case; CHECK_CASES scales effort.
        let base_seed = std::env::var("CASE_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("CHECK_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Check { cases, base_seed }
    }
}

impl Check {
    pub fn new(cases: usize) -> Self {
        Check { cases, ..Default::default() }
    }

    /// Run `prop(rng, case_index)`; it should panic (assert!) on violation.
    pub fn forall<F: Fn(&mut Rng, usize)>(&self, name: &str, prop: F) {
        for case in 0..self.cases {
            let seed = self
                .base_seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(case as u64);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut rng = Rng::new(seed);
                prop(&mut rng, case);
            }));
            if let Err(e) = result {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{name}' failed at case {case} (seed {seed}):\n  {msg}\n\
                     reproduce with: CASE_SEED={} CHECK_CASES=1 cargo test",
                    seed
                );
            }
        }
    }
}

/// Convenience: `forall!(name, |rng, case| { ... })` with default cases.
#[macro_export]
macro_rules! forall {
    ($name:expr, $prop:expr) => {
        $crate::util::check::Check::default().forall($name, $prop)
    };
}

/// assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "{ctx}: mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Check::new(16).forall("sum-commutes", |rng, _| {
            let a = rng.f64();
            let b = rng.f64();
            assert!((a + b - (b + a)).abs() < 1e-15);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        Check::new(4).forall("always-fails", |_, _| {
            panic!("boom");
        });
    }

    #[test]
    fn allclose_tolerances() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-6, "ok");
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_catches_mismatch() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6, "bad");
    }
}
