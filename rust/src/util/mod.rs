//! Infrastructure substrates built in-tree (the offline registry only
//! carries the `xla` crate's dependency closure, so there is no clap /
//! serde / rand / criterion / proptest — each has a purpose-sized
//! replacement here).

pub mod bench;
pub mod check;
pub mod cli;
pub mod config;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
