//! TOML-subset configuration loader (no `toml`/`serde` offline).
//!
//! Supports what the launcher needs: `[section]` headers, `key = value`
//! with string / integer / float / boolean / homogeneous-array values, `#`
//! comments, and dotted lookup (`server.port`). Used by `kllm serve
//! --config <file>` and the experiment harness; every typed accessor
//! reports the full dotted key on error.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    fn parse(raw: &str, line_no: usize) -> Result<Value, String> {
        let s = raw.trim();
        if s.is_empty() {
            return Err(format!("line {line_no}: empty value"));
        }
        if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
        }
        if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
            let mut items = Vec::new();
            if !inner.trim().is_empty() {
                for part in split_top_level(inner) {
                    items.push(Value::parse(&part, line_no)?);
                }
            }
            return Ok(Value::Arr(items));
        }
        match s {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        Err(format!("line {line_no}: cannot parse value '{s}'"))
    }
}

/// Split an array body at top-level commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut depth = 0usize;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {line_no}: expected 'key = value'"))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if values.contains_key(&key) {
                return Err(format!("line {line_no}: duplicate key '{key}'"));
            }
            values.insert(key, Value::parse(v, line_no)?);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.get(key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Int(i)) if *i >= 0 => Ok(*i as usize),
            Some(v) => Err(format!("{key}: expected non-negative int, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(v) => Err(format!("{key}: expected number, got {v:?}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Bool(b)) => Ok(*b),
            Some(v) => Err(format!("{key}: expected bool, got {v:?}")),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
preset = "gpt20m"

[server]
port = 7070            # TCP listener
max_batch = 4
target_util = 0.85
enable_tcp = true
quant = ["kmeans", "a4"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("preset", ""), "gpt20m");
        assert_eq!(c.usize_or("server.port", 0).unwrap(), 7070);
        assert_eq!(c.usize_or("server.max_batch", 0).unwrap(), 4);
        assert!((c.f64_or("server.target_util", 0.0).unwrap() - 0.85).abs() < 1e-12);
        assert!(c.bool_or("server.enable_tcp", false).unwrap());
        match c.get("server.quant").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_and_errors() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.usize_or("missing", 9).unwrap(), 9);
        assert!(c.f64_or("x", 0.0).unwrap() == 3.0);
        assert!(Config::parse("x = ").is_err());
        assert!(Config::parse("x = 1\nx = 2").is_err());
        assert!(Config::parse("just a line").is_err());
    }

    #[test]
    fn comments_inside_strings_survive() {
        let c = Config::parse("s = \"a # b\"").unwrap();
        assert_eq!(c.str_or("s", ""), "a # b");
    }
}
