//! Small statistics toolkit used by the experiment harness and the
//! coordinator's latency metrics: moments, percentiles, RMSE, histograms.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// Root-mean-square error between two equal-length slices
/// (used verbatim for the Fig 3 / Fig 5 threshold/centroid comparisons).
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "rmse length mismatch");
    if a.is_empty() {
        return 0.0;
    }
    let s: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();
    (s / a.len() as f64).sqrt()
}

/// Relative L2 error `||a - b|| / ||b||` (`b` is the reference). Used by
/// the KV-cache accuracy tests and the kv_cache bench so the tested and
/// the benchmarked metric are one definition.
pub fn rel_l2_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "rel_l2_err length mismatch");
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum();
    let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt()
}

/// Min-max normalize to [0, 1] (paper normalizes thresholds/centroids
/// before RMSE in Figs 3 and 5).
pub fn normalize01(xs: &[f32]) -> Vec<f32> {
    let (lo, hi) = min_max(xs);
    let span = (hi - lo).max(1e-12);
    xs.iter().map(|&x| (x - lo) / span).collect()
}

pub fn min_max(xs: &[f32]) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Percentile by linear interpolation on a *sorted* slice, q in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Online latency accumulator (p50/p95/p99/mean/max) for the coordinator.
#[derive(Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<f64>,
}

impl LatencyStats {
    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn summary(&self) -> LatencySummary {
        let mut s = self.samples_us.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            count: s.len(),
            mean_us: if s.is_empty() { 0.0 } else { s.iter().sum::<f64>() / s.len() as f64 },
            p50_us: percentile_sorted(&s, 50.0),
            p95_us: percentile_sorted(&s, 95.0),
            p99_us: percentile_sorted(&s, 99.0),
            max_us: s.last().copied().unwrap_or(0.0),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

impl std::fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
            self.count, self.mean_us, self.p50_us, self.p95_us, self.p99_us, self.max_us
        )
    }
}

/// Geometric mean of ratios (used for the "average speedup" rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_zero_for_identical() {
        let a = [1.0f32, 2.0, 3.0];
        assert_eq!(rmse(&a, &a), 0.0);
    }

    #[test]
    fn rmse_known_value() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert!((rmse(&a, &b) - (12.5f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn normalize01_range() {
        let v = normalize01(&[2.0, 4.0, 6.0]);
        assert_eq!(v, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&s, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 100.0), 100.0);
    }

    #[test]
    fn latency_summary_monotone() {
        let mut l = LatencyStats::default();
        for i in 0..1000 {
            l.record_us(i as f64);
        }
        let s = l.summary();
        assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.p99_us <= s.max_us);
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
