//! Minimal benchmarking harness (criterion is not in the offline registry).
//!
//! Benches under rust/benches/ use `harness = false` and drive this:
//! warmup, adaptive iteration count targeting a fixed measurement window,
//! and mean/p50/min reporting with a throughput hook. Also provides
//! `black_box` via `std::hint`.
//!
//! Results can additionally be appended as JSON lines to a repo-root file
//! (`Bencher::json` / `BenchResult::append_json`), so the perf trajectory
//! of the hot paths is tracked across PRs instead of only printed.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// Resolve a machine-readable results file at the repo root (one level
/// above this crate), e.g. `bench_json_path("BENCH_waq_gemm.json")`.
pub fn bench_json_path(file_name: &str) -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(file_name)
}

#[derive(Default)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    /// optional items/sec given a per-iteration item count
    pub throughput: Option<f64>,
    /// extra `(key, raw JSON value)` pairs appended to the JSON row —
    /// benches use this to tag rows with run parameters (e.g. `kv_bits`,
    /// `peak_kv_bytes`) without widening the core schema
    pub extra: Vec<(String, String)>,
}

impl BenchResult {
    pub fn report(&self) {
        let fmt = |ns: f64| -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} us", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        };
        let tp = self
            .throughput
            .map(|t| {
                if t > 1e9 {
                    format!("  {:.2} Gitem/s", t / 1e9)
                } else if t > 1e6 {
                    format!("  {:.2} Mitem/s", t / 1e6)
                } else {
                    format!("  {:.1} item/s", t)
                }
            })
            .unwrap_or_default();
        println!(
            "bench {:40} iters={:<7} mean={:>10}  p50={:>10}  min={:>10}{}",
            self.name,
            self.iters,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.min_ns),
            tp
        );
    }

    /// One JSON object (single line) with the machine-readable fields.
    pub fn json_line(&self) -> String {
        let name = json_escape(&self.name);
        let tp = match self.throughput {
            Some(t) => format!("{t:.3}"),
            None => "null".to_string(),
        };
        let mut line = format!(
            "{{\"name\": \"{name}\", \"iters\": {}, \"mean_ns\": {:.3}, \
             \"p50_ns\": {:.3}, \"min_ns\": {:.3}, \"throughput\": {tp}",
            self.iters, self.mean_ns, self.p50_ns, self.min_ns
        );
        for (k, v) in &self.extra {
            line.push_str(&format!(", \"{}\": {v}", json_escape(k)));
        }
        line.push('}');
        line
    }

    /// Append the JSON line to `path` (JSON-lines file; created if
    /// missing). IO failures are reported, never fatal to the bench.
    pub fn append_json(&self, path: &Path) {
        append_line(path, &self.json_line());
    }
}

/// Append one line to a JSON-lines results file (created if missing).
/// IO failures are reported, never fatal to the bench — shared by every
/// BENCH_*.json emitter so append semantics can't diverge.
fn append_line(path: &Path, line: &str) {
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = appended {
        eprintln!("bench: could not append to {}: {e}", path.display());
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// One BENCH_kv.json row: the KV-cache memory / accuracy / throughput
/// trade-off at one `--kv-bits` setting (emitted by the `kv_cache` bench
/// and smoke-run in CI, so the perf trajectory captures the memory axis).
pub struct KvBenchRow {
    /// serving backend tag (e.g. `native-packed`)
    pub backend: String,
    /// cache storage bits per element (32 = FP32)
    pub kv_bits: u32,
    /// ideal cache bytes per token position (all layers, K + V)
    pub bytes_per_token: f64,
    /// peak reserved cache bytes over the run
    pub peak_cache_bytes: u64,
    /// measured end-to-end decode throughput at this setting
    pub decode_tok_s: f64,
    /// relative error of one decode step's logits vs the FP32 cache
    /// (0.0 at 32 bits by construction)
    pub attn_rel_err: f64,
}

impl KvBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"backend\": \"{}\", \"kv_bits\": {}, \"bytes_per_token\": {:.3}, \
             \"peak_cache_bytes\": {}, \"decode_tok_s\": {:.3}, \"attn_rel_err\": {:.6}}}",
            json_escape(&self.backend),
            self.kv_bits,
            self.bytes_per_token,
            self.peak_cache_bytes,
            self.decode_tok_s,
            self.attn_rel_err
        )
    }

    /// Append to the repo-root BENCH_kv.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_kv.json"), &self.json_line());
    }
}

/// One BENCH_prefill.json row: the burst-admission prefill trade-off —
/// one admission burst prefilled either sequentially (one
/// `DecodeBackend::prefill` call per request) or batched (one
/// `prefill_batch` call for the whole burst). Emitted by the
/// `e2e_serving` bench's burst-admission sweep and smoke-run in CI.
///
/// Schema (JSON lines, one object per row):
///   `name`           `"prefill_burst/<backend>/<mode>"`
///   `backend`        serving backend tag (e.g. `native-packed`)
///   `mode`           `"sequential"` (N prefill calls) or `"batched"`
///                    (one prefill_batch call)
///   `burst`          requests prefilled in the burst
///   `prompt_tokens`  total prompt tokens across the burst
///   `host_waq_s`     measured WAQ-datapath seconds for the whole burst
///                    (sum of the per-request `StepCost::host_waq_s`)
///   `wall_s`         wall-clock seconds for the whole burst
///   `tok_s`          `prompt_tokens / wall_s`
///   `speedup_vs_sequential`  host-WAQ-seconds ratio sequential/batched
///                    for the same burst (1.0 on sequential rows)
pub struct PrefillBenchRow {
    pub name: String,
    pub backend: String,
    pub mode: String,
    pub burst: u32,
    pub prompt_tokens: u64,
    pub host_waq_s: f64,
    pub wall_s: f64,
    pub tok_s: f64,
    pub speedup_vs_sequential: f64,
}

impl PrefillBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"backend\": \"{}\", \"mode\": \"{}\", \"burst\": {}, \
             \"prompt_tokens\": {}, \"host_waq_s\": {:.6}, \"wall_s\": {:.6}, \
             \"tok_s\": {:.3}, \"speedup_vs_sequential\": {:.4}}}",
            json_escape(&self.name),
            json_escape(&self.backend),
            json_escape(&self.mode),
            self.burst,
            self.prompt_tokens,
            self.host_waq_s,
            self.wall_s,
            self.tok_s,
            self.speedup_vs_sequential
        )
    }

    /// Append to the repo-root BENCH_prefill.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_prefill.json"), &self.json_line());
    }
}

/// One BENCH_shard.json row: tensor-parallel shard scaling of the native
/// WAQ datapath (emitted by the `shard_scaling` bench; CI smoke-runs
/// shards {1, 4} under FAST_BENCH and fails the job when the
/// sharded-vs-unsharded parity or scaling-efficiency tripwires fire).
///
/// Schema (JSON lines, one object per row):
///   `name`          `"shard_scaling/gemm/<shape>"` (batched sharded GEMM)
///                   or `"shard_scaling/e2e/<preset>"` (engine decode
///                   through `--backend native-sharded`)
///   `shards`        column-shard count (1 = sharded datapath on a single
///                   worker, the scaling baseline)
///   `tok_s`         measured tokens/sec through that datapath
///   `mean_ns`       mean ns per GEMM call (gemm rows) / per generated
///                   token (e2e rows)
///   `speedup_vs_1`  best-time ratio t(1) / t(shards), same workload
///   `efficiency`    `speedup_vs_1 / shards` (1.0 = perfect linear
///                   scaling of the column split)
pub struct ShardBenchRow {
    pub name: String,
    pub shards: u32,
    pub tok_s: f64,
    pub mean_ns: f64,
    pub speedup_vs_1: f64,
    pub efficiency: f64,
}

impl ShardBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"shards\": {}, \"tok_s\": {:.3}, \"mean_ns\": {:.3}, \
             \"speedup_vs_1\": {:.4}, \"efficiency\": {:.4}}}",
            json_escape(&self.name),
            self.shards,
            self.tok_s,
            self.mean_ns,
            self.speedup_vs_1,
            self.efficiency
        )
    }

    /// Append to the repo-root BENCH_shard.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_shard.json"), &self.json_line());
    }
}

/// One BENCH_soak.json row: robustness envelope of the serving stack under
/// a heavy-tailed multi-client trace with chaos faults enabled (emitted by
/// the `soak` bench and smoke-run in CI under FAST_BENCH). Every submitted
/// request must resolve to exactly one terminal response — the row records
/// how they resolved and what the tail latency of admission looked like.
///
/// Schema (JSON lines, one object per row):
///   `name`              `"soak/<backend>/<phase>"` (`inproc` or `tcp`)
///   `backend`           serving backend tag (e.g. `native-packed`)
///   `requests`          total requests submitted over the trace
///   `completed`         finished naturally (max_tokens / eos / length)
///   `rejected`          refused at admission (queue cap / drain)
///   `expired`           deadline-expired (in queue or mid-decode)
///   `aborted`           terminated by fault containment or shutdown
///   `p50_queue_wait_s`  median admission wait across terminal responses
///   `p99_queue_wait_s`  p99 admission wait across terminal responses
///   `drain_s`           wall seconds for the final graceful drain
///   `chaos_rate`        injected fault rate (0.0 = chaos disabled)
///   `chaos_seed`        chaos RNG seed (reproduces the fault pattern)
pub struct SoakBenchRow {
    pub name: String,
    pub backend: String,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub aborted: u64,
    pub p50_queue_wait_s: f64,
    pub p99_queue_wait_s: f64,
    pub drain_s: f64,
    pub chaos_rate: f64,
    pub chaos_seed: u64,
}

impl SoakBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"backend\": \"{}\", \"requests\": {}, \
             \"completed\": {}, \"rejected\": {}, \"expired\": {}, \"aborted\": {}, \
             \"p50_queue_wait_s\": {:.6}, \"p99_queue_wait_s\": {:.6}, \
             \"drain_s\": {:.6}, \"chaos_rate\": {:.4}, \"chaos_seed\": {}}}",
            json_escape(&self.name),
            json_escape(&self.backend),
            self.requests,
            self.completed,
            self.rejected,
            self.expired,
            self.aborted,
            self.p50_queue_wait_s,
            self.p99_queue_wait_s,
            self.drain_s,
            self.chaos_rate,
            self.chaos_seed
        )
    }

    /// Append to the repo-root BENCH_soak.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_soak.json"), &self.json_line());
    }
}

/// One BENCH_prefix.json row: the prefix-cache payoff on a shared-system-
/// prompt workload — the same request stream served with `--prefix-cache`
/// off (every prompt prefilled densely) and on (shared blocks aliased out
/// of the radix index, only uncached tails computed). Emitted by the
/// `prefix_cache` bench and smoke-run in CI under FAST_BENCH.
///
/// Schema (JSON lines, one object per row):
///   `name`            `"prefix/<full|fast>"`
///   `backend`         serving backend tag (e.g. `native-packed`)
///   `kv_bits`         cache storage bits per element (32 = FP32)
///   `requests`        requests in the stream (all share one prompt head)
///   `shared_tokens`   length of the shared system-prompt head
///   `host_s_off`      prefill+decode host WAQ seconds, prefix cache off
///   `host_s_on`       same stream, prefix cache on
///   `speedup`         `host_s_off / host_s_on`
///   `prefix_hits`     admissions served partly from the index (on run)
///   `blocks_reused`   blocks aliased instead of recomputed (on run)
///   `evictions`       LRU blocks freed under pool pressure (on run)
///   `bytes_per_token` ideal cache bytes per token position (on run)
pub struct PrefixBenchRow {
    pub name: String,
    pub backend: String,
    pub kv_bits: u32,
    pub requests: u64,
    pub shared_tokens: u64,
    pub host_s_off: f64,
    pub host_s_on: f64,
    pub speedup: f64,
    pub prefix_hits: u64,
    pub blocks_reused: u64,
    pub evictions: u64,
    pub bytes_per_token: f64,
}

impl PrefixBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"backend\": \"{}\", \"kv_bits\": {}, \
             \"requests\": {}, \"shared_tokens\": {}, \"host_s_off\": {:.6}, \
             \"host_s_on\": {:.6}, \"speedup\": {:.3}, \"prefix_hits\": {}, \
             \"blocks_reused\": {}, \"evictions\": {}, \"bytes_per_token\": {:.3}}}",
            json_escape(&self.name),
            json_escape(&self.backend),
            self.kv_bits,
            self.requests,
            self.shared_tokens,
            self.host_s_off,
            self.host_s_on,
            self.speedup,
            self.prefix_hits,
            self.blocks_reused,
            self.evictions,
            self.bytes_per_token
        )
    }

    /// Append to the repo-root BENCH_prefix.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_prefix.json"), &self.json_line());
    }
}

/// One BENCH_spec.json row: speculative decoding payoff at one
/// `(--spec-k, --draft-wbits)` setting — acceptance measured on the real
/// native datapath (test preset, predictable synthetic params), round
/// shape priced at the HBM bandwidth roofline at LLaMA-2-7B scale, the
/// weight-bandwidth-bound regime the subsystem targets. Emitted by the
/// `spec_decode` bench and smoke-run in CI under FAST_BENCH. One
/// `"…/target"` row per run records the non-speculative baseline
/// (spec_k = draft_wbits = 0, accept_rate 0, speedup_bw 1.0).
///
/// Schema (JSON lines, one object per row):
///   `name`             `"spec/<full|fast>/k<K>w<W>"` or `"spec/<…>/target"`
///   `backend`          serving backend tag (`native-spec` / target tag)
///   `spec_k`           configured proposal window (0 = target baseline)
///   `draft_wbits`      draft weight width (0 = target baseline)
///   `requests`         requests served in the run
///   `generated_tokens` tokens emitted across the run
///   `spec_rounds`      speculative rounds executed
///   `proposed`         draft tokens proposed (window clamps included)
///   `accepted`         proposals the target's greedy argmax confirmed
///   `accept_rate`      `accepted / proposed`
///   `host_waq_s`       measured WAQ LUT-GEMM seconds (draft + verify)
///   `host_tok_s`       `generated_tokens / host_waq_s`
///   `tok_s_bw`         HBM-roofline tok/s at LLaMA-2-7B scale: bandwidth
///                      over the round's streamed bytes per emitted token
///   `speedup_bw`       `tok_s_bw / (target row's tok_s_bw)`
pub struct SpecBenchRow {
    pub name: String,
    pub backend: String,
    pub spec_k: u32,
    pub draft_wbits: u32,
    pub requests: u64,
    pub generated_tokens: u64,
    pub spec_rounds: u64,
    pub proposed: u64,
    pub accepted: u64,
    pub accept_rate: f64,
    pub host_waq_s: f64,
    pub host_tok_s: f64,
    pub tok_s_bw: f64,
    pub speedup_bw: f64,
}

impl SpecBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"backend\": \"{}\", \"spec_k\": {}, \
             \"draft_wbits\": {}, \"requests\": {}, \"generated_tokens\": {}, \
             \"spec_rounds\": {}, \"proposed\": {}, \"accepted\": {}, \
             \"accept_rate\": {:.4}, \"host_waq_s\": {:.6}, \"host_tok_s\": {:.3}, \
             \"tok_s_bw\": {:.3}, \"speedup_bw\": {:.4}}}",
            json_escape(&self.name),
            json_escape(&self.backend),
            self.spec_k,
            self.draft_wbits,
            self.requests,
            self.generated_tokens,
            self.spec_rounds,
            self.proposed,
            self.accepted,
            self.accept_rate,
            self.host_waq_s,
            self.host_tok_s,
            self.tok_s_bw,
            self.speedup_bw
        )
    }

    /// Append to the repo-root BENCH_spec.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_spec.json"), &self.json_line());
    }
}

/// One scheduler-bench scenario (`benches/scheduler.rs`), appended to
/// repo-root BENCH_sched.json as a JSON line. Field notes:
///   `sched`            engine scheduler (`burst` | `chunked`)
///   `scenario`         workload shape (`decode-only` | `mixed-flood`)
///   `prefill_chunk`    configured chunk budget (0 = auto/EWMA)
///   `lat_count`        inter-token gaps recorded by the engine's
///                      `decode_lat` histogram (recorded, not inferred)
///   `p50_s`/`p99_s`    decode inter-token latency percentiles, seconds
pub struct SchedBenchRow {
    pub name: String,
    pub sched: String,
    pub scenario: String,
    pub prefill_chunk: usize,
    pub requests: u64,
    pub generated_tokens: u64,
    pub lat_count: u64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl SchedBenchRow {
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"sched\": \"{}\", \"scenario\": \"{}\", \
             \"prefill_chunk\": {}, \"requests\": {}, \"generated_tokens\": {}, \
             \"lat_count\": {}, \"p50_s\": {:.9}, \"p99_s\": {:.9}}}",
            json_escape(&self.name),
            json_escape(&self.sched),
            json_escape(&self.scenario),
            self.prefill_chunk,
            self.requests,
            self.generated_tokens,
            self.lat_count,
            self.p50_s,
            self.p99_s
        )
    }

    /// Append to the repo-root BENCH_sched.json (JSON lines; created if
    /// missing). IO failures are reported, never fatal.
    pub fn append(&self) {
        append_line(&bench_json_path("BENCH_sched.json"), &self.json_line());
    }
}

pub struct Bencher {
    /// measurement window per bench
    pub measure: Duration,
    pub warmup: Duration,
    /// per-iteration item count for throughput reporting
    items_per_iter: Option<u64>,
    /// when set, every result is appended as a JSON line here
    json_sink: Option<PathBuf>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure: Duration::from_millis(900),
            warmup: Duration::from_millis(150),
            items_per_iter: None,
            json_sink: None,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            measure: Duration::from_millis(250),
            warmup: Duration::from_millis(50),
            ..Default::default()
        }
    }

    pub fn throughput(mut self, items: u64) -> Self {
        self.items_per_iter = Some(items);
        self
    }

    /// Also append every result to the named repo-root JSON-lines file
    /// (e.g. `"BENCH_waq_gemm.json"`).
    pub fn json(mut self, file_name: &str) -> Self {
        self.json_sink = Some(bench_json_path(file_name));
        self
    }

    /// Run `f` repeatedly; returns and prints the timing summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: how many iters fit in ~10ms batches?
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let batch = ((10e6 / per_iter).ceil() as u64).clamp(1, 1 << 20);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let min = samples[0];
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            p50_ns: p50,
            min_ns: min,
            throughput: self.items_per_iter.map(|n| n as f64 * 1e9 / mean),
            extra: Vec::new(),
        };
        res.report();
        if let Some(path) = &self.json_sink {
            res.append_json(path);
        }
        res
    }
}

/// `FAST_BENCH=1` shrinks every bench's workload (used by `make bench` in CI
/// sanity runs; the full run omits it).
pub fn fast_mode() -> bool {
    std::env::var("FAST_BENCH").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            measure: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            ..Default::default()
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0 && r.min_ns <= r.mean_ns);
    }

    #[test]
    fn throughput_reported() {
        let b = Bencher::quick().throughput(100);
        let r = b.run("tp", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn json_line_is_machine_readable() {
        let r = BenchResult {
            name: "pa\"th".to_string(),
            iters: 10,
            mean_ns: 1.5,
            p50_ns: 1.0,
            min_ns: 0.5,
            throughput: Some(2e6),
            extra: Vec::new(),
        };
        let line = r.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"mean_ns\": 1.500"), "{line}");
        assert!(line.contains("\\\""), "escapes quotes: {line}");
        let none = BenchResult { throughput: None, ..r };
        assert!(none.json_line().contains("\"throughput\": null"));
    }

    #[test]
    fn extra_pairs_land_in_the_json_row() {
        let r = BenchResult {
            name: "kv".into(),
            extra: vec![
                ("kv_bits".into(), "4".into()),
                ("peak_kv_bytes".into(), "1536".into()),
            ],
            ..Default::default()
        };
        let line = r.json_line();
        assert!(line.ends_with("\"kv_bits\": 4, \"peak_kv_bytes\": 1536}"), "{line}");
    }

    #[test]
    fn kv_row_json_is_machine_readable() {
        let row = KvBenchRow {
            backend: "native-packed".into(),
            kv_bits: 4,
            bytes_per_token: 192.0,
            peak_cache_bytes: 6144,
            decode_tok_s: 123.4,
            attn_rel_err: 0.0123,
        };
        let line = row.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"kv_bits\": 4"), "{line}");
        assert!(line.contains("\"bytes_per_token\": 192.000"), "{line}");
        assert!(line.contains("\"attn_rel_err\": 0.012300"), "{line}");
    }

    #[test]
    fn prefill_row_json_is_machine_readable() {
        let row = PrefillBenchRow {
            name: "prefill_burst/native-packed/batched".into(),
            backend: "native-packed".into(),
            mode: "batched".into(),
            burst: 8,
            prompt_tokens: 128,
            host_waq_s: 0.0125,
            wall_s: 0.02,
            tok_s: 6400.0,
            speedup_vs_sequential: 2.5,
        };
        let line = row.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"mode\": \"batched\""), "{line}");
        assert!(line.contains("\"burst\": 8"), "{line}");
        assert!(line.contains("\"host_waq_s\": 0.012500"), "{line}");
        assert!(line.contains("\"speedup_vs_sequential\": 2.5000"), "{line}");
    }

    #[test]
    fn prefix_row_json_is_machine_readable() {
        let row = PrefixBenchRow {
            name: "prefix/fast".into(),
            backend: "native-packed".into(),
            kv_bits: 32,
            requests: 12,
            shared_tokens: 48,
            host_s_off: 0.5,
            host_s_on: 0.1,
            speedup: 5.0,
            prefix_hits: 10,
            blocks_reused: 120,
            evictions: 3,
            bytes_per_token: 512.0,
        };
        let line = row.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"shared_tokens\": 48"), "{line}");
        assert!(line.contains("\"speedup\": 5.000"), "{line}");
        assert!(line.contains("\"prefix_hits\": 10"), "{line}");
        assert!(line.contains("\"bytes_per_token\": 512.000"), "{line}");
    }

    #[test]
    fn shard_row_json_is_machine_readable() {
        let row = ShardBenchRow {
            name: "shard_scaling/gemm/k768n4096b8".into(),
            shards: 4,
            tok_s: 1234.5,
            mean_ns: 987654.0,
            speedup_vs_1: 3.1,
            efficiency: 0.775,
        };
        let line = row.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"shards\": 4"), "{line}");
        assert!(line.contains("\"speedup_vs_1\": 3.1000"), "{line}");
        assert!(line.contains("\"efficiency\": 0.7750"), "{line}");
    }

    #[test]
    fn spec_row_json_is_machine_readable() {
        let row = SpecBenchRow {
            name: "spec/fast/k4w2".into(),
            backend: "native-spec".into(),
            spec_k: 4,
            draft_wbits: 2,
            requests: 8,
            generated_tokens: 128,
            spec_rounds: 40,
            proposed: 150,
            accepted: 120,
            accept_rate: 0.8,
            host_waq_s: 0.0125,
            host_tok_s: 10240.0,
            tok_s_bw: 412.5,
            speedup_bw: 1.37,
        };
        let line = row.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"spec_k\": 4"), "{line}");
        assert!(line.contains("\"draft_wbits\": 2"), "{line}");
        assert!(line.contains("\"accept_rate\": 0.8000"), "{line}");
        assert!(line.contains("\"tok_s_bw\": 412.500"), "{line}");
        assert!(line.contains("\"speedup_bw\": 1.3700"), "{line}");
        // acceptance never exceeds what was proposed
        assert!(row.accepted <= row.proposed);
    }

    #[test]
    fn soak_row_json_is_machine_readable() {
        let row = SoakBenchRow {
            name: "soak/native-packed/inproc".into(),
            backend: "native-packed".into(),
            requests: 64,
            completed: 50,
            rejected: 6,
            expired: 5,
            aborted: 3,
            p50_queue_wait_s: 0.0012,
            p99_queue_wait_s: 0.0456,
            drain_s: 0.25,
            chaos_rate: 0.05,
            chaos_seed: 0xC4A05,
        };
        let line = row.json_line();
        assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        assert!(line.contains("\"requests\": 64"), "{line}");
        assert!(line.contains("\"p99_queue_wait_s\": 0.045600"), "{line}");
        assert!(line.contains("\"chaos_rate\": 0.0500"), "{line}");
        assert!(line.contains("\"chaos_seed\": 805381"), "{line}");
        // terminal outcomes account for every request in this row
        assert_eq!(row.completed + row.rejected + row.expired + row.aborted, row.requests);
    }

    #[test]
    fn append_json_appends_lines() {
        let path = std::env::temp_dir().join("kllm_bench_json_test.json");
        let _ = std::fs::remove_file(&path);
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 2.0,
            p50_ns: 2.0,
            min_ns: 2.0,
            throughput: None,
            extra: Vec::new(),
        };
        r.append_json(&path);
        r.append_json(&path);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_path_is_repo_root() {
        let p = bench_json_path("BENCH_test.json");
        assert!(p.ends_with("BENCH_test.json"));
        assert!(!p.parent().unwrap().ends_with("rust"), "{p:?} should be repo root");
    }
}
