//! Minimal benchmarking harness (criterion is not in the offline registry).
//!
//! Benches under rust/benches/ use `harness = false` and drive this:
//! warmup, adaptive iteration count targeting a fixed measurement window,
//! and mean/p50/min reporting with a throughput hook. Also provides
//! `black_box` via `std::hint`.

use std::time::{Duration, Instant};

pub use std::hint::black_box;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    /// optional items/sec given a per-iteration item count
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) {
        let fmt = |ns: f64| -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} us", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        };
        let tp = self
            .throughput
            .map(|t| {
                if t > 1e9 {
                    format!("  {:.2} Gitem/s", t / 1e9)
                } else if t > 1e6 {
                    format!("  {:.2} Mitem/s", t / 1e6)
                } else {
                    format!("  {:.1} item/s", t)
                }
            })
            .unwrap_or_default();
        println!(
            "bench {:40} iters={:<7} mean={:>10}  p50={:>10}  min={:>10}{}",
            self.name,
            self.iters,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.min_ns),
            tp
        );
    }
}

pub struct Bencher {
    /// measurement window per bench
    pub measure: Duration,
    pub warmup: Duration,
    /// per-iteration item count for throughput reporting
    items_per_iter: Option<u64>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure: Duration::from_millis(900),
            warmup: Duration::from_millis(150),
            items_per_iter: None,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            measure: Duration::from_millis(250),
            warmup: Duration::from_millis(50),
            items_per_iter: None,
        }
    }

    pub fn throughput(mut self, items: u64) -> Self {
        self.items_per_iter = Some(items);
        self
    }

    /// Run `f` repeatedly; returns and prints the timing summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + calibration: how many iters fit in ~10ms batches?
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let batch = ((10e6 / per_iter).ceil() as u64).clamp(1, 1 << 20);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        let min = samples[0];
        let res = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            p50_ns: p50,
            min_ns: min,
            throughput: self.items_per_iter.map(|n| n as f64 * 1e9 / mean),
        };
        res.report();
        res
    }
}

/// `FAST_BENCH=1` shrinks every bench's workload (used by `make bench` in CI
/// sanity runs; the full run omits it).
pub fn fast_mode() -> bool {
    std::env::var("FAST_BENCH").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            measure: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            items_per_iter: None,
        };
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0 && r.min_ns <= r.mean_ns);
    }

    #[test]
    fn throughput_reported() {
        let b = Bencher::quick().throughput(100);
        let r = b.run("tp", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.throughput.unwrap() > 0.0);
    }
}
