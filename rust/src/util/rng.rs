//! Deterministic PRNG (no `rand` crate offline): splitmix64-seeded
//! xoshiro256++, plus the distributions the repo needs (uniform, normal,
//! zipf, permutation). All experiments seed explicitly so tables are
//! reproducible run-to-run.

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-cryptographic) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of iid N(0, sigma^2) samples.
    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * sigma).collect()
    }

    /// Heavy-tailed sample: N(0,1) mixed with a scaled Student-t-like tail.
    /// Used to synthesize LLM-activation-shaped data (outlier-prone) for
    /// unit tests and microbenches.
    pub fn heavy_tailed(&mut self, outlier_prob: f64, outlier_scale: f64) -> f32 {
        let base = self.normal();
        if self.f64() < outlier_prob {
            (base * outlier_scale) as f32
        } else {
            base as f32
        }
    }

    pub fn heavy_tailed_vec(&mut self, n: usize, p: f64, scale: f64) -> Vec<f32> {
        (0..n).map(|_| self.heavy_tailed(p, scale)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Zipf(s) sample over [0, n) via rejection-free inverse-CDF on a
    /// precomputed table — see [`ZipfTable`] for the bulk path.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Precomputed Zipf CDF for corpus synthesis (eval/corpora.rs).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, exponent: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c = Rng::new(43).next_u64();
        assert_ne!(a[0], c);
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(4);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let t = ZipfTable::new(1000, 1.1);
        let mut r = Rng::new(5);
        let n = 20_000;
        let low = (0..n).filter(|_| t.sample(&mut r) < 10).count();
        assert!(low as f64 / n as f64 > 0.3, "zipf not skewed: {low}");
    }

    #[test]
    fn heavy_tail_produces_outliers() {
        let mut r = Rng::new(6);
        let xs = r.heavy_tailed_vec(10_000, 0.01, 20.0);
        let big = xs.iter().filter(|x| x.abs() > 10.0).count();
        assert!(big > 10, "expected outliers, got {big}");
    }
}
