//! ASCII table renderer for the experiment harness — every `kllm experiment
//! <id>` prints its paper table/figure through this.

#[derive(Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns,
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    /// Horizontal separator row.
    pub fn sep(&mut self) -> &mut Self {
        self.rows.push(Vec::new());
        self
    }

    pub fn note(&mut self, n: &str) -> &mut Self {
        self.notes.push(n.to_string());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let line = |c: char| -> String {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&c.to_string().repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i] - cell.len();
                match aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cell, " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cell)),
                }
            }
            s
        };

        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&line('-'));
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&line('='));
        out.push('\n');
        for row in &self.rows {
            if row.is_empty() {
                out.push_str(&line('-'));
            } else {
                out.push_str(&fmt_row(row, &self.aligns));
            }
            out.push('\n');
        }
        out.push_str(&line('-'));
        out.push('\n');
        for n in &self.notes {
            out.push_str(&format!("  note: {}\n", n));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as markdown (for EXPERIMENTS.md capture).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            if row.is_empty() {
                continue;
            }
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n_note: {}_\n", n));
        }
        out
    }
}

/// Compact float formatting matching the paper's table style: large values
/// in scientific shorthand (`6e3`), small with 2 decimals.
pub fn fmt_ppl(x: f64) -> String {
    if !x.is_finite() {
        return "inf".into();
    }
    if x >= 1000.0 {
        let exp = x.log10().floor() as i32;
        let mant = x / 10f64.powi(exp);
        format!("{:.0}e{}", mant, exp)
    } else {
        format!("{:.2}", x)
    }
}

pub fn fmt_ratio(x: f64) -> String {
    format!("{:.2}x", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_rows() {
        let mut t = Table::new("demo", &["k", "v"]);
        t.row(&["a", "1"]).row(&["bb", "22"]).sep().row(&["c", "3"]);
        let r = t.render();
        assert!(r.contains("demo") && r.contains("bb") && r.contains("22"));
        assert_eq!(r.lines().filter(|l| l.starts_with('|')).count(), 4);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("m", &["a", "b"]);
        t.row(&["1", "2"]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |") && md.contains("| 1 | 2 |"));
    }

    #[test]
    fn ppl_formatting_matches_paper_style() {
        assert_eq!(fmt_ppl(5.47), "5.47");
        assert_eq!(fmt_ppl(6234.0), "6e3");
        assert_eq!(fmt_ppl(2e5), "2e5");
    }
}
