//! Request/response types for the serving coordinator.

use std::time::Instant;

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling
    pub temperature: f32,
    /// stop when this token is produced (None = run to max_new_tokens)
    pub eos_token: Option<i32>,
    pub arrived: Instant,
    /// Absolute completion deadline. `None` = no deadline (the engine
    /// substitutes `EngineConfig::default_deadline_ms` at submit when that
    /// knob is set). A request past its deadline is answered with
    /// [`FinishReason::DeadlineExpired`] — in-queue (no tokens) or
    /// mid-decode (partial tokens returned, KV slot reclaimed) — instead
    /// of occupying capacity nobody is waiting for anymore. Set per
    /// request over TCP with the `deadline_ms` JSON field.
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
            eos_token: None,
            arrived: Instant::now(),
            deadline: None,
        }
    }

    /// Deadline `ms` milliseconds after arrival (builder-style).
    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline = Some(self.arrived + std::time::Duration::from_millis(ms));
        self
    }

    /// True when the request's deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Length of the prompt as *submitted*. When the context window is
    /// shorter, the backend clamps what it actually consumes and
    /// `truncated_prompt` is set — this field keeps reporting the full
    /// submitted length either way.
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish_reason: FinishReason,
    /// True when the backend consumed fewer prompt tokens than submitted
    /// (prompt clamped into the context window, `seq_len - 1`), so
    /// callers can tell their context was cut instead of silently getting
    /// a completion over a shorter prompt. Also counted in
    /// [`EngineStats::truncated_prompts`].
    pub truncated_prompt: bool,
    /// Measured wall-clock from arrival to the first token. The first
    /// token is the one sampled from the prefill's last-position logits,
    /// so TTFT is set exactly once, at admission (queue wait + prefill) —
    /// decode steps can never be the first token.
    pub ttft_s: f64,
    /// Measured wall-clock from arrival to admission (time spent in the
    /// batcher queue). For requests that never reached a slot (rejected,
    /// expired in-queue, or drained while queued) this equals `total_s` —
    /// their whole life was queue wait. The soak bench publishes the
    /// p50/p99 of this field.
    pub queue_wait_s: f64,
    pub total_s: f64,
    /// modeled OASIS accelerator time/energy for the same work — the
    /// per-request delta of the sim clock (this request's prefill plus
    /// every decode step it was in flight for), not the engine total
    pub modeled_accel_s: f64,
    pub modeled_accel_j: f64,
    /// Backpressure hint attached to [`FinishReason::Rejected`] responses:
    /// estimated milliseconds until the engine has drained enough queue to
    /// accept a resubmit (queue depth x per-request service time / decode
    /// batch width). Service time is the EWMA of recent natural
    /// completions once any exist; before the first completion it falls
    /// back to a modeled cost estimate for the rejected request itself
    /// (prefill + `max_new_tokens` decode steps), so cold-start
    /// rejections carry a real hint instead of `0`. `0` for every
    /// non-rejected outcome. Surfaced over TCP as `retry_after_ms` on
    /// rejection replies.
    pub retry_after_ms: u64,
}

/// Why a request left the engine. Every submitted request receives
/// **exactly one** terminal response carrying one of these — the
/// serving-robustness invariant the soak test pins. `MaxTokens`, `Eos`,
/// and `Length` are the natural completions; the rest are the
/// admission-control / fault-containment outcomes.
///
/// Over the TCP front-end the reason is reported as the `finish_reason`
/// string field (see [`FinishReason::name`]); `Rejected` replies
/// additionally carry `"rejected": true` so load-shedding is trivially
/// machine-detectable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    /// context window exhausted
    Length,
    /// engine shut down, drain deadline passed, or a contained engine
    /// fault aborted the request before natural completion
    Aborted,
    /// Admission control: the queue was at `EngineConfig::queue_cap` (or
    /// admission was closed by a drain) when the request arrived. The
    /// response is immediate — rejected requests are never silently
    /// dropped and never consume queue or KV capacity. Counted in
    /// [`EngineStats::rejected`].
    Rejected,
    /// The request's deadline passed before completion: in-queue (no
    /// tokens) or mid-decode (the tokens generated so far are returned
    /// and the KV slot is reclaimed). Counted in [`EngineStats::expired`].
    DeadlineExpired,
}

impl FinishReason {
    /// Stable machine-readable name (the TCP `finish_reason` field).
    pub fn name(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::Eos => "eos",
            FinishReason::Length => "length",
            FinishReason::Aborted => "aborted",
            FinishReason::Rejected => "rejected",
            FinishReason::DeadlineExpired => "deadline_expired",
        }
    }

    /// Natural completion (ran to its stopping condition) vs an
    /// admission-control / fault / shutdown outcome.
    pub fn is_natural(&self) -> bool {
        matches!(
            self,
            FinishReason::MaxTokens | FinishReason::Eos | FinishReason::Length
        )
    }
}

impl std::fmt::Display for FinishReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Fixed log-spaced latency histogram: 32 power-of-two buckets starting
/// at 1 µs, so bucket `i` spans `[2^i, 2^{i+1})` µs (bucket 0 also
/// absorbs sub-µs samples, the last bucket absorbs everything from
/// ~35 minutes up). Recording is O(1) with no allocation — cheap enough
/// to run on every emitted token — and percentiles come back as the
/// geometric midpoint of the covering bucket, so the quantization error
/// is bounded by sqrt(2) in either direction. The engine records one
/// sample per *decode-emitted* token: the measured wall-clock gap since
/// the slot's previous token (spec rounds split the round gap evenly
/// over the tokens they emit). The first token is never recorded here —
/// that gap is TTFT, reported per response.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: [u64; Self::BUCKETS],
    total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; Self::BUCKETS], total: 0 }
    }
}

impl LatencyHistogram {
    pub const BUCKETS: usize = 32;
    /// Lower edge of bucket 0, in seconds (1 µs).
    const FLOOR_S: f64 = 1e-6;

    /// Record one latency sample (seconds). Non-finite or negative
    /// samples are dropped rather than poisoning a bucket.
    pub fn record(&mut self, seconds: f64) {
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        let idx = if seconds <= Self::FLOOR_S {
            0
        } else {
            ((seconds / Self::FLOOR_S).log2().floor() as usize).min(Self::BUCKETS - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// The q-quantile (`q` in [0, 1]) as the geometric midpoint of the
    /// bucket containing it; `0.0` when empty. `percentile(0.5)` = p50,
    /// `percentile(0.99)` = p99.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::FLOOR_S * (1u64 << i) as f64 * std::f64::consts::SQRT_2;
            }
        }
        Self::FLOOR_S * (1u64 << (Self::BUCKETS - 1)) as f64 * std::f64::consts::SQRT_2
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub prefills: u64,
    /// Admitted requests whose prompt was clamped into the context window
    /// (each one's `Response` also carries `truncated_prompt: true`).
    pub truncated_prompts: u64,
    /// Failed admission-burst prefills (`DecodeBackend::prefill_batch`
    /// returned an error): every request of such a burst was answered
    /// with an `Aborted` response instead of being dropped.
    pub prefill_failures: u64,
    /// Contained engine faults: a failed decode step (or a failed
    /// per-request prefill install) that aborted the in-flight requests
    /// it touched but did NOT kill the engine — the engine answered every
    /// affected waiter with `Aborted` and kept serving.
    pub step_failures: u64,
    /// Requests answered with [`FinishReason::Rejected`] by admission
    /// control (queue at `queue_cap`, or submitted during a drain).
    pub rejected: u64,
    /// Requests answered with [`FinishReason::DeadlineExpired`] (in-queue
    /// or mid-decode).
    pub expired: u64,
    /// TCP listener `accept()` errors (the listener logs and keeps
    /// accepting instead of silently swallowing them). Maintained by the
    /// front-end; merged into coordinator-level stats reads.
    pub accept_errors: u64,
    /// TCP connections refused because `--max-conns` handler threads were
    /// already live (each got an immediate structured rejection line).
    pub conn_rejected: u64,
    pub generated_tokens: u64,
    /// decode-step batch occupancy sum (for mean occupancy)
    pub occupancy_sum: u64,
    pub completed: u64,
    /// serving backend name (`coordinator::BackendSpec::name()`, e.g.
    /// `packed` or `native-packed`; empty before engine construction)
    pub waq_backend: &'static str,
    /// host software WAQ-datapath seconds across all decode steps and
    /// prefills: *measured* wall-clock when a `native-*` backend executes
    /// the LUT-GEMM datapath (admission bursts are measured once per
    /// batched prefill), the modeled `baselines::cpu::CpuWaqModel`
    /// roofline when decode runs PJRT artifacts (PJRT prefills add zero)
    pub host_waq_s: f64,
    /// Tensor-parallel critical-path seconds summed across all steps: for
    /// the sharded backend, each sharded GEMM contributes its slowest
    /// shard's measured wall-clock (the latency floor of the column
    /// split); stays 0.0 for unsharded backends
    pub host_shard_crit_s: f64,
    /// KV-cache storage bits per element (32 = FP32; 0 before engine
    /// construction)
    pub kv_bits: u32,
    /// peak reserved KV-cache bytes (lazy block-pool growth: reflects
    /// actual usage, not the worst-case dense footprint)
    pub peak_kv_bytes: u64,
    /// ideal KV-cache storage bytes per token position (all layers, K+V)
    pub kv_bytes_per_token: f64,
    /// Admitted requests whose prompt matched a non-empty prefix in the
    /// radix index (`--prefix-cache on`): their matched tokens were served
    /// by aliasing shared KV blocks instead of recomputing prefill.
    pub prefix_hits: u64,
    /// Total KV blocks aliased from the prefix index across all admissions
    /// (block refcount bumps, summed over layers — the direct measure of
    /// prefill compute and cache capacity the index saved).
    pub prefix_blocks_reused: u64,
    /// Prefix-cache blocks freed by LRU eviction: allocation-pressure
    /// evictions (pool exhausted at alloc time) plus chaos-injected
    /// pressure. Only index-only blocks (refcount 1) are ever evicted.
    pub evictions: u64,
    /// Speculative decode rounds executed (`--backend native-spec`): one
    /// per active slot per decode step — each round proposes draft tokens
    /// and verifies them in a single stacked target pass.
    pub spec_rounds: u64,
    /// Draft tokens proposed across all speculative rounds.
    pub spec_proposed: u64,
    /// Proposed draft tokens accepted by target verification (the
    /// acceptance rate is `spec_accepted / spec_proposed`; every round
    /// additionally emits one sampled token on top of the accepted run).
    pub spec_accepted: u64,
    /// Intra-burst duplicate prompts collapsed at admission: the
    /// duplicate skipped prefill compute and reused its twin's K/V rows
    /// (dense path: same installed cache; paged path: aliased blocks)
    /// and last-position logits.
    pub burst_dedup_hits: u64,
    /// Per-token decode inter-token latency (measured wall-clock gap
    /// between consecutive emitted tokens of a slot; first tokens are
    /// TTFT, not recorded here). This is the scheduler's tripwire
    /// surface: a burst prefill stalling in-flight decodes shows up
    /// directly as fat p99 gaps, and `--sched chunked` exists to bound
    /// them. p50/p99 ride along in [`EngineStats::to_json`].
    pub decode_lat: LatencyHistogram,
    /// Per-linear weight bit-widths actually served, layer-major with
    /// four entries per layer (qkv, attn_out, mlp_up, mlp_down): the flat
    /// plan under uniform `--wbits`, the calibration-driven assignment
    /// under `--wbits auto`. Empty when the backend reports no plan (the
    /// PJRT stub) or before engine construction.
    pub wbits_plan: Vec<u32>,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.decode_steps as f64
        }
    }

    /// Average served weight bits per linear (the number `--wbits-budget`
    /// constrains); `0.0` when no plan is reported.
    pub fn wbits_avg(&self) -> f64 {
        if self.wbits_plan.is_empty() {
            0.0
        } else {
            self.wbits_plan.iter().sum::<u32>() as f64 / self.wbits_plan.len() as f64
        }
    }

    /// One-line JSON dump of every counter — the `{"cmd": "stats"}`
    /// control-path reply and the stdin `stats` command. Keys are stable;
    /// additions append, never rename.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"decode_steps\": {}, \"prefills\": {}, \"truncated_prompts\": {}, ",
                "\"prefill_failures\": {}, \"step_failures\": {}, \"rejected\": {}, ",
                "\"expired\": {}, \"accept_errors\": {}, \"conn_rejected\": {}, ",
                "\"generated_tokens\": {}, \"completed\": {}, \"mean_occupancy\": {:.4}, ",
                "\"waq_backend\": \"{}\", \"host_waq_s\": {:.6}, \"host_shard_crit_s\": {:.6}, ",
                "\"kv_bits\": {}, \"peak_kv_bytes\": {}, \"kv_bytes_per_token\": {:.3}, ",
                "\"prefix_hits\": {}, \"prefix_blocks_reused\": {}, \"evictions\": {}, ",
                "\"spec_rounds\": {}, \"spec_proposed\": {}, \"spec_accepted\": {}, ",
                "\"burst_dedup_hits\": {}, \"decode_lat_count\": {}, ",
                "\"decode_lat_p50_s\": {:.6}, \"decode_lat_p99_s\": {:.6}, ",
                "\"wbits_avg\": {:.4}, \"wbits_plan\": [{}]}}"
            ),
            self.decode_steps,
            self.prefills,
            self.truncated_prompts,
            self.prefill_failures,
            self.step_failures,
            self.rejected,
            self.expired,
            self.accept_errors,
            self.conn_rejected,
            self.generated_tokens,
            self.completed,
            self.mean_occupancy(),
            self.waq_backend,
            self.host_waq_s,
            self.host_shard_crit_s,
            self.kv_bits,
            self.peak_kv_bytes,
            self.kv_bytes_per_token,
            self.prefix_hits,
            self.prefix_blocks_reused,
            self.evictions,
            self.spec_rounds,
            self.spec_proposed,
            self.spec_accepted,
            self.burst_dedup_hits,
            self.decode_lat.count(),
            self.decode_lat.percentile(0.5),
            self.decode_lat.percentile(0.99),
            self.wbits_avg(),
            self.wbits_plan
                .iter()
                .map(|b| b.to_string())
                .collect::<Vec<_>>()
                .join(","),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_names_are_stable_and_classified() {
        let all = [
            (FinishReason::MaxTokens, "max_tokens", true),
            (FinishReason::Eos, "eos", true),
            (FinishReason::Length, "length", true),
            (FinishReason::Aborted, "aborted", false),
            (FinishReason::Rejected, "rejected", false),
            (FinishReason::DeadlineExpired, "deadline_expired", false),
        ];
        for (fr, name, natural) in all {
            assert_eq!(fr.name(), name);
            assert_eq!(fr.to_string(), name);
            assert_eq!(fr.is_natural(), natural, "{name}");
        }
    }

    #[test]
    fn stats_json_is_one_line_and_carries_prefix_counters() {
        let s = EngineStats {
            prefix_hits: 3,
            prefix_blocks_reused: 12,
            evictions: 2,
            spec_rounds: 7,
            spec_proposed: 28,
            spec_accepted: 19,
            burst_dedup_hits: 4,
            waq_backend: "native-packed",
            ..Default::default()
        };
        let j = s.to_json();
        assert!(!j.contains('\n'), "stats dump must be a single line");
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"prefix_hits\": 3"));
        assert!(j.contains("\"prefix_blocks_reused\": 12"));
        assert!(j.contains("\"evictions\": 2"));
        assert!(j.contains("\"spec_rounds\": 7"));
        assert!(j.contains("\"spec_proposed\": 28"));
        assert!(j.contains("\"spec_accepted\": 19"));
        assert!(j.contains("\"burst_dedup_hits\": 4"));
        assert!(j.contains("\"waq_backend\": \"native-packed\""));
    }

    #[test]
    fn latency_histogram_buckets_and_percentiles() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), 0.0, "empty histogram reads 0");
        // 99 samples in the [1, 2) µs bucket, one in the [1024, 2048) µs
        // bucket: p50 sits in the first, p99 still in the first (the
        // 99th of 100 samples), p100 in the tail bucket
        for _ in 0..99 {
            h.record(1.5e-6);
        }
        h.record(1.5e-3);
        assert_eq!(h.count(), 100);
        let sqrt2 = std::f64::consts::SQRT_2;
        assert!((h.percentile(0.5) - 1e-6 * sqrt2).abs() < 1e-12);
        assert!((h.percentile(0.99) - 1e-6 * sqrt2).abs() < 1e-12);
        assert!((h.percentile(1.0) - 1024e-6 * sqrt2).abs() < 1e-9);
        // quantization error is bounded by sqrt(2) both ways
        for s in [3e-6, 7.9e-5, 0.013, 2.0] {
            let mut one = LatencyHistogram::default();
            one.record(s);
            let p = one.percentile(0.5);
            assert!(p / s <= sqrt2 + 1e-9 && s / p <= sqrt2 + 1e-9, "{s} -> {p}");
        }
        // garbage samples are dropped, extremes clamp into edge buckets
        let mut g = LatencyHistogram::default();
        g.record(f64::NAN);
        g.record(-1.0);
        assert_eq!(g.count(), 0);
        g.record(0.0); // sub-µs clamps into bucket 0
        g.record(1e9); // beyond the last bucket clamps into it
        assert_eq!(g.count(), 2);
        assert!(g.percentile(0.0) > 0.0);
    }

    #[test]
    fn stats_json_appends_latency_keys() {
        let mut s = EngineStats::default();
        s.decode_lat.record(2e-6);
        s.decode_lat.record(2e-6);
        let j = s.to_json();
        assert!(!j.contains('\n'));
        assert!(j.contains("\"decode_lat_count\": 2"), "{j}");
        assert!(j.contains("\"decode_lat_p50_s\": "), "{j}");
        assert!(j.ends_with('}'), "{j}");
        let p99 = s.decode_lat.percentile(0.99);
        assert!(j.contains(&format!("\"decode_lat_p99_s\": {p99:.6}")), "{j}");
    }

    #[test]
    fn stats_json_appends_wbits_plan_keys() {
        let empty = EngineStats::default();
        assert_eq!(empty.wbits_avg(), 0.0);
        assert!(empty.to_json().contains("\"wbits_plan\": []"));
        let s = EngineStats {
            wbits_plan: vec![4, 3, 2, 3, 4, 2, 3, 4],
            ..Default::default()
        };
        assert!((s.wbits_avg() - 3.125).abs() < 1e-12);
        let j = s.to_json();
        assert!(!j.contains('\n'));
        assert!(j.contains("\"wbits_avg\": 3.1250"), "{j}");
        assert!(j.contains("\"wbits_plan\": [4,3,2,3,4,2,3,4]"), "{j}");
        assert!(j.ends_with('}'), "{j}");
    }

    #[test]
    fn deadline_expiry_boundaries() {
        let now = Instant::now();
        let r = Request::new(1, vec![1], 4);
        assert!(r.deadline.is_none());
        assert!(!r.expired(now), "no deadline never expires");
        let r = r.with_deadline_ms(0);
        assert!(r.expired(r.arrived), "0ms deadline is already due at arrival");
        let far = Request::new(2, vec![1], 4).with_deadline_ms(60_000);
        assert!(!far.expired(Instant::now()));
    }
}
