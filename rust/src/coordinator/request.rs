//! Request/response types for the serving coordinator.

use std::time::Instant;

pub type RequestId = u64;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling
    pub temperature: f32,
    /// stop when this token is produced (None = run to max_new_tokens)
    pub eos_token: Option<i32>,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            temperature: 0.0,
            eos_token: None,
            arrived: Instant::now(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Length of the prompt as *submitted*. When the context window is
    /// shorter, the backend clamps what it actually consumes and
    /// `truncated_prompt` is set — this field keeps reporting the full
    /// submitted length either way.
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub finish_reason: FinishReason,
    /// True when the backend consumed fewer prompt tokens than submitted
    /// (prompt clamped into the context window, `seq_len - 1`), so
    /// callers can tell their context was cut instead of silently getting
    /// a completion over a shorter prompt. Also counted in
    /// [`EngineStats::truncated_prompts`].
    pub truncated_prompt: bool,
    /// Measured wall-clock from arrival to the first token. The first
    /// token is the one sampled from the prefill's last-position logits,
    /// so TTFT is set exactly once, at admission (queue wait + prefill) —
    /// decode steps can never be the first token.
    pub ttft_s: f64,
    pub total_s: f64,
    /// modeled OASIS accelerator time/energy for the same work — the
    /// per-request delta of the sim clock (this request's prefill plus
    /// every decode step it was in flight for), not the engine total
    pub modeled_accel_s: f64,
    pub modeled_accel_j: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    Eos,
    /// context window exhausted
    Length,
    /// engine shut down before completion
    Aborted,
}

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub prefills: u64,
    /// Admitted requests whose prompt was clamped into the context window
    /// (each one's `Response` also carries `truncated_prompt: true`).
    pub truncated_prompts: u64,
    /// Failed admission-burst prefills (`DecodeBackend::prefill_batch`
    /// returned an error): every request of such a burst was answered
    /// with an `Aborted` response instead of being dropped.
    pub prefill_failures: u64,
    pub generated_tokens: u64,
    /// decode-step batch occupancy sum (for mean occupancy)
    pub occupancy_sum: u64,
    pub completed: u64,
    /// serving backend name (`coordinator::BackendSpec::name()`, e.g.
    /// `packed` or `native-packed`; empty before engine construction)
    pub waq_backend: &'static str,
    /// host software WAQ-datapath seconds across all decode steps and
    /// prefills: *measured* wall-clock when a `native-*` backend executes
    /// the LUT-GEMM datapath (admission bursts are measured once per
    /// batched prefill), the modeled `baselines::cpu::CpuWaqModel`
    /// roofline when decode runs PJRT artifacts (PJRT prefills add zero)
    pub host_waq_s: f64,
    /// Tensor-parallel critical-path seconds summed across all steps: for
    /// the sharded backend, each sharded GEMM contributes its slowest
    /// shard's measured wall-clock (the latency floor of the column
    /// split); stays 0.0 for unsharded backends
    pub host_shard_crit_s: f64,
    /// KV-cache storage bits per element (32 = FP32; 0 before engine
    /// construction)
    pub kv_bits: u32,
    /// peak reserved KV-cache bytes (lazy block-pool growth: reflects
    /// actual usage, not the worst-case dense footprint)
    pub peak_kv_bytes: u64,
    /// ideal KV-cache storage bytes per token position (all layers, K+V)
    pub kv_bytes_per_token: f64,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.decode_steps as f64
        }
    }
}
