//! L3 serving coordinator: request router, continuous batcher, KV slot
//! manager (over the paged `crate::kvcache` subsystem, FP32 or n-bit
//! K-Means storage via `EngineConfig::kv_bits`), the backend-agnostic
//! engine, and the leader thread + TCP front-end. Python never runs here
//! — decode compute goes through a [`backend::DecodeBackend`]: either
//! AOT PJRT artifacts or the native K-Means WAQ LUT-GEMM datapath.

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod kv;
pub mod request;
pub mod server;

pub use backend::{
    probe_decode_logits, BackendSpec, ChaosBackend, ChaosCfg, ChaosCounters, DecodeBackend,
    NativeCfg, NativeWaqBackend, PagedPrefill, PagedPrefillOut, PjrtBackend, PrefillOut,
    ScheduleOut, ScheduleWork, ShardedWaqBackend, SpecRound, SpeculativeBackend, StepCost,
    VerifyRun, WbitsSpec,
};
pub use batcher::{AdmitPolicy, Batcher};
pub use engine::{Engine, EngineConfig, SchedPolicy, SimTotals};
pub use kv::KvManager;
// the KV precision knob is part of the engine-config surface
pub use crate::kvcache::KvBits;
pub use request::{EngineStats, FinishReason, Request, RequestId, Response};
pub use server::{serve_tcp, serve_tcp_with, Coordinator, DrainReport, TcpCfg};
