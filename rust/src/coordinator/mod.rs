//! L3 serving coordinator: request router, continuous batcher, KV slot
//! manager, PJRT-backed engine, and the leader thread + TCP front-end.
//! Python never runs here — the engine executes AOT artifacts only.

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod request;
pub mod server;

pub use batcher::{AdmitPolicy, Batcher};
pub use engine::{Engine, EngineConfig, SimTotals};
pub use kv::KvManager;
pub use request::{EngineStats, FinishReason, Request, RequestId, Response};
pub use server::{serve_tcp, Coordinator};
