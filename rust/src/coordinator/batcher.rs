//! Continuous batcher: admission queue + scheduling policy. Decode-priority
//! (vLLM-style): running slots always step; waiting requests are admitted
//! into free slots (one prefill per engine iteration by default, so decode
//! latency stays bounded — the policy knob the e2e bench sweeps).

use std::collections::VecDeque;
use std::time::Instant;

use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// at most one prefill per engine iteration (decode-priority)
    OnePerStep,
    /// fill every free slot before stepping (prefill-priority)
    FillAll,
}

pub struct Batcher {
    queue: VecDeque<Request>,
    pub policy: AdmitPolicy,
    /// queue-depth cap for bounded admission (0 = unbounded, the
    /// pre-admission-control behavior)
    cap: usize,
    /// monotone admission counter (FIFO fairness check)
    admitted: u64,
}

impl Batcher {
    pub fn new(policy: AdmitPolicy) -> Self {
        Batcher::with_cap(policy, 0)
    }

    /// Bounded batcher: `try_enqueue` refuses pushes past `cap` queued
    /// requests (`cap == 0` keeps the queue unbounded).
    pub fn with_cap(policy: AdmitPolicy, cap: usize) -> Self {
        Batcher { queue: VecDeque::new(), policy, cap, admitted: 0 }
    }

    /// Unconditional enqueue (internal/test paths that bypass admission
    /// control — production submission goes through [`Batcher::try_enqueue`]).
    pub fn enqueue(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    /// Bounded enqueue: hands the request back (`Err`) when the queue is
    /// at `cap`, so the caller can answer it with a `Rejected` response
    /// instead of growing the queue without limit.
    pub fn try_enqueue(&mut self, r: Request) -> Result<(), Request> {
        if self.cap > 0 && self.queue.len() >= self.cap {
            return Err(r);
        }
        self.queue.push_back(r);
        Ok(())
    }

    /// Remove and return every queued request whose deadline has passed
    /// at `now`, preserving FIFO order of both the removed set and the
    /// survivors. The engine answers each with `DeadlineExpired` — expiry
    /// never silently drops a request.
    pub fn take_expired(&mut self, now: Instant) -> Vec<Request> {
        if self.queue.iter().all(|r| !r.expired(now)) {
            return Vec::new();
        }
        let mut expired = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for r in self.queue.drain(..) {
            if r.expired(now) {
                expired.push(r);
            } else {
                kept.push_back(r);
            }
        }
        self.queue = kept;
        expired
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests to admit this iteration given `free_slots` capacity.
    /// FIFO order is guaranteed. The returned burst is the unit of the
    /// engine's admission handshake: `Engine::step` hands the whole batch
    /// to ONE `DecodeBackend::prefill_batch` call (so a FillAll burst
    /// prefills every free slot in a single pass over the model), and on
    /// prefill failure every request popped here still gets a `Response`
    /// — admitted requests never silently vanish.
    pub fn admit(&mut self, free_slots: usize) -> Vec<Request> {
        self.admit_capped(free_slots, usize::MAX)
    }

    /// Partial admission: like [`Batcher::admit`] but additionally capped
    /// at `max` requests — the surface the chunked scheduler uses to take
    /// only as much pending work as its per-step budget and free-slot
    /// count allow, leaving the rest queued in FIFO order.
    pub fn admit_capped(&mut self, free_slots: usize, max: usize) -> Vec<Request> {
        let want = match self.policy {
            AdmitPolicy::OnePerStep => free_slots.min(1),
            AdmitPolicy::FillAll => free_slots,
        };
        let n = want.min(max).min(self.queue.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.queue.pop_front().unwrap());
        }
        self.admitted += n as u64;
        out
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn drain(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let first = b.admit(3);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let rest = b.admit(10);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn one_per_step_policy() {
        let mut b = Batcher::new(AdmitPolicy::OnePerStep);
        for i in 0..4 {
            b.enqueue(req(i));
        }
        assert_eq!(b.admit(4).len(), 1);
        assert_eq!(b.admit(4).len(), 1);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn fill_all_admits_whole_burst_in_one_call() {
        // the batched-prefill handshake: one admit() call returns the
        // entire burst (min of free slots and queue depth), in FIFO order
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let burst = b.admit(8);
        assert_eq!(burst.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert_eq!(b.pending(), 0);
        assert!(b.admit(8).is_empty());
    }

    #[test]
    fn try_enqueue_enforces_cap_and_returns_request() {
        let mut b = Batcher::with_cap(AdmitPolicy::FillAll, 2);
        assert!(b.try_enqueue(req(0)).is_ok());
        assert!(b.try_enqueue(req(1)).is_ok());
        let bounced = b.try_enqueue(req(2)).expect_err("queue at cap");
        assert_eq!(bounced.id, 2, "the rejected request comes back intact");
        assert_eq!(b.pending(), 2);
        // admission frees capacity again
        assert_eq!(b.admit(1).len(), 1);
        assert!(b.try_enqueue(req(3)).is_ok());
    }

    #[test]
    fn cap_zero_is_unbounded() {
        let mut b = Batcher::with_cap(AdmitPolicy::FillAll, 0);
        for i in 0..100 {
            assert!(b.try_enqueue(req(i)).is_ok());
        }
        assert_eq!(b.pending(), 100);
    }

    #[test]
    fn take_expired_preserves_fifo_of_survivors() {
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        // ids 0,2,4 already expired (0ms deadline); 1,3 far-future
        for i in 0..5u64 {
            let r = if i % 2 == 0 {
                req(i).with_deadline_ms(0)
            } else {
                req(i).with_deadline_ms(60_000)
            };
            b.enqueue(r);
        }
        let now = std::time::Instant::now();
        let expired: Vec<u64> = b.take_expired(now).iter().map(|r| r.id).collect();
        assert_eq!(expired, vec![0, 2, 4]);
        assert_eq!(b.pending(), 2);
        let rest: Vec<u64> = b.admit(10).iter().map(|r| r.id).collect();
        assert_eq!(rest, vec![1, 3], "survivors keep FIFO order");
        // no deadlines → fast path returns nothing
        assert!(b.take_expired(now).is_empty());
    }

    #[test]
    fn admit_bounded_by_free_slots() {
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..8 {
            b.enqueue(req(i));
        }
        assert_eq!(b.admit(0).len(), 0);
        assert_eq!(b.admit(2).len(), 2);
        assert_eq!(b.admitted(), 2);
    }

    #[test]
    fn admit_capped_takes_partial_bursts_in_fifo_order() {
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..6 {
            b.enqueue(req(i));
        }
        // cap below free slots: the cap wins
        let first: Vec<u64> = b.admit_capped(4, 2).iter().map(|r| r.id).collect();
        assert_eq!(first, vec![0, 1]);
        // free slots below cap: capacity wins
        let second: Vec<u64> = b.admit_capped(1, 8).iter().map(|r| r.id).collect();
        assert_eq!(second, vec![2]);
        assert_eq!(b.admitted(), 3);
        assert_eq!(b.pending(), 3, "the rest stays queued");
        // the policy bound still applies under a large cap
        let mut one = Batcher::new(AdmitPolicy::OnePerStep);
        for i in 0..3 {
            one.enqueue(req(i));
        }
        assert_eq!(one.admit_capped(4, 8).len(), 1);
    }
}
