//! Continuous batcher: admission queue + scheduling policy. Decode-priority
//! (vLLM-style): running slots always step; waiting requests are admitted
//! into free slots (one prefill per engine iteration by default, so decode
//! latency stays bounded — the policy knob the e2e bench sweeps).

use std::collections::VecDeque;

use super::request::Request;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// at most one prefill per engine iteration (decode-priority)
    OnePerStep,
    /// fill every free slot before stepping (prefill-priority)
    FillAll,
}

pub struct Batcher {
    queue: VecDeque<Request>,
    pub policy: AdmitPolicy,
    /// monotone admission counter (FIFO fairness check)
    admitted: u64,
}

impl Batcher {
    pub fn new(policy: AdmitPolicy) -> Self {
        Batcher { queue: VecDeque::new(), policy, admitted: 0 }
    }

    pub fn enqueue(&mut self, r: Request) {
        self.queue.push_back(r);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests to admit this iteration given `free_slots` capacity.
    /// FIFO order is guaranteed. The returned burst is the unit of the
    /// engine's admission handshake: `Engine::step` hands the whole batch
    /// to ONE `DecodeBackend::prefill_batch` call (so a FillAll burst
    /// prefills every free slot in a single pass over the model), and on
    /// prefill failure every request popped here still gets a `Response`
    /// — admitted requests never silently vanish.
    pub fn admit(&mut self, free_slots: usize) -> Vec<Request> {
        let want = match self.policy {
            AdmitPolicy::OnePerStep => free_slots.min(1),
            AdmitPolicy::FillAll => free_slots,
        };
        let n = want.min(self.queue.len());
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.queue.pop_front().unwrap());
        }
        self.admitted += n as u64;
        out
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn drain(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let first = b.admit(3);
        assert_eq!(first.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let rest = b.admit(10);
        assert_eq!(rest.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn one_per_step_policy() {
        let mut b = Batcher::new(AdmitPolicy::OnePerStep);
        for i in 0..4 {
            b.enqueue(req(i));
        }
        assert_eq!(b.admit(4).len(), 1);
        assert_eq!(b.admit(4).len(), 1);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn fill_all_admits_whole_burst_in_one_call() {
        // the batched-prefill handshake: one admit() call returns the
        // entire burst (min of free slots and queue depth), in FIFO order
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..5 {
            b.enqueue(req(i));
        }
        let burst = b.admit(8);
        assert_eq!(burst.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert_eq!(b.pending(), 0);
        assert!(b.admit(8).is_empty());
    }

    #[test]
    fn admit_bounded_by_free_slots() {
        let mut b = Batcher::new(AdmitPolicy::FillAll);
        for i in 0..8 {
            b.enqueue(req(i));
        }
        assert_eq!(b.admit(0).len(), 0);
        assert_eq!(b.admit(2).len(), 2);
        assert_eq!(b.admitted(), 2);
    }
}
