//! Coordinator front-end: the leader thread that owns the Engine and its
//! decode backend (the PJRT runtime is not Send, so backends are built on
//! — and never leave — that thread) plus a channel-based submission API,
//! graceful drain, and an optional TCP JSON-lines listener.
//!
//! ## TCP JSON-lines schema
//!
//! Request (one JSON object per line):
//! ```json
//! {"prompt": [1, 2, 3], "max_new_tokens": 16, "temperature": 0.0,
//!  "deadline_ms": 500}
//! ```
//! `prompt` is required; `max_new_tokens` defaults to 16, `temperature`
//! to 0.0 (greedy), and `deadline_ms` (optional) bounds this request's
//! end-to-end latency — overriding the server-wide
//! `--default-deadline-ms` when present.
//!
//! Reply (one JSON object per line, always exactly one per request line):
//! ```json
//! {"id": 7, "tokens": [5, 9], "finish_reason": "max_tokens",
//!  "rejected": false, "truncated_prompt": false, "queue_wait_s": 0.00012,
//!  "ttft_s": 0.0031, "total_s": 0.0094, "modeled_accel_s": 0.0021}
//! ```
//! `finish_reason` is one of `max_tokens | eos | length | aborted |
//! rejected | deadline_expired` ([`FinishReason::name`]); `rejected` is
//! `true` exactly when admission control refused the request (queue at
//! `--queue-cap`, or the server is draining), so load-shedding is
//! machine-detectable without string matching. Rejection replies
//! additionally carry `"retry_after_ms"` — the engine's backpressure
//! hint (queue depth x recent service time) telling the client when a
//! resubmit is likely to be admitted; `0` when the engine has no
//! estimate yet. Malformed or failed request lines get
//! `{"error": "<json-escaped message>"}` instead.
//!
//! A control line `{"cmd": "stats"}` (no prompt) replies with one JSON
//! line of engine counters ([`EngineStats::to_json`]) — including the
//! prefix-cache counters (`prefix_hits`, `prefix_blocks_reused`,
//! `evictions`), the speculative counters (`spec_rounds`,
//! `spec_proposed`, `spec_accepted`), and the recorded decode
//! inter-token latency histogram (`decode_lat_count`,
//! `decode_lat_p50_s`, `decode_lat_p99_s` — the per-token gaps the
//! chunked scheduler bounds) — without consuming queue or KV capacity.
//!
//! The full wire protocol (TCP and the stdin REPL), with examples and
//! field-by-field reference, is consolidated in `docs/serving.md` at the
//! repository root — that document and this module's schema comments
//! describe the same single implementation below.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::backend::{
    BackendSpec, ChaosBackend, DecodeBackend, NativeCfg, NativeWaqBackend, PjrtBackend,
    ShardedWaqBackend, SpeculativeBackend,
};
use super::engine::{Engine, EngineConfig, SimTotals};
use super::request::{EngineStats, FinishReason, Request, RequestId, Response};
use crate::gemm::WaqBackend;
use crate::runtime::{artifacts_dir, Manifest, ParamSet, Runtime};
use crate::util::json::{self, Json};

enum Cmd {
    Submit(Request, Sender<Response>),
    Stats(Sender<(EngineStats, SimTotals)>),
    /// Graceful drain: stop admitting (new submits get `Rejected`),
    /// finish in-flight work under the deadline, abort the rest, reply
    /// with a [`DrainReport`], then exit the engine thread.
    Drain(Duration, Sender<DrainReport>),
    Shutdown,
}

/// What a graceful drain accomplished (the `kllm serve` shutdown dump and
/// the soak bench's drain row).
#[derive(Clone, Debug)]
pub struct DrainReport {
    /// Requests that reached a *natural* completion during the drain
    /// window (max_tokens / eos / length).
    pub finished: u64,
    /// Requests aborted when the drain deadline cut them off (in-flight
    /// and still-queued; each waiter got an `Aborted` response).
    pub aborted: u64,
    /// Wall-clock the drain took.
    pub drain_s: f64,
    /// KV blocks still held after the drain — must be 0; the soak test
    /// asserts it (a leak here means a slot escaped release).
    pub in_use_blocks: usize,
    /// Final engine stats (submits arriving mid-drain are counted in
    /// `stats.rejected`).
    pub stats: EngineStats,
    pub sim: SimTotals,
}

/// Where the engine thread finds the model description: a preset name
/// (resolved against the artifacts directory) or an in-memory manifest
/// (no disk access for native backends).
enum EngineSource {
    Preset(String),
    Manifest(Manifest),
}

/// Listener-side counters (incremented on the TCP threads, merged into
/// `EngineStats` by `Coordinator::stats`/`drain` — the engine thread
/// never sees them).
#[derive(Debug, Default)]
struct NetCounters {
    accept_errors: AtomicU64,
    conn_rejected: AtomicU64,
}

pub struct Coordinator {
    tx: Sender<Cmd>,
    next_id: Arc<AtomicU64>,
    net: Arc<NetCounters>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Start the engine thread for a preset's artifacts with the given
    /// (host) parameters.
    pub fn start(preset: String, params: ParamSet, cfg: EngineConfig) -> Result<Coordinator> {
        Self::start_source(EngineSource::Preset(preset), params, cfg)
    }

    /// Start from an in-memory manifest. Native backends need no artifacts
    /// directory at all (e.g. `Manifest::synthetic`); PJRT backends load
    /// HLO files from `manifest.dir`.
    pub fn start_with_manifest(
        manifest: Manifest,
        params: ParamSet,
        cfg: EngineConfig,
    ) -> Result<Coordinator> {
        Self::start_source(EngineSource::Manifest(manifest), params, cfg)
    }

    fn start_source(
        source: EngineSource,
        params: ParamSet,
        cfg: EngineConfig,
    ) -> Result<Coordinator> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("kllm-engine".into())
            .spawn(move || engine_thread(source, params, cfg, rx, ready_tx))
            .map_err(|e| anyhow!("spawn engine: {e}"))?;
        // surface backend/engine construction errors synchronously
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator {
            tx,
            next_id: Arc::new(AtomicU64::new(1)),
            net: Arc::new(NetCounters::default()),
            handle: Some(handle),
        })
    }

    pub fn submit_async(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<(RequestId, Receiver<Response>)> {
        self.submit_with(prompt, max_new_tokens, temperature, None)
    }

    /// Full-surface submit: like [`Coordinator::submit_async`] plus an
    /// optional per-request deadline (milliseconds from now) overriding
    /// the engine's `default_deadline_ms`. Exactly one `Response` arrives
    /// on the returned receiver — including when the request is rejected
    /// by admission control or expires before decoding.
    pub fn submit_with(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
        deadline_ms: Option<u64>,
    ) -> Result<(RequestId, Receiver<Response>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.temperature = temperature;
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        }
        let (rtx, rrx) = channel();
        self.tx
            .send(Cmd::Submit(req, rtx))
            .map_err(|_| anyhow!("engine gone"))?;
        Ok((id, rrx))
    }

    /// Blocking convenience.
    pub fn generate(&self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<Response> {
        let (_, rx) = self.submit_async(prompt, max_new_tokens, 0.0)?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))
    }

    pub fn stats(&self) -> Result<(EngineStats, SimTotals)> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Stats(tx)).map_err(|_| anyhow!("engine gone"))?;
        let (mut stats, sim) = rx.recv().map_err(|_| anyhow!("engine gone"))?;
        self.merge_net(&mut stats);
        Ok((stats, sim))
    }

    /// Graceful drain (the SIGTERM-equivalent path): admission closes
    /// (submits arriving from now on are answered `Rejected`), in-flight
    /// and queued work keeps stepping until done or until `limit`
    /// elapses, stragglers are answered `Aborted`, and the engine thread
    /// exits. Every waiter is answered — drain never strands a request.
    /// The coordinator stays usable only for `shutdown()`/Drop afterwards.
    pub fn drain(&self, limit: Duration) -> Result<DrainReport> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::Drain(limit, tx))
            .map_err(|_| anyhow!("engine gone"))?;
        let mut report = rx.recv().map_err(|_| anyhow!("engine died mid-drain"))?;
        self.merge_net(&mut report.stats);
        Ok(report)
    }

    fn merge_net(&self, stats: &mut EngineStats) {
        stats.accept_errors = self.net.accept_errors.load(Ordering::Relaxed);
        stats.conn_rejected = self.net.conn_rejected.load(Ordering::Relaxed);
    }

    pub fn shutdown(mut self) -> Result<()> {
        self.tx.send(Cmd::Shutdown).ok();
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow!("engine panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.send(Cmd::Shutdown).ok();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Construct the configured decode backend on the engine thread (the PJRT
/// runtime is not Send; the native backend simply has no reason to move).
/// When `cfg.chaos` is set, the backend is wrapped in a fault-injecting
/// [`ChaosBackend`] — chaos composes over every backend uniformly.
fn build_backend(
    source: &EngineSource,
    params: &ParamSet,
    cfg: &EngineConfig,
) -> Result<Box<dyn DecodeBackend>> {
    let inner: Box<dyn DecodeBackend> = match cfg.backend {
        BackendSpec::Pjrt(waq) => {
            let rt = match source {
                EngineSource::Preset(p) => Runtime::for_preset(p)?,
                EngineSource::Manifest(m) => Runtime::new(&m.dir)?,
            };
            Box::new(PjrtBackend::new(rt, params, waq, cfg.mode)?)
        }
        BackendSpec::Native(waq) => {
            let manifest = native_manifest(source)?;
            let ncfg = NativeCfg {
                wbits: cfg.wbits,
                w_group: cfg.w_group,
                ..NativeCfg::from_mode(waq, cfg.mode)
            };
            let native = NativeWaqBackend::new(&manifest, params, ncfg)?;
            Box::new(native)
        }
        BackendSpec::NativeSharded => {
            let manifest = native_manifest(source)?;
            let ncfg = NativeCfg {
                wbits: cfg.wbits,
                w_group: cfg.w_group,
                ..NativeCfg::from_mode(WaqBackend::Packed, cfg.mode)
            };
            let sharded = ShardedWaqBackend::new(&manifest, params, ncfg, cfg.shards)?;
            Box::new(sharded)
        }
        // speculative decoding: the verification target is the plain
        // native packed backend (`--shards` is ignored here — compose a
        // sharded target by teaching this arm ShardedWaqBackend when
        // needed); the {2,3,4}-bit draft is built inside from the same
        // manifest + params, so draft and target serve the same model.
        // The target honors `--wbits` (including the auto planner); the
        // draft always runs uniform `--draft-wbits`.
        BackendSpec::NativeSpec => {
            let manifest = native_manifest(source)?;
            let ncfg = NativeCfg {
                wbits: cfg.wbits,
                w_group: cfg.w_group,
                ..NativeCfg::from_mode(WaqBackend::Packed, cfg.mode)
            };
            let target = NativeWaqBackend::new(&manifest, params, ncfg)?;
            let spec = SpeculativeBackend::new(
                &manifest,
                params,
                Box::new(target),
                cfg.mode,
                cfg.spec_k,
                cfg.draft_wbits,
            )?;
            Box::new(spec)
        }
    };
    Ok(match cfg.chaos {
        Some(chaos_cfg) => Box::new(ChaosBackend::new(inner, chaos_cfg)),
        None => inner,
    })
}

/// Resolve the manifest for a native (artifact-free) backend.
fn native_manifest(source: &EngineSource) -> Result<Manifest> {
    match source {
        EngineSource::Preset(p) => Manifest::load(&artifacts_dir(p)).map_err(|e| anyhow!(e)),
        EngineSource::Manifest(m) => Ok(m.clone()),
    }
}

/// What the command handler tells the engine loop to do next.
enum Flow {
    Continue,
    Shutdown,
    Drain(Duration, Sender<DrainReport>),
}

fn deliver(waiters: &mut HashMap<RequestId, Sender<Response>>, resp: Response) {
    if let Some(tx) = waiters.remove(&resp.id) {
        tx.send(resp).ok();
    }
}

fn engine_thread(
    source: EngineSource,
    params: ParamSet,
    cfg: EngineConfig,
    rx: Receiver<Cmd>,
    ready: Sender<Result<()>>,
) -> Result<()> {
    let backend = match build_backend(&source, &params, &cfg) {
        Ok(b) => b,
        Err(e) => {
            ready.send(Err(anyhow!("{e}"))).ok();
            return Err(anyhow!("backend init failed"));
        }
    };
    let mut engine = Engine::new(backend, &cfg);
    ready.send(Ok(())).ok();

    let mut waiters: HashMap<RequestId, Sender<Response>> = HashMap::new();
    // helper: handle one command
    fn handle(
        engine: &mut Engine,
        waiters: &mut HashMap<RequestId, Sender<Response>>,
        cmd: Cmd,
    ) -> Flow {
        match cmd {
            Cmd::Submit(req, tx) => {
                let id = req.id;
                match engine.try_submit(req) {
                    // queue full: the rejection response goes straight
                    // back — the waiter map never sees the request
                    Some(reject) => {
                        tx.send(reject).ok();
                    }
                    None => {
                        waiters.insert(id, tx);
                    }
                }
                Flow::Continue
            }
            Cmd::Stats(tx) => {
                tx.send((engine.stats.clone(), engine.sim)).ok();
                Flow::Continue
            }
            Cmd::Drain(limit, tx) => Flow::Drain(limit, tx),
            Cmd::Shutdown => {
                for resp in engine.abort_all() {
                    deliver(waiters, resp);
                }
                Flow::Shutdown
            }
        }
    }

    loop {
        // drain every queued command without blocking
        loop {
            match rx.try_recv() {
                Ok(cmd) => match handle(&mut engine, &mut waiters, cmd) {
                    Flow::Continue => {}
                    Flow::Shutdown => return Ok(()),
                    Flow::Drain(limit, tx) => {
                        run_drain(&mut engine, &mut waiters, &rx, limit, tx);
                        return Ok(());
                    }
                },
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    handle(&mut engine, &mut waiters, Cmd::Shutdown);
                    return Ok(());
                }
            }
        }
        if engine.has_work() {
            // step() contains backend faults internally; an Err here is
            // unrecoverable engine-state corruption — still answer every
            // waiter before surfacing it, so nobody hangs on a dead thread
            match engine.step() {
                Ok(responses) => {
                    for resp in responses {
                        deliver(&mut waiters, resp);
                    }
                }
                Err(e) => {
                    eprintln!("engine: unrecoverable step error: {e}");
                    for resp in engine.abort_all() {
                        deliver(&mut waiters, resp);
                    }
                    return Err(e);
                }
            }
        } else {
            // idle: block for the next command
            match rx.recv() {
                Ok(cmd) => match handle(&mut engine, &mut waiters, cmd) {
                    Flow::Continue => {}
                    Flow::Shutdown => return Ok(()),
                    Flow::Drain(limit, tx) => {
                        run_drain(&mut engine, &mut waiters, &rx, limit, tx);
                        return Ok(());
                    }
                },
                Err(_) => {
                    handle(&mut engine, &mut waiters, Cmd::Shutdown);
                    return Ok(());
                }
            }
        }
    }
}

/// The drain procedure: admission is closed (new submits answered
/// `Rejected` immediately), in-flight + queued work steps until idle or
/// the deadline, stragglers are aborted, and every collected report
/// channel gets the same [`DrainReport`]. Runs on the engine thread; the
/// thread exits after it returns.
fn run_drain(
    engine: &mut Engine,
    waiters: &mut HashMap<RequestId, Sender<Response>>,
    rx: &Receiver<Cmd>,
    limit: Duration,
    tx: Sender<DrainReport>,
) {
    let started = Instant::now();
    let mut report_txs = vec![tx];
    let mut finished = 0u64;
    let mut cut_short = false;
    loop {
        // commands keep arriving mid-drain: reject submits, answer stats,
        // collect concurrent drain requests, honor a hard shutdown
        loop {
            match rx.try_recv() {
                Ok(Cmd::Submit(req, rtx)) => {
                    rtx.send(engine.reject(req)).ok();
                }
                Ok(Cmd::Stats(stx)) => {
                    stx.send((engine.stats.clone(), engine.sim)).ok();
                }
                Ok(Cmd::Drain(_, dtx)) => report_txs.push(dtx),
                Ok(Cmd::Shutdown) => cut_short = true,
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        if cut_short || !engine.has_work() || started.elapsed() >= limit {
            break;
        }
        match engine.step() {
            Ok(responses) => {
                for resp in responses {
                    if resp.finish_reason.is_natural() {
                        finished += 1;
                    }
                    deliver(waiters, resp);
                }
            }
            Err(e) => {
                eprintln!("engine: step error during drain ({e}); aborting the rest");
                break;
            }
        }
    }
    let mut aborted = 0u64;
    for resp in engine.abort_all() {
        aborted += 1;
        deliver(waiters, resp);
    }
    let report = DrainReport {
        finished,
        aborted,
        drain_s: started.elapsed().as_secs_f64(),
        in_use_blocks: engine.kv().cache().in_use_blocks(),
        stats: engine.stats.clone(),
        sim: engine.sim,
    };
    for rtx in report_txs {
        rtx.send(report.clone()).ok();
    }
}

// ---------------------------------------------------------------------------
// TCP JSON-lines front-end
// ---------------------------------------------------------------------------

/// Listener hardening knobs (`--max-conns`, `--read-timeout-ms`).
#[derive(Clone, Copy, Debug)]
pub struct TcpCfg {
    /// Maximum concurrent connection-handler threads; excess connections
    /// get an immediate structured rejection line and are closed (counted
    /// in `EngineStats::conn_rejected`). `0` = unlimited.
    pub max_conns: usize,
    /// Per-read socket timeout so a dead client can't pin a handler
    /// thread forever; a timed-out connection is closed cleanly.
    pub read_timeout: Option<Duration>,
}

impl Default for TcpCfg {
    fn default() -> Self {
        TcpCfg { max_conns: 64, read_timeout: Some(Duration::from_secs(30)) }
    }
}

/// Serve the JSON-lines protocol (see the module docs for the schema)
/// with default hardening ([`TcpCfg::default`]). Returns the bound port.
pub fn serve_tcp(coord: Arc<Coordinator>, port: u16) -> Result<u16> {
    serve_tcp_with(coord, port, TcpCfg::default())
}

/// [`serve_tcp`] with explicit listener hardening. Accept errors are
/// counted (`EngineStats::accept_errors`) and logged — never silently
/// swallowed — and the listener keeps accepting after them. The wire
/// schema served here is documented line-by-line in `docs/serving.md`.
pub fn serve_tcp_with(coord: Arc<Coordinator>, port: u16, cfg: TcpCfg) -> Result<u16> {
    use std::io::Write;
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    let actual = listener.local_addr()?.port();
    let net = coord.net.clone();
    let active = Arc::new(AtomicUsize::new(0));
    std::thread::Builder::new()
        .name("kllm-tcp".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let mut stream = match stream {
                    Ok(s) => s,
                    Err(e) => {
                        net.accept_errors.fetch_add(1, Ordering::Relaxed);
                        eprintln!("kllm-tcp: accept error: {e}");
                        continue;
                    }
                };
                let slots = active.fetch_add(1, Ordering::AcqRel);
                if cfg.max_conns > 0 && slots >= cfg.max_conns {
                    active.fetch_sub(1, Ordering::AcqRel);
                    net.conn_rejected.fetch_add(1, Ordering::Relaxed);
                    // structured rejection, then close — the client sees
                    // backpressure, not a mystery hangup
                    let _ = stream.write_all(conn_reject_reply().as_bytes());
                    let _ = stream.write_all(b"\n");
                    continue;
                }
                let coord = coord.clone();
                let active = active.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(coord, stream, cfg.read_timeout);
                    active.fetch_sub(1, Ordering::AcqRel);
                });
            }
        })
        .map_err(|e| anyhow!("spawn tcp: {e}"))?;
    Ok(actual)
}

fn handle_conn(
    coord: Arc<Coordinator>,
    stream: std::net::TcpStream,
    read_timeout: Option<Duration>,
) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    stream.set_read_timeout(read_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            // read timeout: close the idle/dead connection cleanly
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
        let reply = match handle_line(&coord, line.trim()) {
            Ok(j) => j,
            Err(e) => error_reply(&e),
        };
        stream.write_all(reply.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
    }
}

/// The `{"error": ...}` reply line, with the message JSON-escaped — raw
/// interpolation corrupted the protocol whenever an error contained a
/// quote or backslash (regression-tested).
fn error_reply(msg: &str) -> String {
    format!("{{\"error\": {}}}", json::escape(msg))
}

/// The structured over-capacity rejection sent to connections past
/// `--max-conns` before closing them.
fn conn_reject_reply() -> String {
    format!(
        "{{\"rejected\": true, \"error\": {}}}",
        json::escape("server at connection capacity")
    )
}

/// One reply line for a completed/terminal response (schema in the
/// module docs). A single construction site so the TCP surface cannot
/// diverge between completion and rejection paths.
fn response_reply(resp: &Response) -> String {
    let toks = resp
        .tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    // the backpressure hint is a rejection-only field: absent elsewhere so
    // clients can treat its presence as "resubmit later" without checking
    // finish_reason first
    let retry = if resp.finish_reason == FinishReason::Rejected {
        format!(", \"retry_after_ms\": {}", resp.retry_after_ms)
    } else {
        String::new()
    };
    format!(
        "{{\"id\": {}, \"tokens\": [{}], \"finish_reason\": {}, \"rejected\": {}, \
         \"truncated_prompt\": {}, \"queue_wait_s\": {:.6}, \"ttft_s\": {:.6}, \
         \"total_s\": {:.6}, \"modeled_accel_s\": {:.6}{}}}",
        resp.id,
        toks,
        json::escape(resp.finish_reason.name()),
        resp.finish_reason == FinishReason::Rejected,
        resp.truncated_prompt,
        resp.queue_wait_s,
        resp.ttft_s,
        resp.total_s,
        resp.modeled_accel_s,
        retry
    )
}

fn handle_line(coord: &Coordinator, line: &str) -> Result<String, String> {
    let j = Json::parse(line)?;
    // control path first: a stats line has no prompt and never enqueues
    if j.get("cmd").and_then(Json::as_str) == Some("stats") {
        let (stats, _) = coord.stats().map_err(|e| e.to_string())?;
        return Ok(stats.to_json());
    }
    let prompt: Vec<i32> = j
        .expect("prompt")?
        .as_arr()
        .ok_or("prompt must be a list")?
        .iter()
        .filter_map(Json::as_f64)
        .map(|v| v as i32)
        .collect();
    let max_new = j
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(16);
    let temperature = j
        .get("temperature")
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as f32;
    let deadline_ms = j
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .map(|v| v.max(0.0) as u64);
    let (_, rx) = coord
        .submit_with(prompt, max_new, temperature, deadline_ms)
        .map_err(|e| e.to_string())?;
    let resp = rx.recv().map_err(|_| "request dropped".to_string())?;
    Ok(response_reply(&resp))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite regression: error strings with JSON metacharacters must
    /// produce *parseable* reply lines (the old code interpolated raw).
    #[test]
    fn error_reply_escapes_metacharacters() {
        for msg in [
            "plain failure",
            "unexpected token '\"' at line 1",
            "path C:\\tmp\\x and a\nnewline",
        ] {
            let line = error_reply(msg);
            let j = Json::parse(&line).expect("error reply must stay valid JSON");
            assert_eq!(j.get("error").and_then(Json::as_str), Some(msg), "{line}");
        }
    }

    #[test]
    fn conn_reject_reply_is_structured() {
        let j = Json::parse(&conn_reject_reply()).expect("valid JSON");
        assert_eq!(j.get("rejected").and_then(Json::as_bool), Some(true));
        assert!(j.get("error").and_then(Json::as_str).is_some());
    }

    #[test]
    fn response_reply_surfaces_rejection_and_finish_reason() {
        let mk = |fr: FinishReason, tokens: Vec<i32>| Response {
            id: 42,
            prompt_len: 3,
            tokens,
            finish_reason: fr,
            truncated_prompt: false,
            ttft_s: 0.001,
            queue_wait_s: 0.0005,
            total_s: 0.002,
            modeled_accel_s: 0.0001,
            modeled_accel_j: 0.0,
            retry_after_ms: 120,
        };
        let done = Json::parse(&response_reply(&mk(FinishReason::MaxTokens, vec![1, 2])))
            .expect("valid JSON");
        assert_eq!(done.get("finish_reason").and_then(Json::as_str), Some("max_tokens"));
        assert_eq!(done.get("rejected").and_then(Json::as_bool), Some(false));
        assert_eq!(done.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(done.get("queue_wait_s").and_then(Json::as_f64).is_some());
        // the hint is a rejection-only field
        assert!(done.get("retry_after_ms").is_none());

        let rej = Json::parse(&response_reply(&mk(FinishReason::Rejected, vec![])))
            .expect("valid JSON");
        assert_eq!(rej.get("rejected").and_then(Json::as_bool), Some(true));
        assert_eq!(rej.get("finish_reason").and_then(Json::as_str), Some("rejected"));
        assert_eq!(rej.get("tokens").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(rej.get("retry_after_ms").and_then(Json::as_f64), Some(120.0));
    }
}
