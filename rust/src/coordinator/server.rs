//! Coordinator front-end: the leader thread that owns the Engine and its
//! decode backend (the PJRT runtime is not Send, so backends are built on
//! — and never leave — that thread) plus a channel-based submission API
//! and an optional TCP JSON-lines listener.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::backend::{
    BackendSpec, DecodeBackend, NativeCfg, NativeWaqBackend, PjrtBackend, ShardedWaqBackend,
};
use super::engine::{Engine, EngineConfig, SimTotals};
use super::request::{EngineStats, Request, RequestId, Response};
use crate::gemm::WaqBackend;
use crate::runtime::{artifacts_dir, Manifest, ParamSet, Runtime};
use crate::util::json::Json;

enum Cmd {
    Submit(Request, Sender<Response>),
    Stats(Sender<(EngineStats, SimTotals)>),
    Shutdown,
}

/// Where the engine thread finds the model description: a preset name
/// (resolved against the artifacts directory) or an in-memory manifest
/// (no disk access for native backends).
enum EngineSource {
    Preset(String),
    Manifest(Manifest),
}

pub struct Coordinator {
    tx: Sender<Cmd>,
    next_id: Arc<AtomicU64>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl Coordinator {
    /// Start the engine thread for a preset's artifacts with the given
    /// (host) parameters.
    pub fn start(preset: String, params: ParamSet, cfg: EngineConfig) -> Result<Coordinator> {
        Self::start_source(EngineSource::Preset(preset), params, cfg)
    }

    /// Start from an in-memory manifest. Native backends need no artifacts
    /// directory at all (e.g. `Manifest::synthetic`); PJRT backends load
    /// HLO files from `manifest.dir`.
    pub fn start_with_manifest(
        manifest: Manifest,
        params: ParamSet,
        cfg: EngineConfig,
    ) -> Result<Coordinator> {
        Self::start_source(EngineSource::Manifest(manifest), params, cfg)
    }

    fn start_source(
        source: EngineSource,
        params: ParamSet,
        cfg: EngineConfig,
    ) -> Result<Coordinator> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("kllm-engine".into())
            .spawn(move || engine_thread(source, params, cfg, rx, ready_tx))
            .map_err(|e| anyhow!("spawn engine: {e}"))?;
        // surface backend/engine construction errors synchronously
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Coordinator {
            tx,
            next_id: Arc::new(AtomicU64::new(1)),
            handle: Some(handle),
        })
    }

    pub fn submit_async(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<(RequestId, Receiver<Response>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.temperature = temperature;
        let (rtx, rrx) = channel();
        self.tx
            .send(Cmd::Submit(req, rtx))
            .map_err(|_| anyhow!("engine gone"))?;
        Ok((id, rrx))
    }

    /// Blocking convenience.
    pub fn generate(&self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<Response> {
        let (_, rx) = self.submit_async(prompt, max_new_tokens, 0.0)?;
        rx.recv().map_err(|_| anyhow!("engine dropped request"))
    }

    pub fn stats(&self) -> Result<(EngineStats, SimTotals)> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Stats(tx)).map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))
    }

    pub fn shutdown(mut self) -> Result<()> {
        self.tx.send(Cmd::Shutdown).ok();
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow!("engine panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.send(Cmd::Shutdown).ok();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Construct the configured decode backend on the engine thread (the PJRT
/// runtime is not Send; the native backend simply has no reason to move).
fn build_backend(
    source: &EngineSource,
    params: &ParamSet,
    cfg: &EngineConfig,
) -> Result<Box<dyn DecodeBackend>> {
    match cfg.backend {
        BackendSpec::Pjrt(waq) => {
            let rt = match source {
                EngineSource::Preset(p) => Runtime::for_preset(p)?,
                EngineSource::Manifest(m) => Runtime::new(&m.dir)?,
            };
            Ok(Box::new(PjrtBackend::new(rt, params, waq, cfg.mode)?))
        }
        BackendSpec::Native(waq) => {
            let manifest = native_manifest(source)?;
            let native = NativeWaqBackend::new(
                &manifest,
                params,
                NativeCfg::from_mode(waq, cfg.mode),
            )?;
            Ok(Box::new(native))
        }
        BackendSpec::NativeSharded => {
            let manifest = native_manifest(source)?;
            let sharded = ShardedWaqBackend::new(
                &manifest,
                params,
                NativeCfg::from_mode(WaqBackend::Packed, cfg.mode),
                cfg.shards,
            )?;
            Ok(Box::new(sharded))
        }
    }
}

/// Resolve the manifest for a native (artifact-free) backend.
fn native_manifest(source: &EngineSource) -> Result<Manifest> {
    match source {
        EngineSource::Preset(p) => Manifest::load(&artifacts_dir(p)).map_err(|e| anyhow!(e)),
        EngineSource::Manifest(m) => Ok(m.clone()),
    }
}

fn engine_thread(
    source: EngineSource,
    params: ParamSet,
    cfg: EngineConfig,
    rx: Receiver<Cmd>,
    ready: Sender<Result<()>>,
) -> Result<()> {
    let backend = match build_backend(&source, &params, &cfg) {
        Ok(b) => b,
        Err(e) => {
            ready.send(Err(anyhow!("{e}"))).ok();
            return Err(anyhow!("backend init failed"));
        }
    };
    let mut engine = Engine::new(backend, &cfg);
    ready.send(Ok(())).ok();

    let mut waiters: HashMap<RequestId, Sender<Response>> = HashMap::new();
    // helper: handle one command; returns false on shutdown
    fn handle(
        engine: &mut Engine,
        waiters: &mut HashMap<RequestId, Sender<Response>>,
        cmd: Cmd,
    ) -> bool {
        match cmd {
            Cmd::Submit(req, tx) => {
                waiters.insert(req.id, tx);
                engine.submit(req);
                true
            }
            Cmd::Stats(tx) => {
                tx.send((engine.stats.clone(), engine.sim)).ok();
                true
            }
            Cmd::Shutdown => {
                for resp in engine.abort_all() {
                    if let Some(tx) = waiters.remove(&resp.id) {
                        tx.send(resp).ok();
                    }
                }
                false
            }
        }
    }

    loop {
        // drain every queued command without blocking
        loop {
            match rx.try_recv() {
                Ok(cmd) => {
                    if !handle(&mut engine, &mut waiters, cmd) {
                        return Ok(());
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    handle(&mut engine, &mut waiters, Cmd::Shutdown);
                    return Ok(());
                }
            }
        }
        if engine.has_work() {
            for resp in engine.step()? {
                if let Some(tx) = waiters.remove(&resp.id) {
                    tx.send(resp).ok();
                }
            }
        } else {
            // idle: block for the next command
            match rx.recv() {
                Ok(cmd) => {
                    if !handle(&mut engine, &mut waiters, cmd) {
                        return Ok(());
                    }
                }
                Err(_) => {
                    handle(&mut engine, &mut waiters, Cmd::Shutdown);
                    return Ok(());
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TCP JSON-lines front-end
// ---------------------------------------------------------------------------

/// Serve `{"prompt": [ids...], "max_new_tokens": n}` lines over TCP,
/// responding with `{"id":..,"tokens":[..],"truncated_prompt":..,
/// "ttft_s":..,"total_s":..}`.
/// Returns the bound port. Runs until the listener thread is dropped with
/// the process (demo front-end; the in-process API is the primary one).
pub fn serve_tcp(coord: Arc<Coordinator>, port: u16) -> Result<u16> {
    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    let actual = listener.local_addr()?.port();
    std::thread::Builder::new()
        .name("kllm-tcp".into())
        .spawn(move || {
            for stream in listener.incoming().flatten() {
                let coord = coord.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(coord, stream);
                });
            }
        })
        .map_err(|e| anyhow!("spawn tcp: {e}"))?;
    Ok(actual)
}

fn handle_conn(coord: Arc<Coordinator>, stream: std::net::TcpStream) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let reply = match handle_line(&coord, line.trim()) {
            Ok(j) => j,
            Err(e) => format!("{{\"error\": \"{e}\"}}"),
        };
        stream.write_all(reply.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
    }
}

fn handle_line(coord: &Coordinator, line: &str) -> Result<String, String> {
    let j = Json::parse(line)?;
    let prompt: Vec<i32> = j
        .expect("prompt")?
        .as_arr()
        .ok_or("prompt must be a list")?
        .iter()
        .filter_map(Json::as_f64)
        .map(|v| v as i32)
        .collect();
    let max_new = j
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .unwrap_or(16);
    let temperature = j
        .get("temperature")
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as f32;
    let (_, rx) = coord
        .submit_async(prompt, max_new, temperature)
        .map_err(|e| e.to_string())?;
    let resp = rx.recv().map_err(|_| "request dropped".to_string())?;
    let toks = resp
        .tokens
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    Ok(format!(
        "{{\"id\": {}, \"tokens\": [{}], \"truncated_prompt\": {}, \"ttft_s\": {:.6}, \"total_s\": {:.6}, \"modeled_accel_s\": {:.6}}}",
        resp.id, toks, resp.truncated_prompt, resp.ttft_s, resp.total_s, resp.modeled_accel_s
    ))
}
