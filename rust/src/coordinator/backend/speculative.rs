//! Speculative decoding: a low-bit packed draft proposes (2-bit by
//! default), the packed target verifies — bit-exact with the target
//! alone under greedy sampling (`--backend native-spec`).
//!
//! The composite owns two models quantized from the SAME manifest and
//! parameter set:
//!
//!   * **draft** — a [`NativeWaqBackend`] re-quantized at
//!     `--draft-wbits` (2 by default, any of {2,3,4}). The unified
//!     [`crate::quant::PackedStream`] form picks its density from the
//!     codebook width — a 4-entry codebook streams four reduction rows
//!     per byte, so the default 2-bit draft moves *half* the weight
//!     bytes of the target's 4-bit pass; that bandwidth gap is the whole
//!     speedup budget (a 4-bit draft streams the same bytes as the
//!     target and only wins when its proposals are nearly free to
//!     verify). The draft keeps a private FP32 [`KvManager`] (no prefix
//!     index) so its rollbacks never touch the engine's shared paged
//!     cache.
//!   * **target** — any paged-capable [`DecodeBackend`] (`native-packed`
//!     or `native-sharded`); its logits define correctness.
//!
//! One decode round per engine step, per active slot at position `p`
//! with last emitted token `t` (fed by the engine, not yet in any cache):
//!
//!   1. *propose*: up to `--spec-k` batched greedy draft steps produce
//!      `d_1..d_k` against the draft cache;
//!   2. *verify*: the target scores `[t, d_1..d_k]` at positions
//!      `p..p+k` in ONE stacked [`DecodeBackend::verify_paged`] pass —
//!      each linear's weights stream once per layer for all k+1 rows —
//!      appending K/V into the shared paged cache as it goes;
//!   3. *accept*: the longest prefix with `argmax(L_j) == d_{j+1}` (the
//!      engine's own NaN-safe [`greedy_argmax`]) is committed; rejected
//!      positions roll back via [`KvManager::truncate`] (COW-safe:
//!      reference drops only, shared prefix blocks untouched); the
//!      engine receives the accepted tokens through
//!      [`DecodeBackend::take_spec_rounds`] plus the logits row at the
//!      first divergent position, from which it samples the round's
//!      final token exactly as a non-speculative step would.
//!
//! Acceptance == `k` leaves the draft cache one row short (it never saw
//! its own last proposal as input), so those slots run one extra batched
//! draft step to stay in lockstep. A draft slot that desyncs from the
//! engine's cache (abort, slot reuse) is simply released and its slot
//! degrades to `k = 0` rounds — an ordinary decode through the verify
//! path — until the next paged prefill re-admits it.
//!
//! Bit-exactness argument: `verify_paged` rows reproduce `decode`'s
//! float sequence exactly (see `native.rs`), acceptance uses the same
//! argmax the engine samples greedily with, and a round with `m`
//! accepted tokens leaves cache contents and position identical to
//! `m + 1` plain decode steps — so greedy `native-spec` output is
//! bit-identical to the target alone at every `--kv-bits`, enforced by
//! `tests/backend_parity.rs`.

use anyhow::{anyhow, bail, Result};

use super::{
    BackendSpec, DecodeBackend, NativeCfg, PagedPrefill, PagedPrefillOut, PrefillOut, SpecRound,
    StepCost, VerifyRun, WbitsSpec,
};
use crate::coordinator::engine::greedy_argmax;
use crate::coordinator::kv::KvManager;
use crate::coordinator::NativeWaqBackend;
use crate::gemm::WaqBackend;
use crate::kvcache::KvQuantizer;
use crate::runtime::artifacts::ModelCfg;
use crate::runtime::{Manifest, ParamSet};
use crate::sim::OasisMode;

/// `--backend native-spec`: draft-propose / target-verify speculative
/// decoding over the shared paged KV cache.
pub struct SpeculativeBackend {
    target: Box<dyn DecodeBackend>,
    draft: NativeWaqBackend,
    /// Draft-private cache: FP32, no prefix index — its truncations are
    /// invisible to the engine's shared cache.
    draft_kv: KvManager,
    spec_k: usize,
    draft_wbits: u32,
    /// Rounds of the latest `decode`, drained by `take_spec_rounds`.
    rounds: Vec<SpecRound>,
}

impl SpeculativeBackend {
    /// Compose a speculative backend: quantize a draft twin of
    /// `manifest`/`params` at `draft_wbits` (any of {2,3,4}; the packed
    /// stream density follows the codebook width) and
    /// pair it with `target`, which must serve the same model config and
    /// support paged prefill (the composite's rollback is
    /// `KvManager::truncate`, a paged-cache operation).
    pub fn new(
        manifest: &Manifest,
        params: &ParamSet,
        target: Box<dyn DecodeBackend>,
        mode: OasisMode,
        spec_k: usize,
        draft_wbits: u32,
    ) -> Result<SpeculativeBackend> {
        if spec_k == 0 {
            bail!("invalid --spec-k 0: speculative decoding needs >= 1 draft token");
        }
        if !matches!(draft_wbits, 2 | 3 | 4) {
            bail!("invalid --draft-wbits {draft_wbits}: the draft serves 2, 3, or 4 bits");
        }
        if !target.supports_paged_prefill() {
            bail!(
                "speculative target '{}' must support paged prefill",
                target.spec().name()
            );
        }
        let m = target.model();
        let mm = manifest.model;
        if mm.decode_batch != m.decode_batch || mm.seq_len != m.seq_len || mm.vocab != m.vocab {
            bail!("speculative draft and target must serve the same model config");
        }
        let cfg = NativeCfg {
            wbits: WbitsSpec::Uniform(draft_wbits),
            ..NativeCfg::from_mode(WaqBackend::Packed, mode)
        };
        let draft = NativeWaqBackend::new(manifest, params, cfg)?;
        Ok(SpeculativeBackend {
            draft_kv: KvManager::new(m),
            target,
            draft,
            spec_k,
            draft_wbits,
            rounds: Vec::new(),
        })
    }

    /// Configured proposal window.
    pub fn spec_k(&self) -> usize {
        self.spec_k
    }

    /// Draft weight bit-width (2/3/4; 2 streams the densest form).
    pub fn draft_wbits(&self) -> u32 {
        self.draft_wbits
    }

    /// Drop draft slots whose request no longer matches the engine's
    /// cache (aborted / finished / reused slots). Lazy by design: the
    /// engine never tells backends about releases, so the composite
    /// re-derives liveness from the shared cache at each entry point.
    fn sync_slots(&mut self, kv: &KvManager) {
        for slot in 0..self.draft_kv.cfg.decode_batch {
            if let Some(dr) = self.draft_kv.request_of(slot) {
                if kv.request_of(slot) != Some(dr) {
                    self.draft_kv.release(slot);
                }
            }
        }
    }
}

impl DecodeBackend for SpeculativeBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::NativeSpec
    }

    fn model(&self) -> ModelCfg {
        self.target.model()
    }

    /// Cache codebooks come from the target's calibration — the shared
    /// paged cache stores the *target's* K/V, the draft cache is FP32.
    fn kv_quantizer(&self, bits: u32) -> KvQuantizer {
        self.target.kv_quantizer(bits)
    }

    /// The *target's* plan — its logits define the served model; the
    /// draft's uniform `--draft-wbits` twin is an internal accelerator.
    fn wbits_plan(&self) -> Option<Vec<u32>> {
        self.target.wbits_plan()
    }

    /// Dense prefill delegates to the target (the probe path). The draft
    /// stays cold — its slots are only admitted through `prefill_paged`,
    /// so a dense-admitted slot simply runs `k = 0` rounds.
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        self.target.prefill(prompt)
    }

    fn prefill_batch(&mut self, prompts: &[&[i32]]) -> Result<Vec<PrefillOut>> {
        self.target.prefill_batch(prompts)
    }

    fn supports_paged_prefill(&self) -> bool {
        true
    }

    /// The engine must admit through the paged cache: speculative
    /// rollback is `KvManager::truncate`, which needs every slot resident
    /// in block tables, not dense KV pairs.
    fn requires_paged_admission(&self) -> bool {
        true
    }

    /// Target prefill first (all-or-nothing, into the shared cache), then
    /// the draft prefills the SAME prompts into its private cache — whole
    /// prompts, `cached = 0` on the first chunk: the draft has no prefix
    /// index, recomputing a shared prefix with the cheap model costs less
    /// than keeping a second index coherent. A chunk *resume* (the
    /// iteration-level scheduler re-enters with the same slot + request
    /// and a longer prompt slice) skips the claim and continues from the
    /// draft's own cursor, so mid-prefill slots never trip the "slot not
    /// free" claim error. On a draft failure only the newly claimed draft
    /// slots are released and the error propagates; the engine then
    /// releases the shared-cache slots too, keeping both sides clean.
    fn prefill_paged(
        &mut self,
        reqs: &[PagedPrefill<'_>],
        kv: &mut KvManager,
    ) -> Result<Vec<PagedPrefillOut>> {
        let mut outs = self.target.prefill_paged(reqs, kv)?;
        self.sync_slots(kv);
        // resume detection: `sync_slots` just released every draft slot
        // whose request diverged from the shared cache, so a surviving
        // match means this call continues a prefill already in flight
        let resumed: Vec<bool> = reqs
            .iter()
            .map(|r| {
                self.draft_kv.request_of(r.slot).is_some()
                    && self.draft_kv.request_of(r.slot) == kv.request_of(r.slot)
            })
            .collect();
        let claim = |dkv: &mut KvManager, req: &PagedPrefill<'_>| -> Result<()> {
            let request = kv
                .request_of(req.slot)
                .ok_or_else(|| anyhow!("paged prefill: slot {} unclaimed", req.slot))?;
            let plen = req.prompt.len().max(1);
            dkv.admit_prefix(req.slot, request, req.prompt, plen)
                .map_err(anyhow::Error::msg)?;
            Ok(())
        };
        let mut claimed = Vec::with_capacity(reqs.len());
        let mut run = || -> Result<Vec<PagedPrefillOut>> {
            for (req, &resume) in reqs.iter().zip(&resumed) {
                if resume {
                    continue;
                }
                claim(&mut self.draft_kv, req)?;
                claimed.push(req.slot);
            }
            let draft_reqs: Vec<PagedPrefill<'_>> = reqs
                .iter()
                .zip(&resumed)
                .map(|(r, &resume)| PagedPrefill {
                    prompt: r.prompt,
                    slot: r.slot,
                    // resume chunks continue from the draft's cursor; a
                    // first chunk recomputes any index-served prefix
                    // (the draft keeps no prefix index, so its cache
                    // must cover the whole prompt itself)
                    cached: if resume {
                        self.draft_kv.position(r.slot).unwrap_or(0)
                    } else {
                        0
                    },
                })
                .collect();
            let douts = self.draft.prefill_paged(&draft_reqs, &mut self.draft_kv)?;
            for (req, dout) in reqs.iter().zip(&douts) {
                self.draft_kv
                    .set_position(req.slot, dout.plen)
                    .map_err(anyhow::Error::msg)?;
            }
            Ok(douts)
        };
        match run() {
            Ok(douts) => {
                for (out, dout) in outs.iter_mut().zip(douts) {
                    out.cost.accel_s += dout.cost.accel_s;
                    out.cost.accel_j += dout.cost.accel_j;
                    out.cost.host_waq_s += dout.cost.host_waq_s;
                    out.cost.shard_crit_s += dout.cost.shard_crit_s;
                    out.cost.draft_s += dout.cost.host_waq_s;
                }
                Ok(outs)
            }
            Err(e) => {
                for slot in claimed {
                    self.draft_kv.release(slot);
                }
                Err(e)
            }
        }
    }

    /// One speculative round per active slot: batched draft proposals,
    /// one stacked target verification, greedy acceptance, rollback.
    /// Returns the logits row at each slot's first divergent position
    /// (what the engine samples); the accepted prefixes travel via
    /// `take_spec_rounds`. The shared cache leaves this call already
    /// advanced/truncated — the engine must not `advance` it again.
    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> Result<(Vec<f32>, StepCost)> {
        let m = self.target.model();
        let (b, s, vocab) = (m.decode_batch, m.seq_len, m.vocab);
        if toks.len() != b || pos.len() != b || active.len() != b {
            bail!("decode arity mismatch: expected {b} slots");
        }
        self.sync_slots(kv);
        self.rounds.clear();

        // per-slot proposal window: spec_k, clamped to the context room
        // (verify appends k+1 rows at p..p+k, so k <= s-1-p; the engine
        // only decodes non-exhausted slots, so p <= s-2 and k >= 1), and
        // zero for slots without a live, position-synced draft twin
        let mut k_slot = vec![0usize; b];
        for i in 0..b {
            if !active[i] {
                continue;
            }
            let p = pos[i] as usize;
            if self.draft_kv.request_of(i).is_some() {
                if self.draft_kv.position(i) == Some(p) {
                    k_slot[i] = self.spec_k.min(s.saturating_sub(1).saturating_sub(p));
                } else {
                    // desynced draft (should not happen; degrade safely)
                    self.draft_kv.release(i);
                }
            }
        }

        // --- propose: up to max(k_slot) batched greedy draft steps -----
        let mut cur_toks = toks.to_vec();
        let mut cur_pos = pos.to_vec();
        let mut proposals: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut draft_cost = StepCost::default();
        let kmax = k_slot.iter().copied().max().unwrap_or(0);
        for step in 0..kmax {
            let step_active: Vec<bool> =
                (0..b).map(|i| active[i] && step < k_slot[i]).collect();
            let (logits, c) =
                self.draft.decode(&cur_toks, &cur_pos, &step_active, &mut self.draft_kv)?;
            draft_cost.accel_s += c.accel_s;
            draft_cost.accel_j += c.accel_j;
            draft_cost.host_waq_s += c.host_waq_s;
            draft_cost.shard_crit_s += c.shard_crit_s;
            for i in 0..b {
                if !step_active[i] {
                    continue;
                }
                self.draft_kv.advance(i).map_err(anyhow::Error::msg)?;
                let d = greedy_argmax(&logits[i * vocab..(i + 1) * vocab]);
                proposals[i].push(d);
                cur_toks[i] = d;
                cur_pos[i] += 1;
            }
        }

        // --- verify: one stacked pass over [t, d_1..d_k] per slot ------
        let run_tokens: Vec<(usize, Vec<i32>)> = (0..b)
            .filter(|&i| active[i])
            .map(|i| {
                let mut ts = Vec::with_capacity(proposals[i].len() + 1);
                ts.push(toks[i]);
                ts.extend_from_slice(&proposals[i]);
                (i, ts)
            })
            .collect();
        let runs: Vec<VerifyRun<'_>> = run_tokens
            .iter()
            .map(|(i, ts)| VerifyRun { slot: *i, start: pos[*i] as usize, tokens: ts })
            .collect();
        let (run_logits, verify_cost) = self.target.verify_paged(&runs, kv)?;
        if run_logits.len() != runs.len() {
            bail!("verify returned {} result rows for {} runs", run_logits.len(), runs.len());
        }

        // --- accept: longest matching prefix, then roll back the rest --
        let mut out = vec![0f32; b * vocab];
        let mut needs_extra = vec![false; b];
        for (run, lg) in runs.iter().zip(&run_logits) {
            let i = run.slot;
            let p = run.start;
            let props = &proposals[i];
            if lg.len() != run.tokens.len() * vocab {
                bail!("verify logits shape mismatch for slot {i}");
            }
            let mut acc = 0usize;
            while acc < props.len()
                && greedy_argmax(&lg[acc * vocab..(acc + 1) * vocab]) == props[acc]
            {
                acc += 1;
            }
            // commit: keep rows p..=p+acc, drop the rejected tail; the
            // slot position lands at p+acc+1, exactly where acc+1 plain
            // decode steps would have left it
            kv.truncate(i, p + acc + 1).map_err(anyhow::Error::msg)?;
            out[i * vocab..(i + 1) * vocab]
                .copy_from_slice(&lg[acc * vocab..(acc + 1) * vocab]);
            self.rounds.push(SpecRound {
                slot: i,
                proposed: props.len() as u64,
                accepted: props[..acc].to_vec(),
            });
            if k_slot[i] == 0 {
                continue; // no draft twin: nothing to roll back
            }
            if acc < props.len() {
                // draft rows p..p+k-1 hold [t, d_1..d_{k-1}]; keep the
                // accepted prefix and resync to the shared position
                self.draft_kv
                    .truncate(i, p + acc + 1)
                    .map_err(anyhow::Error::msg)?;
            } else if p + props.len() + 1 < s - 1 {
                // full acceptance: the draft never consumed d_k, so it is
                // one row behind — run one extra step below (skipped when
                // the slot exhausts this round anyway)
                needs_extra[i] = true;
            }
        }

        // --- keep fully-accepting drafts in lockstep -------------------
        if needs_extra.iter().any(|&f| f) {
            let (_, c) =
                self.draft.decode(&cur_toks, &cur_pos, &needs_extra, &mut self.draft_kv)?;
            draft_cost.accel_s += c.accel_s;
            draft_cost.accel_j += c.accel_j;
            draft_cost.host_waq_s += c.host_waq_s;
            draft_cost.shard_crit_s += c.shard_crit_s;
            for i in 0..b {
                if needs_extra[i] {
                    self.draft_kv.advance(i).map_err(anyhow::Error::msg)?;
                }
            }
        }

        let mut cost = verify_cost;
        cost.verify_s = verify_cost.host_waq_s;
        cost.draft_s = draft_cost.host_waq_s;
        cost.accel_s += draft_cost.accel_s;
        cost.accel_j += draft_cost.accel_j;
        cost.host_waq_s += draft_cost.host_waq_s;
        cost.shard_crit_s += draft_cost.shard_crit_s;
        Ok((out, cost))
    }

    fn take_spec_rounds(&mut self) -> Option<Vec<SpecRound>> {
        Some(std::mem::take(&mut self.rounds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            seq_len: 16,
            batch: 2,
            decode_batch: 2,
            head_dim: 16,
            d_ff: 64,
            n_linears: 8,
        }
    }

    fn build(spec_k: usize, wbits: u32) -> Result<SpeculativeBackend> {
        let manifest = Manifest::synthetic("tiny", tiny_cfg());
        let params = ParamSet::init(&manifest, &mut Rng::new(42));
        let target = Box::new(NativeWaqBackend::new(
            &manifest,
            &params,
            NativeCfg::from_mode(WaqBackend::Packed, OasisMode::a4()),
        )?);
        SpeculativeBackend::new(
            &manifest,
            &params,
            target,
            OasisMode::a4(),
            spec_k,
            wbits,
        )
    }

    #[test]
    fn constructor_validates_config() {
        assert!(build(0, 2).is_err(), "spec_k 0 rejected");
        assert!(build(4, 1).is_err(), "1-bit draft rejected");
        assert!(build(4, 5).is_err(), "draft wider than 4 bits rejected");
        for wbits in [3u32, 4] {
            let b = build(2, wbits).expect("any packed width builds");
            assert_eq!(b.draft_wbits(), wbits);
        }
        let b = build(2, 2).expect("valid config builds");
        assert_eq!(b.spec(), BackendSpec::NativeSpec);
        assert_eq!(b.spec_k(), 2);
        assert_eq!(b.draft_wbits(), 2);
        assert!(b.requires_paged_admission());
        assert!(b.supports_paged_prefill());
    }

    #[test]
    fn rejects_non_paged_target() {
        let manifest = Manifest::synthetic("tiny", tiny_cfg());
        let params = ParamSet::init(&manifest, &mut Rng::new(42));
        let target = Box::new(crate::coordinator::PjrtBackend::stub(
            tiny_cfg(),
            WaqBackend::Packed,
            OasisMode::a4(),
        ));
        let err = SpeculativeBackend::new(
            &manifest,
            &params,
            target,
            OasisMode::a4(),
            2,
            2,
        );
        assert!(err.is_err(), "dense-KV target must be rejected");
    }

    #[test]
    fn decode_without_draft_slot_degrades_to_plain_rounds() {
        // dense-probe shape: slots admitted outside prefill_paged run
        // k = 0 rounds whose logits equal a plain target decode
        let mut spec = build(4, 2).expect("build");
        let m = spec.model();
        let prompt = [3i32, 7, 11];
        let pre = spec.prefill(&prompt).expect("prefill");
        let mut kv = KvManager::new(m);
        kv.install_prefill(0, 1, pre.plen, &pre.k_cache, &pre.v_cache).unwrap();
        let mut toks = vec![0i32; m.decode_batch];
        let mut pos = vec![0i32; m.decode_batch];
        let mut active = vec![false; m.decode_batch];
        toks[0] = 5;
        pos[0] = pre.plen as i32;
        active[0] = true;
        let (logits, _) = spec.decode(&toks, &pos, &active, &mut kv).expect("decode");
        let rounds = spec.take_spec_rounds().expect("speculative backend");
        assert_eq!(rounds.len(), 1);
        assert_eq!(rounds[0].proposed, 0);
        assert!(rounds[0].accepted.is_empty());
        // position advanced by the backend (truncate == advance at k = 0)
        assert_eq!(kv.position(0), Some(pre.plen + 1));
        assert!(logits[..m.vocab].iter().any(|v| *v != 0.0));
    }
}
