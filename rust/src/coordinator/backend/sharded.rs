//! The tensor-parallel sharded native backend: `NativeWaqBackend`'s exact
//! datapath with every WAQ LUT-GEMM linear split into `S` column shards
//! executed concurrently on a persistent worker pool (`gemm::sharded`).
//!
//! The shard seam, end to end:
//!   * **Load time** — each packed weight matrix is partitioned into `S`
//!     contiguous column shards (`PackedWeights::slice_cols`: row-pair
//!     packing preserved; codebook, per-column scales, outlier-dequant
//!     state, and a LUT replica go with each shard), mirroring how
//!     tensor-parallel serving shards a `Linear` across ranks.
//!   * **Step time** — one GEMM call fans the shards out over the pool;
//!     each shard writes its disjoint column slice of the shared output
//!     rows (zero-copy "all-gather": the full row is only consumed at
//!     the next nonlinearity boundary — norm, GELU, softmax — exactly
//!     where multi-device TP would gather).
//!   * **Unsharded remainder** — embeddings, norms, attention, the LM
//!     head, and the paged KV cache are untouched: attention is FP row
//!     arithmetic over the cache's block-table gather, not a LUT-GEMM,
//!     so sharding it would split the *reduction* (requiring a real
//!     all-reduce) rather than the embarrassingly-parallel column axis.
//!     `kv_quantizer` likewise delegates to the unsharded calibration
//!     pass, so `--kv-bits {32,4,3,2}` compose unchanged.
//!
//! Because every shard performs the identical per-column FP operations in
//! the identical order as the unsharded packed kernel, this backend is
//! **bit-exact** with `native-packed` at any shard count — enforced by
//! the parity net in `tests/backend_parity.rs` and the `shard_scaling`
//! bench's CI tripwires. `StepCost::shard_crit_s` reports the real
//! slowest-shard critical path of each step (the latency floor a
//! multi-worker split cannot beat) — the chunked scheduler's auto budget
//! (`--prefill-chunk 0`) EWMA-tracks exactly this number to size prefill
//! chunks against decode steps. `DecodeBackend::schedule` composes via
//! the trait default: chunks and decode both delegate to the inner
//! sharded datapath, no override needed.

use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::{
    BackendSpec, DecodeBackend, NativeCfg, NativeWaqBackend, PagedPrefill, PagedPrefillOut,
    PrefillOut, StepCost, VerifyRun,
};
use crate::coordinator::kv::KvManager;
use crate::gemm::{ShardPool, WaqBackend};
use crate::kvcache::KvQuantizer;
use crate::runtime::artifacts::ModelCfg;
use crate::runtime::{Manifest, ParamSet};

/// `--backend native-sharded`: the native K-Means WAQ datapath with
/// tensor-parallel column-sharded linears on a persistent worker pool.
pub struct ShardedWaqBackend {
    inner: NativeWaqBackend,
    shards: usize,
}

impl ShardedWaqBackend {
    /// Quantize `params` exactly like [`NativeWaqBackend`] (same
    /// calibration pass, same codebooks — the packed kernel is forced,
    /// since shards stream nibble-packed column slices), then split every
    /// linear into `shards` column shards on a fresh persistent pool.
    /// `shards == 0` is a configuration error, reported as `Err`.
    pub fn new(
        manifest: &Manifest,
        params: &ParamSet,
        cfg: NativeCfg,
        shards: usize,
    ) -> Result<ShardedWaqBackend> {
        if shards == 0 {
            bail!("invalid --shards 0: the sharded backend needs >= 1 column shard");
        }
        let cfg = NativeCfg { waq: WaqBackend::Packed, ..cfg };
        let mut inner = NativeWaqBackend::new(manifest, params, cfg)?;
        let pool = Arc::new(ShardPool::new(shards).map_err(anyhow::Error::msg)?);
        inner.shard_linears(shards, &pool)?;
        Ok(ShardedWaqBackend { inner, shards })
    }

    /// Configured shard count (worker threads in the pool; narrow
    /// matrices may execute fewer effective shards).
    pub fn shard_count(&self) -> usize {
        self.shards
    }

    /// Handle to the compensation-branch outlier counter (shared with the
    /// inner datapath).
    pub fn outlier_counter(&self) -> Arc<AtomicU64> {
        self.inner.outlier_counter()
    }
}

impl DecodeBackend for ShardedWaqBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::NativeSharded
    }

    fn model(&self) -> ModelCfg {
        self.inner.model()
    }

    /// Cache codebooks come from the *unsharded* calibration pass —
    /// attention (and therefore the KV cache) is not sharded, so the
    /// sharded backend serves any `--kv-bits` with books bit-identical
    /// to `native-packed`'s.
    fn kv_quantizer(&self, bits: u32) -> KvQuantizer {
        self.inner.kv_quantizer(bits)
    }

    /// The inner datapath's plan — `slice_cols` preserves each linear's
    /// stream width, so the sharded backend serves the same per-layer
    /// bit assignment as unsharded `native-packed`.
    fn wbits_plan(&self) -> Option<Vec<u32>> {
        self.inner.wbits_plan()
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        self.inner.prefill(prompt)
    }

    /// Batched admission prefill over the sharded linears: the inner
    /// datapath stacks the burst and each column-sharded GEMM fans out
    /// over the worker pool once per layer, so the per-GEMM dispatch/latch
    /// overhead amortizes over every admitted request. Per-request
    /// `shard_crit_s` is the burst's measured slowest-shard critical path
    /// split proportionally to token counts.
    fn prefill_batch(&mut self, prompts: &[&[i32]]) -> Result<Vec<PrefillOut>> {
        self.inner.prefill_batch(prompts)
    }

    fn supports_paged_prefill(&self) -> bool {
        true
    }

    /// Paged (prefix-cache) prefill over the sharded linears: the inner
    /// datapath computes only each request's uncached tail, with K/V
    /// appended into the paged cache and attention read back through it.
    /// Attention is unsharded, so prefix hits compose with any shard
    /// count bit-exactly.
    fn prefill_paged(
        &mut self,
        reqs: &[PagedPrefill<'_>],
        kv: &mut KvManager,
    ) -> Result<Vec<PagedPrefillOut>> {
        self.inner.prefill_paged(reqs, kv)
    }

    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> Result<(Vec<f32>, StepCost)> {
        self.inner.decode(toks, pos, active, kv)
    }

    /// Stacked speculative verification over the sharded linears: the
    /// inner datapath runs each stacked GEMM once per layer, fanned out
    /// over the shard pool — so a sharded target composes with the
    /// speculative backend bit-exactly (attention is unsharded).
    fn verify_paged(
        &mut self,
        runs: &[VerifyRun<'_>],
        kv: &mut KvManager,
    ) -> Result<(Vec<Vec<f32>>, StepCost)> {
        self.inner.verify_paged(runs, kv)
    }
}
