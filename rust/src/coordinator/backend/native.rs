//! The native K-Means WAQ decode backend: the paper's datapath as a real,
//! servable execution engine — no PJRT, no artifacts, measured throughput.
//!
//! Construction quantizes a `ParamSet` end to end:
//!   1. a short full-precision calibration forward over seeded random
//!      tokens records the pre-GEMM activations of every linear (the
//!      offline calibration the paper's scheme assumes);
//!   2. each linear gets a K-Means weight quantization at its planned
//!      bit-width (`quant::quantize_weights_grouped`: uniform `--wbits`,
//!      or the calibration-driven per-linear plan of `--wbits auto`, with
//!      FineQuant-style per-group scales along the reduction dimension),
//!      an activation codebook learned from its calibration rows
//!      (`quant::learn_act_codebook`), and the Cartesian-product LUT of
//!      both codebooks;
//!   3. weights are stored in the form the configured [`WaqBackend`]
//!      streams (a 2/3/4-bit [`crate::quant::PackedStream`] form for
//!      `Packed` — the density follows the codebook width).
//!
//! Serving then runs every linear through the dual-branch WAQ LUT-GEMM:
//! online per-token quantization with Orizuru outlier detection
//! (`orizuru::detect_outliers`), the main branch batched across slots via
//! `WaqGemm::execute_batch` (the packed/tiled/threaded kernel), and the
//! detected outliers routed through the error-compensation branch
//! (`gemm::compensate`). Admission bursts take the same batched shape:
//! `prefill_batch` stacks every prompt's token rows into one activation
//! matrix and runs each linear once per layer for the whole burst
//! (`prefill` is a burst of one), so LUT builds, weight-tile streaming,
//! and thread fan-out amortize over the burst exactly as they do over a
//! decode batch. Embeddings, norms, attention arithmetic, and
//! the tied LM head stay FP32, matching the paper (only GEMM layers are
//! quantized) — but decode attention *reads* K/V through the paged
//! cache's block-table gather (`KvManager::key_scores`/`value_mix`) and
//! appends each new token's rows in place, so when the engine serves an
//! n-bit cache (`--kv-bits 4|3|2`) the dominant long-context traffic is
//! index-domain too, with dequant fused into the dot/mix loops.
//!
//! The packed and direct kernels are bit-exact and the compensation math
//! is identical across weight forms, so `native-packed` and
//! `native-direct` produce bit-identical logits. `native-histogram`
//! groups float accumulation by LUT entry instead of by k, so its logits
//! agree only to float-reassociation tolerance (see
//! `gemm::waq::execute_histogram`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::{
    batch_occupancy, BackendSpec, CostModel, DecodeBackend, PagedPrefill, PagedPrefillOut,
    PrefillOut, StepCost, VerifyRun,
};
use crate::coordinator::kv::KvManager;
use crate::gemm::{CartesianLut, ShardPool, ShardedWaqGemm, WaqBackend, WaqGemm};
use crate::kvcache::KvQuantizer;
use crate::orizuru;
use crate::quant::{self, Codebook, OutlierCfg, QuantToken};
use crate::runtime::artifacts::ModelCfg;
use crate::runtime::{HostTensor, Manifest, ParamSet};
use crate::sim::OasisMode;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// Weight bit-width policy of the quantized linears (`--wbits`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WbitsSpec {
    /// One codebook width for every linear (`--wbits 2|3|4`).
    Uniform(u32),
    /// Calibration-driven per-linear plan (`--wbits auto`): construction
    /// records each linear's output MSE under 2/3/4-bit codebooks on its
    /// calibration rows, then `quant::plan_bits` spends an average-bits
    /// budget (`--wbits-budget`) greedily where the sensitivity is.
    Auto { budget: f64 },
}

/// Quantization configuration of the native backend.
#[derive(Clone, Copy, Debug)]
pub struct NativeCfg {
    /// Which software WAQ GEMM kernel executes the main branch.
    pub waq: WaqBackend,
    /// Weight bit-width policy: uniform, or planned per linear.
    pub wbits: WbitsSpec,
    /// Reduction-dimension rows sharing one weight scale (FineQuant-style
    /// per-group scales; must be a multiple of 4, `0` = one scale per
    /// column). Matrices shorter than the group size get a single group,
    /// which is numerically identical to the ungrouped path.
    pub w_group: usize,
    pub a_bits: u32,
    pub outlier: OutlierCfg,
    /// Modeled-clock schedule: look-ahead OASIS (true) vs critical-path
    /// OASIS-C (false). Affects reported costs only — the native datapath
    /// always executes the look-ahead dataflow.
    pub lookahead: bool,
    /// Calibration sequence length (clamped to [2, seq_len]).
    pub calib_tokens: usize,
    pub calib_seed: u64,
}

impl Default for NativeCfg {
    fn default() -> Self {
        NativeCfg {
            waq: WaqBackend::default(),
            wbits: WbitsSpec::Uniform(4),
            w_group: 128,
            a_bits: 4,
            outlier: OutlierCfg::default(),
            lookahead: true,
            calib_tokens: 24,
            calib_seed: 0xCA11B,
        }
    }
}

impl NativeCfg {
    /// Derive the quantization knobs from the engine's OASIS mode so the
    /// native datapath and the modeled clock describe the same scheme.
    pub fn from_mode(waq: WaqBackend, mode: OasisMode) -> NativeCfg {
        NativeCfg {
            waq,
            a_bits: mode.n_a_bits,
            outlier: OutlierCfg { total_frac: mode.outlier_frac },
            lookahead: mode.lookahead,
            ..NativeCfg::default()
        }
    }
}

/// How a quantized linear executes its dual-branch GEMM: one fused kernel
/// call, or `S` tensor-parallel column shards on a persistent worker pool
/// (bit-exact with each other — see `gemm::sharded`).
enum GemmExec {
    Mono(WaqGemm),
    Sharded(ShardedWaqGemm),
}

/// One quantized linear: prepared WAQ GEMM + its activation codebook.
struct QuantLinear {
    exec: GemmExec,
    cb: Codebook,
    k_per_side: usize,
}

impl QuantLinear {
    fn build(w: &Matrix, calib: &[Vec<f32>], cfg: &NativeCfg, w_bits: u32) -> QuantLinear {
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cb = quant::learn_act_codebook(&refs, None, cfg.a_bits, cfg.outlier);
        let qw = quant::quantize_weights_grouped(w, None, w_bits, cfg.w_group);
        let lut = CartesianLut::build(&cb, &qw.codebook);
        QuantLinear {
            k_per_side: cfg.outlier.k_per_side(w.rows),
            exec: GemmExec::Mono(WaqGemm::new(qw, lut, cfg.waq)),
            cb,
        }
    }

    /// Split the GEMM into `shards` column shards executed on `pool`
    /// (`ShardedWaqBackend` construction). Requires the packed kernel —
    /// the shards stream column slices of the packed form at whatever
    /// stream width (2/3/4-bit) the linear's plan chose.
    fn shard(&mut self, shards: usize, pool: &Arc<ShardPool>) -> Result<()> {
        let GemmExec::Mono(gemm) = &self.exec else {
            bail!("linear is already sharded");
        };
        let Some(pw) = gemm.packed_weights() else {
            bail!("sharding requires the packed WAQ kernel");
        };
        let sharded = ShardedWaqGemm::from_packed(pw, &gemm.lut, shards, pool.clone())
            .map_err(anyhow::Error::msg)?;
        self.exec = GemmExec::Sharded(sharded);
        Ok(())
    }

    /// Dual-branch forward for a batch of token rows: Orizuru detection,
    /// online K-Means quantization, main-branch LUT-GEMM across the whole
    /// batch, then per-token outlier compensation (inside each shard's
    /// column range for the sharded executor, which also adds its
    /// slowest-shard wall-clock to `shard_crit_ns`).
    fn forward(
        &self,
        xs: &[Vec<f32>],
        outliers_seen: &AtomicU64,
        shard_crit_ns: &mut u64,
    ) -> Vec<Vec<f32>> {
        let toks: Vec<QuantToken> = xs
            .iter()
            .map(|x| {
                let outs = orizuru::detect_outliers(x, self.k_per_side);
                outliers_seen.fetch_add(outs.len() as u64, Ordering::Relaxed);
                quant::quantize_token_with_outliers(x, &self.cb, &outs)
            })
            .collect();
        match &self.exec {
            GemmExec::Mono(gemm) => {
                let mut out = gemm.execute_batch(&toks);
                for (o, t) in out.iter_mut().zip(&toks) {
                    gemm.compensate(o, t);
                }
                out
            }
            GemmExec::Sharded(sh) => {
                let mut out: Vec<Vec<f32>> =
                    toks.iter().map(|_| vec![0.0f32; sh.n_cols()]).collect();
                *shard_crit_ns += sh.execute_batch_into(&toks, &mut out);
                out
            }
        }
    }
}

struct Layer {
    ln1: Vec<f32>,
    ln2: Vec<f32>,
    qkv: QuantLinear,
    attn_out: QuantLinear,
    mlp_up: QuantLinear,
    mlp_down: QuantLinear,
}

pub struct NativeWaqBackend {
    model: ModelCfg,
    waq: WaqBackend,
    cost: CostModel,
    tok_emb: Matrix,
    pos_emb: Matrix,
    lnf: Vec<f32>,
    layers: Vec<Layer>,
    /// Calibration K/V rows per `[layer * n_heads + head]` (each row
    /// `head_dim` long), retained so `kv_quantizer` can learn
    /// per-layer/per-head cache codebooks at any requested bit-width —
    /// callers may ask repeatedly and at different widths, so the rows
    /// outlive construction. At this repro's model scales that is a few
    /// hundred KB; a production port should drop them once the engine
    /// has built its cache (or memoize books per width).
    kv_calib_k: Vec<Vec<Vec<f32>>>,
    kv_calib_v: Vec<Vec<Vec<f32>>>,
    /// Total outlier fraction for the cache's Orizuru escape hatch
    /// (same knob as the activation path's `OutlierCfg`).
    kv_outlier_frac: f64,
    /// Total outlier channels routed through the compensation branch.
    outliers_seen: Arc<AtomicU64>,
    /// Per-linear weight bit-widths actually served (layer-major: qkv,
    /// attn_out, mlp_up, mlp_down) — the flat plan under uniform
    /// `--wbits`, the calibration-driven plan under `--wbits auto`.
    bit_plan: Vec<u32>,
}

impl NativeWaqBackend {
    /// Quantize `params` into a servable native model. Only the manifest's
    /// model config and parameter order are used — no artifacts on disk.
    pub fn new(manifest: &Manifest, params: &ParamSet, cfg: NativeCfg) -> Result<NativeWaqBackend> {
        let m = manifest.model;
        if m.n_heads * m.head_dim != m.d_model {
            bail!("inconsistent model config: {} heads x {} != d_model {}",
                  m.n_heads, m.head_dim, m.d_model);
        }
        let get_mat = |name: &str, rows: usize, cols: usize| -> Result<Matrix> {
            let t = param(manifest, params, name, &[rows, cols])?;
            Ok(Matrix::from_vec(rows, cols, t.as_f32()?.to_vec()))
        };
        let get_vec = |name: &str, n: usize| -> Result<Vec<f32>> {
            Ok(param(manifest, params, name, &[n])?.as_f32()?.to_vec())
        };

        let (d, ff) = (m.d_model, m.d_ff);
        let tok_emb = get_mat("tok_emb", m.vocab, d)?;
        let pos_emb = get_mat("pos_emb", m.seq_len, d)?;
        let lnf = get_vec("lnf", d)?;
        struct FpLayer {
            ln1: Vec<f32>,
            ln2: Vec<f32>,
            qkv: Matrix,
            attn_out: Matrix,
            mlp_up: Matrix,
            mlp_down: Matrix,
        }
        let fp_layers = (0..m.n_layers)
            .map(|l| {
                Ok(FpLayer {
                    ln1: get_vec(&format!("l{l}.ln1"), d)?,
                    ln2: get_vec(&format!("l{l}.ln2"), d)?,
                    qkv: get_mat(&format!("l{l}.qkv"), d, 3 * d)?,
                    attn_out: get_mat(&format!("l{l}.attn_out"), d, d)?,
                    mlp_up: get_mat(&format!("l{l}.mlp_up"), d, ff)?,
                    mlp_down: get_mat(&format!("l{l}.mlp_down"), ff, d)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        // --- FP calibration forward: pre-GEMM activations per linear ----
        let n = cfg.calib_tokens.clamp(2, m.seq_len);
        let mut rng = Rng::new(cfg.calib_seed);
        let mut x = Matrix::zeros(n, d);
        for t in 0..n {
            let tok = rng.below(m.vocab);
            embed_into(x.row_mut(t), &tok_emb, &pos_emb, tok, t);
        }
        let mut taps: Vec<[Vec<Vec<f32>>; 4]> = Vec::with_capacity(m.n_layers);
        // per-(layer, head) calibration K/V rows for the KV-cache codebooks
        let mut kv_calib_k: Vec<Vec<Vec<f32>>> = Vec::with_capacity(m.n_layers * m.n_heads);
        let mut kv_calib_v: Vec<Vec<Vec<f32>>> = Vec::with_capacity(m.n_layers * m.n_heads);
        for fl in &fp_layers {
            let xn = Matrix::from_vec(n, d, rms_rows(&x, &fl.ln1).concat());
            let qkv = xn.matmul(&fl.qkv);
            let (h, hd) = (m.n_heads, m.head_dim);
            for head in 0..h {
                let k_rows = (0..n)
                    .map(|t| qkv.row(t)[d + head * hd..d + (head + 1) * hd].to_vec())
                    .collect();
                let v_rows = (0..n)
                    .map(|t| qkv.row(t)[2 * d + head * hd..2 * d + (head + 1) * hd].to_vec())
                    .collect();
                kv_calib_k.push(k_rows);
                kv_calib_v.push(v_rows);
            }
            let att = causal_attention(&qkv, m.n_heads, m.head_dim);
            add_matrix(&mut x, &att.matmul(&fl.attn_out));
            let xn2 = Matrix::from_vec(n, d, rms_rows(&x, &fl.ln2).concat());
            let mut hmid = xn2.matmul(&fl.mlp_up);
            for v in hmid.data.iter_mut() {
                *v = gelu(*v);
            }
            add_matrix(&mut x, &hmid.matmul(&fl.mlp_down));
            taps.push([mat_rows(&xn), mat_rows(&att), mat_rows(&xn2), mat_rows(&hmid)]);
        }

        // --- per-linear bit plan (layer-major: qkv, attn_out, mlp_up,
        // mlp_down) -------------------------------------------------------
        let bit_plan: Vec<u32> = match cfg.wbits {
            WbitsSpec::Uniform(b) => {
                if !(2..=4).contains(&b) {
                    bail!("--wbits must be 2, 3, 4, or auto (got {b})");
                }
                vec![b; 4 * m.n_layers]
            }
            WbitsSpec::Auto { budget } => {
                if !(2.0..=4.0).contains(&budget) {
                    bail!("--wbits-budget must lie in [2, 4] (got {budget})");
                }
                if let Some(plan) = &manifest.wbits_plan {
                    // a manifest that already carries a plan pins it:
                    // re-serving reproduces the exact mixed-precision
                    // assignment without re-running sensitivity planning
                    if plan.len() != 4 * m.n_layers {
                        bail!(
                            "manifest wbits_plan has {} entries, model needs {}",
                            plan.len(),
                            4 * m.n_layers
                        );
                    }
                    plan.clone()
                } else {
                    // sensitivity table: each linear's output MSE on its
                    // own calibration rows under 2/3/4-bit codebooks
                    let mut mse = Vec::with_capacity(4 * m.n_layers);
                    let mut sizes = Vec::with_capacity(4 * m.n_layers);
                    for (fl, t) in fp_layers.iter().zip(&taps) {
                        let lins = [
                            (&fl.qkv, &t[0]),
                            (&fl.attn_out, &t[1]),
                            (&fl.mlp_up, &t[2]),
                            (&fl.mlp_down, &t[3]),
                        ];
                        for (w, rows) in lins {
                            mse.push(linear_sensitivity(w, rows, cfg.w_group));
                            sizes.push(w.rows * w.cols);
                        }
                    }
                    quant::plan_bits(&mse, &sizes, budget)
                }
            }
        };

        // --- quantize every linear against its calibration rows ---------
        let layers: Vec<Layer> = fp_layers
            .into_iter()
            .zip(&taps)
            .enumerate()
            .map(|(l, (fl, t))| Layer {
                qkv: QuantLinear::build(&fl.qkv, &t[0], &cfg, bit_plan[4 * l]),
                attn_out: QuantLinear::build(&fl.attn_out, &t[1], &cfg, bit_plan[4 * l + 1]),
                mlp_up: QuantLinear::build(&fl.mlp_up, &t[2], &cfg, bit_plan[4 * l + 2]),
                mlp_down: QuantLinear::build(&fl.mlp_down, &t[3], &cfg, bit_plan[4 * l + 3]),
                ln1: fl.ln1,
                ln2: fl.ln2,
            })
            .collect();

        let mode = OasisMode {
            n_a_bits: cfg.a_bits,
            outlier_frac: cfg.outlier.total_frac,
            lookahead: cfg.lookahead,
        };
        Ok(NativeWaqBackend {
            model: m,
            waq: cfg.waq,
            cost: CostModel::new(m, mode, cfg.waq),
            tok_emb,
            pos_emb,
            lnf,
            layers,
            kv_calib_k,
            kv_calib_v,
            kv_outlier_frac: cfg.outlier.total_frac,
            outliers_seen: Arc::new(AtomicU64::new(0)),
            bit_plan,
        })
    }

    /// Handle to the running count of outlier channels routed through the
    /// compensation branch (clone before boxing into an engine).
    pub fn outlier_counter(&self) -> Arc<AtomicU64> {
        self.outliers_seen.clone()
    }

    /// Split every quantized linear into `shards` tensor-parallel column
    /// shards executed on `pool` (see `gemm::sharded`) — the
    /// `ShardedWaqBackend` construction step. Embeddings, norms,
    /// attention, and the KV cache stay unsharded; only the WAQ LUT-GEMM
    /// linears are split, so logits remain bit-identical to the unsharded
    /// packed datapath.
    pub(crate) fn shard_linears(&mut self, shards: usize, pool: &Arc<ShardPool>) -> Result<()> {
        for layer in self.layers.iter_mut() {
            layer.qkv.shard(shards, pool)?;
            layer.attn_out.shard(shards, pool)?;
            layer.mlp_up.shard(shards, pool)?;
            layer.mlp_down.shard(shards, pool)?;
        }
        Ok(())
    }

    /// Tied-embedding LM head on one final-norm row (kept FP32).
    fn head_logits(&self, hn: &[f32]) -> Vec<f32> {
        (0..self.model.vocab)
            .map(|v| dot(hn, self.tok_emb.row(v)))
            .collect()
    }

    /// Run one quantized linear and charge its wall-clock to `waq_ns` —
    /// the measured WAQ-datapath seconds exclude the FP attention/norm/
    /// LM-head work, so they stay comparable to `CpuWaqModel`'s modeled
    /// GEMM-only roofline. `crit_ns` collects the slowest-shard critical
    /// path when the linears are sharded (0 for the mono executor).
    fn quant_forward(
        &self,
        lin: &QuantLinear,
        xs: &[Vec<f32>],
        waq_ns: &mut u64,
        crit_ns: &mut u64,
    ) -> Vec<Vec<f32>> {
        let t0 = Instant::now();
        let out = lin.forward(xs, &self.outliers_seen, crit_ns);
        *waq_ns += t0.elapsed().as_nanos() as u64;
        out
    }
}

impl DecodeBackend for NativeWaqBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Native(self.waq)
    }

    fn model(&self) -> ModelCfg {
        self.model
    }

    fn wbits_plan(&self) -> Option<Vec<u32>> {
        Some(self.bit_plan.clone())
    }

    /// Per-layer/per-head cache codebooks learned from the same FP
    /// calibration forward that trained the activation codebooks (the
    /// K/V rows were retained at construction). The Orizuru escape hatch
    /// inherits the backend's outlier fraction: `floor(frac * hd / 2)`
    /// FP-preserved channels per side per row — zero until `frac * hd / 2
    /// >= 1` (hd >= 200 at the paper's 1% fraction; see
    /// `KvQuantizer::with_outlier_frac`), so small-head presets keep the
    /// full 4x bytes/token win.
    fn kv_quantizer(&self, bits: u32) -> KvQuantizer {
        KvQuantizer::from_calibration(
            self.model.n_heads,
            self.model.head_dim,
            bits,
            &self.kv_calib_k,
            &self.kv_calib_v,
        )
        .with_outlier_frac(self.kv_outlier_frac)
    }

    /// Single-request prefill is a burst of one: the batched path is the
    /// only implementation, so sequential and batched prefill cannot
    /// diverge (per-row accumulation order is identical by construction;
    /// the parity property test pins it anyway).
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let mut outs = self.prefill_batch(&[prompt])?;
        outs.pop().ok_or_else(|| anyhow!("prefill_batch returned no result"))
    }

    /// The genuinely batched admission path: every prompt's token rows are
    /// stacked (request-major) into ONE activation matrix, each WAQ
    /// LUT-GEMM linear runs once per layer for the whole burst through the
    /// packed/tiled (or sharded) executor, and causal attention + K/V
    /// extraction run per request over its own row range — ragged prompt
    /// lengths are handled by a row-offset map. Per-row quantization and
    /// accumulation are independent of batch composition, so each
    /// request's logits and caches are bit-exact with a solo `prefill`.
    ///
    /// Cost attribution: the modeled accelerator cost is per request
    /// (`CostModel::prefill(plen)`, identical to the sequential path, so
    /// the sim clock is batching-invariant); the *measured* host-WAQ and
    /// slowest-shard seconds are taken once for the burst and split
    /// proportionally to each request's token count.
    fn prefill_batch(&mut self, prompts: &[&[i32]]) -> Result<Vec<PrefillOut>> {
        let m = self.model;
        let (h, hd, d, s) = (m.n_heads, m.head_dim, m.d_model, m.seq_len);
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        // clamp into the context window; an empty prompt degrades to the
        // pad token (mirrors the PJRT backend)
        let plens: Vec<usize> = prompts.iter().map(|p| p.len().clamp(1, s - 1)).collect();
        // row-offset map: request r owns stacked rows offs[r]..offs[r]+plens[r]
        let mut offs = Vec::with_capacity(plens.len());
        let mut total = 0usize;
        for &plen in &plens {
            offs.push(total);
            total += plen;
        }
        let mut x = Matrix::zeros(total, d);
        for (r, prompt) in prompts.iter().enumerate() {
            for t in 0..plens[r] {
                let tok = prompt.get(t).map_or(0, |&v| v.rem_euclid(m.vocab as i32)) as usize;
                embed_into(x.row_mut(offs[r] + t), &self.tok_emb, &self.pos_emb, tok, t);
            }
        }
        let mut kcs: Vec<Vec<f32>> =
            plens.iter().map(|_| vec![0f32; m.n_layers * h * s * hd]).collect();
        let mut vcs: Vec<Vec<f32>> =
            plens.iter().map(|_| vec![0f32; m.n_layers * h * s * hd]).collect();
        // measured WAQ-datapath nanoseconds across the burst's linears,
        // and the slowest-shard critical path when they are sharded
        let mut waq_ns = 0u64;
        let mut crit_ns = 0u64;
        for (l, layer) in self.layers.iter().enumerate() {
            let qkv_rows = self.quant_forward(
                &layer.qkv,
                &rms_rows(&x, &layer.ln1),
                &mut waq_ns,
                &mut crit_ns,
            );
            // per request: pull its K/V rows out and run causal attention
            // over its own row range only (attention never crosses
            // request boundaries)
            let mut att_rows: Vec<Vec<f32>> = Vec::with_capacity(total);
            for r in 0..plens.len() {
                let (off, n) = (offs[r], plens[r]);
                let qkv = Matrix::from_vec(n, 3 * d, qkv_rows[off..off + n].concat());
                for t in 0..n {
                    let row = qkv.row(t);
                    for head in 0..h {
                        let base = (l * h + head) * s * hd + t * hd;
                        kcs[r][base..base + hd]
                            .copy_from_slice(&row[d + head * hd..d + (head + 1) * hd]);
                        vcs[r][base..base + hd]
                            .copy_from_slice(&row[2 * d + head * hd..2 * d + (head + 1) * hd]);
                    }
                }
                let att = causal_attention(&qkv, h, hd);
                att_rows.extend(mat_rows(&att));
            }
            let proj = self.quant_forward(&layer.attn_out, &att_rows, &mut waq_ns, &mut crit_ns);
            add_rows(&mut x, &proj);
            let mut up = self.quant_forward(
                &layer.mlp_up,
                &rms_rows(&x, &layer.ln2),
                &mut waq_ns,
                &mut crit_ns,
            );
            for r in up.iter_mut() {
                for v in r.iter_mut() {
                    *v = gelu(*v);
                }
            }
            let down = self.quant_forward(&layer.mlp_down, &up, &mut waq_ns, &mut crit_ns);
            add_rows(&mut x, &down);
        }
        let shape = [m.n_layers, 1, h, s, hd];
        let host_s = waq_ns as f64 * 1e-9;
        let crit_s = crit_ns as f64 * 1e-9;
        let mut outs = Vec::with_capacity(plens.len());
        let mut hn = vec![0f32; d];
        for (r, (kc, vc)) in kcs.into_iter().zip(vcs).enumerate() {
            let (off, plen) = (offs[r], plens[r]);
            rms_into(x.row(off + plen - 1), &self.lnf, &mut hn);
            let logits = self.head_logits(&hn);
            // measured-once burst seconds, split by token share
            let frac = plen as f64 / total as f64;
            let mut cost = self.cost.prefill(plen);
            cost.host_waq_s = host_s * frac;
            cost.shard_crit_s = crit_s * frac;
            outs.push(PrefillOut {
                plen,
                logits,
                k_cache: HostTensor::f32(kc, &shape),
                v_cache: HostTensor::f32(vc, &shape),
                cost,
            });
        }
        Ok(outs)
    }

    fn supports_paged_prefill(&self) -> bool {
        true
    }

    /// Prefill through the paged cache: each request's *uncached tail*
    /// rows are stacked (request-major) into one activation matrix and
    /// every WAQ LUT-GEMM linear runs once per layer for the burst, like
    /// `prefill_batch` — but K/V rows are appended straight into the
    /// slot's block tables and each tail row's attention reads the cache
    /// through the same fused-dequant gathers decode uses
    /// (`key_scores`/`value_mix`, identical softmax shape). Cached prefix
    /// positions are never recomputed and never requantized: a cold run
    /// and a prefix-hit run read identical stored payloads, so their
    /// logits are bit-exact at every `--kv-bits`. At FP32 storage the
    /// gathers reproduce `causal_attention`'s accumulation order, keeping
    /// this path bit-exact with the dense `prefill_batch` too.
    ///
    /// Chunk/resume contract (the iteration-level scheduler's seam): a
    /// *chunk* is simply a call with `prompt` sliced to the chunk end and
    /// `cached` at the resume cursor — each tail row `t` computes at
    /// absolute position `cached + t` attending over `0..=cached + t`,
    /// so the per-row float sequence is identical whether the prompt
    /// arrives whole or split across any number of calls (row
    /// independence). Only the final chunk's last-position logits are
    /// sampled; intermediate chunks' logits are discarded by the engine.
    fn prefill_paged(
        &mut self,
        reqs: &[PagedPrefill<'_>],
        kv: &mut KvManager,
    ) -> Result<Vec<PagedPrefillOut>> {
        let m = self.model;
        let (h, hd, d, s) = (m.n_heads, m.head_dim, m.d_model, m.seq_len);
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let plens: Vec<usize> = reqs.iter().map(|r| r.prompt.len().clamp(1, s - 1)).collect();
        for (r, req) in reqs.iter().enumerate() {
            if req.cached >= plens[r] {
                bail!(
                    "paged prefill: cached {} must leave a tail (plen {})",
                    req.cached,
                    plens[r]
                );
            }
        }
        let tails: Vec<usize> = reqs.iter().zip(&plens).map(|(r, &p)| p - r.cached).collect();
        // row-offset map over the stacked *tail* rows only
        let mut offs = Vec::with_capacity(tails.len());
        let mut total = 0usize;
        for &t in &tails {
            offs.push(total);
            total += t;
        }
        let mut x = Matrix::zeros(total, d);
        for (r, req) in reqs.iter().enumerate() {
            for t in 0..tails[r] {
                let p = req.cached + t;
                let tok =
                    req.prompt.get(p).map_or(0, |&v| v.rem_euclid(m.vocab as i32)) as usize;
                embed_into(x.row_mut(offs[r] + t), &self.tok_emb, &self.pos_emb, tok, p);
            }
        }
        let mut waq_ns = 0u64;
        let mut crit_ns = 0u64;
        let scale = 1.0 / (hd as f32).sqrt();
        for (l, layer) in self.layers.iter().enumerate() {
            let qkv_rows = self.quant_forward(
                &layer.qkv,
                &rms_rows(&x, &layer.ln1),
                &mut waq_ns,
                &mut crit_ns,
            );
            let mut att_rows: Vec<Vec<f32>> = Vec::with_capacity(total);
            for (r, req) in reqs.iter().enumerate() {
                for t in 0..tails[r] {
                    let p = req.cached + t;
                    let row = &qkv_rows[offs[r] + t];
                    kv.append_token(l, req.slot, p, &row[d..2 * d], &row[2 * d..3 * d])
                        .map_err(|e| anyhow!("kv append: {e}"))?;
                    // same attention shape as decode: gather, scale, max,
                    // exp, normalize, mix — over cache positions 0..=p
                    let mut att = vec![0f32; d];
                    let mut scores = vec![0f32; p + 1];
                    for head in 0..h {
                        let q = &row[head * hd..(head + 1) * hd];
                        kv.key_scores(l, req.slot, head, p + 1, q, &mut scores);
                        let mut maxv = f32::NEG_INFINITY;
                        for sc in scores.iter_mut() {
                            *sc *= scale;
                            maxv = maxv.max(*sc);
                        }
                        let mut denom = 0f32;
                        for sc in scores.iter_mut() {
                            *sc = (*sc - maxv).exp();
                            denom += *sc;
                        }
                        let inv = 1.0 / denom;
                        for sc in scores.iter_mut() {
                            *sc *= inv;
                        }
                        let orow = &mut att[head * hd..(head + 1) * hd];
                        kv.value_mix(l, req.slot, head, p + 1, &scores, orow);
                    }
                    att_rows.push(att);
                }
            }
            let proj =
                self.quant_forward(&layer.attn_out, &att_rows, &mut waq_ns, &mut crit_ns);
            add_rows(&mut x, &proj);
            let mut up = self.quant_forward(
                &layer.mlp_up,
                &rms_rows(&x, &layer.ln2),
                &mut waq_ns,
                &mut crit_ns,
            );
            for r in up.iter_mut() {
                for v in r.iter_mut() {
                    *v = gelu(*v);
                }
            }
            let down = self.quant_forward(&layer.mlp_down, &up, &mut waq_ns, &mut crit_ns);
            add_rows(&mut x, &down);
        }
        let host_s = waq_ns as f64 * 1e-9;
        let crit_s = crit_ns as f64 * 1e-9;
        let mut outs = Vec::with_capacity(reqs.len());
        let mut hn = vec![0f32; d];
        for r in 0..reqs.len() {
            // the last tail row sits at absolute position plen - 1
            rms_into(x.row(offs[r] + tails[r] - 1), &self.lnf, &mut hn);
            let logits = self.head_logits(&hn);
            let frac = tails[r] as f64 / total as f64;
            // modeled and measured cost both cover only the computed tail
            let mut cost = self.cost.prefill(tails[r]);
            cost.host_waq_s = host_s * frac;
            cost.shard_crit_s = crit_s * frac;
            outs.push(PagedPrefillOut { plen: plens[r], logits, cost });
        }
        Ok(outs)
    }

    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> Result<(Vec<f32>, StepCost)> {
        let m = self.model;
        let b = m.decode_batch;
        if toks.len() != b || pos.len() != b || active.len() != b {
            bail!("decode arity mismatch: expected {b} slots");
        }
        // measured WAQ-datapath nanoseconds (LUT-GEMM linears only), and
        // the slowest-shard critical path when the linears are sharded
        let mut waq_ns = 0u64;
        let mut crit_ns = 0u64;
        let (h, hd, d, s) = (m.n_heads, m.head_dim, m.d_model, m.seq_len);
        let slots: Vec<usize> = (0..b).filter(|&i| active[i]).collect();
        let mut out = vec![0f32; b * m.vocab];
        if slots.is_empty() {
            let mut cost = self.cost.decode(0, 0);
            cost.host_waq_s = 0.0;
            return Ok((out, cost));
        }
        let mut xs: Vec<Vec<f32>> = slots
            .iter()
            .map(|&i| {
                let tok = toks[i].rem_euclid(m.vocab as i32) as usize;
                let p = (pos[i] as usize).min(s - 1);
                let mut row = vec![0f32; d];
                embed_into(&mut row, &self.tok_emb, &self.pos_emb, tok, p);
                row
            })
            .collect();
        for (l, layer) in self.layers.iter().enumerate() {
            let xn = rms_vecs(&xs, &layer.ln1);
            let qkv = self.quant_forward(&layer.qkv, &xn, &mut waq_ns, &mut crit_ns);
            let mut att_rows: Vec<Vec<f32>> = Vec::with_capacity(slots.len());
            for (bi, &slot) in slots.iter().enumerate() {
                // no clamp: the paged cache's own bounds/protocol checks
                // produce the precise diagnostic for a bad position
                let p = pos[slot] as usize;
                let row = &qkv[bi];
                // append this token's K/V at its cache position (the paged
                // store quantizes in place when serving an n-bit cache)
                kv.append_token(l, slot, p, &row[d..2 * d], &row[2 * d..3 * d])
                    .map_err(|e| anyhow!("kv append: {e}"))?;
                // causal attention over cache positions 0..=p, K/V read
                // through the block-table gather with fused dequant
                let scale = 1.0 / (hd as f32).sqrt();
                let mut att = vec![0f32; d];
                let mut scores = vec![0f32; p + 1];
                for head in 0..h {
                    let q = &row[head * hd..(head + 1) * hd];
                    kv.key_scores(l, slot, head, p + 1, q, &mut scores);
                    let mut maxv = f32::NEG_INFINITY;
                    for sc in scores.iter_mut() {
                        *sc *= scale;
                        maxv = maxv.max(*sc);
                    }
                    let mut denom = 0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - maxv).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    for sc in scores.iter_mut() {
                        *sc *= inv;
                    }
                    let orow = &mut att[head * hd..(head + 1) * hd];
                    kv.value_mix(l, slot, head, p + 1, &scores, orow);
                }
                att_rows.push(att);
            }
            let proj =
                self.quant_forward(&layer.attn_out, &att_rows, &mut waq_ns, &mut crit_ns);
            for (x, pr) in xs.iter_mut().zip(&proj) {
                add_into(x, pr);
            }
            let xn2 = rms_vecs(&xs, &layer.ln2);
            let mut up = self.quant_forward(&layer.mlp_up, &xn2, &mut waq_ns, &mut crit_ns);
            for r in up.iter_mut() {
                for v in r.iter_mut() {
                    *v = gelu(*v);
                }
            }
            let down = self.quant_forward(&layer.mlp_down, &up, &mut waq_ns, &mut crit_ns);
            for (x, dn) in xs.iter_mut().zip(&down) {
                add_into(x, dn);
            }
        }
        let mut hn = vec![0f32; d];
        for (bi, &slot) in slots.iter().enumerate() {
            rms_into(&xs[bi], &self.lnf, &mut hn);
            out[slot * m.vocab..(slot + 1) * m.vocab]
                .copy_from_slice(&self.head_logits(&hn));
        }
        let (active_n, mean_ctx) = batch_occupancy(pos, active);
        let mut cost = self.cost.decode(active_n, mean_ctx);
        // measured, not modeled: wall-clock of the WAQ LUT-GEMM linears
        // (quantize + main branch + compensation), the datapath the
        // CpuWaqModel roofline models for the PJRT backend
        cost.host_waq_s = waq_ns as f64 * 1e-9;
        cost.shard_crit_s = crit_ns as f64 * 1e-9;
        Ok((out, cost))
    }

    /// Stacked verification: every run's token rows go into ONE activation
    /// matrix (run-major) and each WAQ LUT-GEMM linear streams its weights
    /// once per layer for the whole stack — the amortization speculative
    /// decoding rides on (k+1 positions scored for one weight pass).
    /// Structurally this is `prefill_paged` with (a) arbitrary start
    /// positions, (b) logits computed at *every* row, and (c) decode-style
    /// modeled cost. Per-row quantization and accumulation are independent
    /// of stacking, and each row's attention reads the paged cache over
    /// `0..=start + j` with the exact gather/scale/max/exp/normalize
    /// sequence `decode` uses — so row `j`'s logits are bit-exact with a
    /// plain decode of the same token at the same position.
    fn verify_paged(
        &mut self,
        runs: &[VerifyRun<'_>],
        kv: &mut KvManager,
    ) -> Result<(Vec<Vec<f32>>, StepCost)> {
        let m = self.model;
        let (h, hd, d, s) = (m.n_heads, m.head_dim, m.d_model, m.seq_len);
        if runs.is_empty() {
            return Ok((Vec::new(), StepCost::default()));
        }
        let lens: Vec<usize> = runs.iter().map(|r| r.tokens.len()).collect();
        for (run, &len) in runs.iter().zip(&lens) {
            if len == 0 {
                bail!("verify run for slot {} has no tokens", run.slot);
            }
            if run.start + len > s {
                bail!(
                    "verify run for slot {} overruns the context window ({} + {len} > {s})",
                    run.slot,
                    run.start
                );
            }
        }
        // row-offset map over the stacked rows (run-major)
        let mut offs = Vec::with_capacity(lens.len());
        let mut total = 0usize;
        for &len in &lens {
            offs.push(total);
            total += len;
        }
        let mut x = Matrix::zeros(total, d);
        for (r, run) in runs.iter().enumerate() {
            for (j, &t) in run.tokens.iter().enumerate() {
                let tok = t.rem_euclid(m.vocab as i32) as usize;
                let row = x.row_mut(offs[r] + j);
                embed_into(row, &self.tok_emb, &self.pos_emb, tok, run.start + j);
            }
        }
        let mut waq_ns = 0u64;
        let mut crit_ns = 0u64;
        let scale = 1.0 / (hd as f32).sqrt();
        for (l, layer) in self.layers.iter().enumerate() {
            let qkv_rows = self.quant_forward(
                &layer.qkv,
                &rms_rows(&x, &layer.ln1),
                &mut waq_ns,
                &mut crit_ns,
            );
            let mut att_rows: Vec<Vec<f32>> = Vec::with_capacity(total);
            for (r, run) in runs.iter().enumerate() {
                for j in 0..lens[r] {
                    let p = run.start + j;
                    let row = &qkv_rows[offs[r] + j];
                    kv.append_token(l, run.slot, p, &row[d..2 * d], &row[2 * d..3 * d])
                        .map_err(|e| anyhow!("kv append: {e}"))?;
                    let mut att = vec![0f32; d];
                    let mut scores = vec![0f32; p + 1];
                    for head in 0..h {
                        let q = &row[head * hd..(head + 1) * hd];
                        kv.key_scores(l, run.slot, head, p + 1, q, &mut scores);
                        let mut maxv = f32::NEG_INFINITY;
                        for sc in scores.iter_mut() {
                            *sc *= scale;
                            maxv = maxv.max(*sc);
                        }
                        let mut denom = 0f32;
                        for sc in scores.iter_mut() {
                            *sc = (*sc - maxv).exp();
                            denom += *sc;
                        }
                        let inv = 1.0 / denom;
                        for sc in scores.iter_mut() {
                            *sc *= inv;
                        }
                        let orow = &mut att[head * hd..(head + 1) * hd];
                        kv.value_mix(l, run.slot, head, p + 1, &scores, orow);
                    }
                    att_rows.push(att);
                }
            }
            let proj =
                self.quant_forward(&layer.attn_out, &att_rows, &mut waq_ns, &mut crit_ns);
            add_rows(&mut x, &proj);
            let mut up = self.quant_forward(
                &layer.mlp_up,
                &rms_rows(&x, &layer.ln2),
                &mut waq_ns,
                &mut crit_ns,
            );
            for r in up.iter_mut() {
                for v in r.iter_mut() {
                    *v = gelu(*v);
                }
            }
            let down = self.quant_forward(&layer.mlp_down, &up, &mut waq_ns, &mut crit_ns);
            add_rows(&mut x, &down);
        }
        let mut logits = Vec::with_capacity(runs.len());
        let mut hn = vec![0f32; d];
        for (r, &len) in lens.iter().enumerate() {
            let mut rows = Vec::with_capacity(len * m.vocab);
            for j in 0..len {
                rms_into(x.row(offs[r] + j), &self.lnf, &mut hn);
                rows.extend(self.head_logits(&hn));
            }
            logits.push(rows);
        }
        // modeled cost: depth level j of the stack is one decode step over
        // the runs still alive at that depth (what a sequential engine
        // would have paid); the measured host seconds show the stacking's
        // actual amortization
        let mut cost = StepCost::default();
        let max_len = lens.iter().copied().max().unwrap_or(0);
        for j in 0..max_len {
            let mut n = 0usize;
            let mut ctx = 0usize;
            for (run, &len) in runs.iter().zip(&lens) {
                if len > j {
                    n += 1;
                    ctx += run.start + j;
                }
            }
            let c = self.cost.decode(n, ctx / n.max(1));
            cost.accel_s += c.accel_s;
            cost.accel_j += c.accel_j;
        }
        cost.host_waq_s = waq_ns as f64 * 1e-9;
        cost.shard_crit_s = crit_ns as f64 * 1e-9;
        Ok((logits, cost))
    }
}

// ---------------------------------------------------------------------------
// FP32 building blocks shared by calibration, prefill, and decode
// ---------------------------------------------------------------------------

/// Output-MSE sensitivity of one linear under 2/3/4-bit K-Means
/// codebooks, measured on its calibration rows: `out[b - 2]` is the mean
/// squared error of `x @ dequant(quantize(W, b))` against `x @ W` over
/// all calibration rows and output channels. This is the planner's
/// currency — it captures how much *output* damage a width does to THIS
/// linear on the activations it actually sees, not just weight distortion.
fn linear_sensitivity(w: &Matrix, calib: &[Vec<f32>], group: usize) -> [f64; 3] {
    let mut out = [0f64; 3];
    let mut y = vec![0f32; w.cols];
    for (slot, bits) in [2u32, 3, 4].into_iter().enumerate() {
        let deq = quant::quantize_weights_grouped(w, None, bits, group).dequantize();
        let mut err = 0f64;
        for x in calib {
            y.iter_mut().for_each(|v| *v = 0.0);
            for (k, &xv) in x.iter().enumerate() {
                for ((o, &wv), &dv) in y.iter_mut().zip(w.row(k)).zip(deq.row(k)) {
                    *o += xv * (wv - dv);
                }
            }
            err += y.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
        }
        out[slot] = err / (calib.len().max(1) * w.cols) as f64;
    }
    out
}

/// Positional parameter lookup with shape validation.
fn param<'a>(
    manifest: &Manifest,
    params: &'a ParamSet,
    name: &str,
    shape: &[usize],
) -> Result<&'a HostTensor> {
    let i = ParamSet::index_of(manifest, name)
        .ok_or_else(|| anyhow!("param '{name}' missing from manifest"))?;
    let t = params
        .tensors
        .get(i)
        .ok_or_else(|| anyhow!("param '{name}' missing from ParamSet"))?;
    if t.shape() != shape {
        bail!("param '{name}': expected shape {shape:?}, got {:?}", t.shape());
    }
    Ok(t)
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// out = tok_emb[tok] + pos_emb[pos]
fn embed_into(out: &mut [f32], tok_emb: &Matrix, pos_emb: &Matrix, tok: usize, pos: usize) {
    for ((o, &e), &pe) in out.iter_mut().zip(tok_emb.row(tok)).zip(pos_emb.row(pos)) {
        *o = e + pe;
    }
}

/// RMSNorm one row: out = x * g / sqrt(mean(x^2) + 1e-5).
fn rms_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x).zip(g) {
        *o = v * gv * inv;
    }
}

fn rms_rows(x: &Matrix, g: &[f32]) -> Vec<Vec<f32>> {
    (0..x.rows)
        .map(|r| {
            let mut o = vec![0f32; x.cols];
            rms_into(x.row(r), g, &mut o);
            o
        })
        .collect()
}

fn rms_vecs(xs: &[Vec<f32>], g: &[f32]) -> Vec<Vec<f32>> {
    xs.iter()
        .map(|x| {
            let mut o = vec![0f32; x.len()];
            rms_into(x, g, &mut o);
            o
        })
        .collect()
}

/// tanh-approximate GELU (what `jax.nn.gelu` lowers by default, keeping
/// the native forward aligned with the AOT artifacts).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044_715 * x * x * x)).tanh())
}

fn add_into(x: &mut [f32], y: &[f32]) {
    for (a, &b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

fn add_rows(x: &mut Matrix, rows: &[Vec<f32>]) {
    let cols = x.cols;
    for (xr, r) in x.data.chunks_exact_mut(cols).zip(rows) {
        add_into(xr, r);
    }
}

fn add_matrix(x: &mut Matrix, y: &Matrix) {
    for (a, &b) in x.data.iter_mut().zip(&y.data) {
        *a += b;
    }
}

fn mat_rows(m: &Matrix) -> Vec<Vec<f32>> {
    (0..m.rows).map(|r| m.row(r).to_vec()).collect()
}

/// Full-sequence causal attention over a fused (n, 3*d) qkv matrix laid
/// out [q | k | v] per row, d = h * hd. Returns the (n, d) context.
fn causal_attention(qkv: &Matrix, h: usize, hd: usize) -> Matrix {
    let n = qkv.rows;
    let d = h * hd;
    debug_assert_eq!(qkv.cols, 3 * d);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(n, d);
    let mut scores: Vec<f32> = Vec::with_capacity(n);
    for t in 0..n {
        for head in 0..h {
            let q = &qkv.row(t)[head * hd..(head + 1) * hd];
            scores.clear();
            let mut maxv = f32::NEG_INFINITY;
            for sp in 0..=t {
                let k = &qkv.row(sp)[d + head * hd..d + (head + 1) * hd];
                let sc = dot(q, k) * scale;
                maxv = maxv.max(sc);
                scores.push(sc);
            }
            let mut denom = 0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - maxv).exp();
                denom += *sc;
            }
            let inv = 1.0 / denom;
            let orow = &mut out.row_mut(t)[head * hd..(head + 1) * hd];
            for (sp, &w) in scores.iter().enumerate() {
                let v = &qkv.row(sp)[2 * d + head * hd..2 * d + (head + 1) * hd];
                let wn = w * inv;
                for (o, &vv) in orow.iter_mut().zip(v) {
                    *o += wn * vv;
                }
            }
        }
    }
    out
}
