//! Deterministic fault injection for the serving stack.
//!
//! [`ChaosBackend`] wraps any [`DecodeBackend`] and injects faults from a
//! seeded [`Rng`] — **no wall-clock, no OS randomness** — so a chaos run
//! is exactly reproducible from `(seed, rates, call sequence)`: the soak
//! test asserts two identical-seed runs produce identical outcomes, and a
//! failing fleet trace replays locally from its seed. Three fault shapes,
//! each at its own rate:
//!
//!   * **hard errors** — `prefill`/`prefill_batch`/`decode` return `Err`
//!     (what a wedged accelerator or a poisoned artifact looks like). The
//!     engine's containment turns these into `Aborted` responses, never
//!     thread death. Bounded by `fault_budget` so a test can script
//!     "exactly one mid-burst failure, then healthy".
//!   * **NaN logit rows** — one active slot's row is overwritten with NaN
//!     after a successful decode (a numerically blown-up datapath); the
//!     engine's NaN-safe sampling must keep the request in-vocab.
//!   * **latency spikes** — `spike_s` is added to the step's modeled
//!     `accel_s` (a straggler step); exercises deadline expiry under sim
//!     time without sleeping.
//!
//! The wrapper composes with every backend (`native-packed`,
//! `native-sharded`, the PJRT stub) and every `--kv-bits`, because it
//! delegates `spec`/`model`/`kv_quantizer` untouched — chaos is a serving
//! seam, not a datapath change. Enabled via `EngineConfig::chaos`
//! (`--chaos-seed` / `--chaos-rate` on `kllm serve`).
//!
//! A fourth, opt-in shape targets the KV allocator:
//!
//!   * **allocation pressure** — with `kv_pressure_rate > 0`, decode and
//!     paged-prefill calls roll for a forced LRU eviction of up to
//!     `kv_pressure_blocks` prefix-cache blocks
//!     (`PagedKvCache::evict_cached`), exercising the eviction and
//!     copy-on-write paths under deterministic soak. Only index-only
//!     blocks (refcount 1) are ever evicted, so correctness is untouched
//!     — hits just get colder.
//!
//! Determinism contract: every entry point draws from the RNG in a fixed
//! order (`prefill`/`prefill_batch`: one draw; `prefill_paged`: fault,
//! then pressure when enabled; `decode`: fault, NaN, spike, pressure when
//! enabled, then a victim-slot draw only when the NaN fires), so the
//! fault pattern is a pure function of the seed and the call sequence —
//! it cannot silently shift when an unrelated branch stops consuming
//! randomness. The pressure roll only exists when `kv_pressure_rate > 0`,
//! so legacy profiles replay bit-identical fault patterns.
//!
//! `DecodeBackend::schedule` (the iteration-level scheduler's mixed
//! step) composes through the trait default, which dispatches to this
//! wrapper's own `prefill_paged` and `decode` — so a mixed step draws
//! exactly the per-call sequences above, and a phase the default skips
//! (no chunks planned, or no active decode slot) consumes **zero**
//! draws. Chunk-fault tests rely on that arithmetic to place a fault on
//! a chosen chunk.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::{
    BackendSpec, DecodeBackend, PagedPrefill, PagedPrefillOut, PrefillOut, SpecRound, StepCost,
    VerifyRun,
};
use crate::coordinator::kv::KvManager;
use crate::kvcache::KvQuantizer;
use crate::runtime::artifacts::ModelCfg;
use crate::util::rng::Rng;

/// Fault-injection rates and bounds (all probabilities in `[0, 1]`).
#[derive(Clone, Copy, Debug)]
pub struct ChaosCfg {
    /// RNG seed — the whole fault pattern derives from it.
    pub seed: u64,
    /// Probability a `prefill`/`prefill_batch` call returns `Err`.
    pub prefill_err_rate: f64,
    /// Probability a `decode` call returns `Err`.
    pub decode_err_rate: f64,
    /// Probability a successful decode gets one NaN-poisoned logit row.
    pub nan_rate: f64,
    /// Probability a successful decode's modeled time gains `spike_s`.
    pub spike_rate: f64,
    /// Modeled seconds added per latency spike.
    pub spike_s: f64,
    /// Maximum *hard errors* injected over the backend's lifetime (NaN
    /// rows and spikes are not counted). `u64::MAX` = unlimited. Lets a
    /// test script "fail exactly once mid-burst, then run healthy".
    pub fault_budget: u64,
    /// Probability a decode/paged-prefill call forces an LRU eviction of
    /// prefix-cache blocks (allocation pressure on the KV pool). 0 (the
    /// default) keeps the legacy draw sequence bit-identical.
    pub kv_pressure_rate: f64,
    /// Blocks evicted per fired pressure event (upper bound; fewer when
    /// the index has fewer evictable blocks).
    pub kv_pressure_blocks: usize,
}

impl ChaosCfg {
    /// All fault shapes at the same `rate` (the `--chaos-rate` CLI knob):
    /// hard errors, NaN rows, and spikes each fire with probability
    /// `rate`, unlimited budget, 5 modeled-ms spikes.
    pub fn uniform(seed: u64, rate: f64) -> ChaosCfg {
        ChaosCfg {
            seed,
            prefill_err_rate: rate,
            decode_err_rate: rate,
            nan_rate: rate,
            spike_rate: rate,
            spike_s: 5e-3,
            fault_budget: u64::MAX,
            kv_pressure_rate: 0.0,
            kv_pressure_blocks: 0,
        }
    }

    /// Enable the KV-allocator pressure profile: each decode/paged-prefill
    /// call force-evicts up to `blocks` prefix-cache blocks with
    /// probability `rate` (the `--chaos-kv-pressure` knob).
    pub fn with_kv_pressure(mut self, rate: f64, blocks: usize) -> ChaosCfg {
        self.kv_pressure_rate = rate;
        self.kv_pressure_blocks = blocks;
        self
    }
}

/// Shared injection counters (cloneable handle; the backend keeps the
/// other clone) so tests and the soak bench can assert how much chaos
/// actually landed without threading state out of the engine.
#[derive(Clone, Debug, Default)]
pub struct ChaosCounters(Arc<CounterCells>);

#[derive(Debug, Default)]
struct CounterCells {
    prefill_errs: AtomicU64,
    decode_errs: AtomicU64,
    nan_rows: AtomicU64,
    spikes: AtomicU64,
    kv_evictions: AtomicU64,
}

impl ChaosCounters {
    pub fn prefill_errs(&self) -> u64 {
        self.0.prefill_errs.load(Ordering::Relaxed)
    }

    pub fn decode_errs(&self) -> u64 {
        self.0.decode_errs.load(Ordering::Relaxed)
    }

    pub fn nan_rows(&self) -> u64 {
        self.0.nan_rows.load(Ordering::Relaxed)
    }

    pub fn spikes(&self) -> u64 {
        self.0.spikes.load(Ordering::Relaxed)
    }

    /// Prefix-cache blocks freed by injected allocation pressure.
    pub fn kv_evictions(&self) -> u64 {
        self.0.kv_evictions.load(Ordering::Relaxed)
    }

    /// Hard errors only (the ones that consume `fault_budget`).
    pub fn hard_errors(&self) -> u64 {
        self.prefill_errs() + self.decode_errs()
    }

    fn bump(cell: &AtomicU64) {
        cell.fetch_add(1, Ordering::Relaxed);
    }
}

/// Seeded fault-injecting wrapper around any [`DecodeBackend`].
pub struct ChaosBackend {
    inner: Box<dyn DecodeBackend>,
    cfg: ChaosCfg,
    rng: Rng,
    budget_left: u64,
    counters: ChaosCounters,
}

impl ChaosBackend {
    pub fn new(inner: Box<dyn DecodeBackend>, cfg: ChaosCfg) -> ChaosBackend {
        ChaosBackend {
            inner,
            rng: Rng::new(cfg.seed),
            budget_left: cfg.fault_budget,
            counters: ChaosCounters::default(),
            cfg,
        }
    }

    /// Handle to the injection counters (clone-cheap, thread-safe).
    pub fn counters(&self) -> ChaosCounters {
        self.counters.clone()
    }

    /// Consume one unit of hard-error budget; false when exhausted (the
    /// fault is then suppressed and the call proceeds normally).
    fn take_fault(&mut self) -> bool {
        if self.budget_left == 0 {
            return false;
        }
        self.budget_left -= 1;
        true
    }

    /// Roll for KV allocation pressure and apply it. The draw only exists
    /// when the profile enables pressure (`kv_pressure_rate > 0`), so
    /// legacy seeds replay identical fault patterns.
    fn maybe_pressure(&mut self, kv: &mut KvManager) {
        if self.cfg.kv_pressure_rate <= 0.0 {
            return;
        }
        let roll = self.rng.f64();
        if roll < self.cfg.kv_pressure_rate {
            let n = kv.cache_mut().evict_cached(self.cfg.kv_pressure_blocks);
            self.counters.0.kv_evictions.fetch_add(n as u64, Ordering::Relaxed);
        }
    }
}

impl DecodeBackend for ChaosBackend {
    fn spec(&self) -> BackendSpec {
        self.inner.spec()
    }

    fn model(&self) -> ModelCfg {
        self.inner.model()
    }

    fn kv_quantizer(&self, bits: u32) -> KvQuantizer {
        // delegate so chaos composes with calibrated n-bit KV backends
        self.inner.kv_quantizer(bits)
    }

    fn wbits_plan(&self) -> Option<Vec<u32>> {
        // chaos is a serving seam, not a datapath change: report the
        // wrapped backend's per-layer bit assignment untouched
        self.inner.wbits_plan()
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let roll = self.rng.f64();
        if roll < self.cfg.prefill_err_rate && self.take_fault() {
            ChaosCounters::bump(&self.counters.0.prefill_errs);
            bail!("chaos: injected prefill fault");
        }
        self.inner.prefill(prompt)
    }

    fn prefill_batch(&mut self, prompts: &[&[i32]]) -> Result<Vec<PrefillOut>> {
        // one draw per burst (not per prompt): the unit the engine's
        // containment answers is the burst, so that's the unit of fault
        let roll = self.rng.f64();
        if roll < self.cfg.prefill_err_rate && self.take_fault() {
            ChaosCounters::bump(&self.counters.0.prefill_errs);
            bail!("chaos: injected burst-prefill fault ({} prompts)", prompts.len());
        }
        self.inner.prefill_batch(prompts)
    }

    fn supports_paged_prefill(&self) -> bool {
        self.inner.supports_paged_prefill()
    }

    fn prefill_paged(
        &mut self,
        reqs: &[PagedPrefill<'_>],
        kv: &mut KvManager,
    ) -> Result<Vec<PagedPrefillOut>> {
        // same burst-granularity fault unit as prefill_batch
        let roll = self.rng.f64();
        if roll < self.cfg.prefill_err_rate && self.take_fault() {
            ChaosCounters::bump(&self.counters.0.prefill_errs);
            bail!("chaos: injected paged-prefill fault ({} requests)", reqs.len());
        }
        self.maybe_pressure(kv);
        self.inner.prefill_paged(reqs, kv)
    }

    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> Result<(Vec<f32>, StepCost)> {
        // fixed draw order regardless of which faults fire
        let fault = self.rng.f64();
        let nan = self.rng.f64();
        let spike = self.rng.f64();
        if fault < self.cfg.decode_err_rate && self.take_fault() {
            ChaosCounters::bump(&self.counters.0.decode_errs);
            bail!("chaos: injected decode fault");
        }
        self.maybe_pressure(kv);
        let (mut logits, mut cost) = self.inner.decode(toks, pos, active, kv)?;
        if nan < self.cfg.nan_rate {
            let victims: Vec<usize> = active
                .iter()
                .enumerate()
                .filter_map(|(i, &a)| a.then_some(i))
                .collect();
            if !victims.is_empty() {
                let slot = victims[self.rng.below(victims.len())];
                let vocab = self.inner.model().vocab;
                for v in &mut logits[slot * vocab..(slot + 1) * vocab] {
                    *v = f32::NAN;
                }
                ChaosCounters::bump(&self.counters.0.nan_rows);
            }
        }
        if spike < self.cfg.spike_rate {
            cost.accel_s += self.cfg.spike_s;
            ChaosCounters::bump(&self.counters.0.spikes);
        }
        Ok((logits, cost))
    }

    /// Delegated untouched (no draw): the speculative composite calls
    /// `verify_paged` on its *target*, inside this wrapper — chaos on the
    /// speculative path rides the one `decode` draw per round, keeping
    /// legacy seeds' draw order bit-identical.
    fn verify_paged(
        &mut self,
        runs: &[VerifyRun<'_>],
        kv: &mut KvManager,
    ) -> Result<(Vec<Vec<f32>>, StepCost)> {
        self.inner.verify_paged(runs, kv)
    }

    fn take_spec_rounds(&mut self) -> Option<Vec<SpecRound>> {
        self.inner.take_spec_rounds()
    }

    fn requires_paged_admission(&self) -> bool {
        self.inner.requires_paged_admission()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    /// Minimal healthy inner backend: finite logits, fixed cost.
    struct FlatBackend {
        model: ModelCfg,
    }

    impl DecodeBackend for FlatBackend {
        fn spec(&self) -> BackendSpec {
            BackendSpec::Native(crate::gemm::WaqBackend::Packed)
        }

        fn model(&self) -> ModelCfg {
            self.model
        }

        fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
            let m = self.model;
            let plen = prompt.len().clamp(1, m.seq_len - 1);
            let shape = [m.n_layers, 1, m.n_heads, m.seq_len, m.head_dim];
            let mut logits = vec![0.0f32; m.vocab];
            logits[1] = 1.0;
            Ok(PrefillOut {
                plen,
                logits,
                k_cache: HostTensor::zeros(&shape),
                v_cache: HostTensor::zeros(&shape),
                cost: StepCost { accel_s: 1e-4, ..StepCost::default() },
            })
        }

        fn decode(
            &mut self,
            _toks: &[i32],
            _pos: &[i32],
            _active: &[bool],
            _kv: &mut KvManager,
        ) -> Result<(Vec<f32>, StepCost)> {
            let m = self.model;
            let mut logits = vec![0.0f32; m.decode_batch * m.vocab];
            for s in 0..m.decode_batch {
                logits[s * m.vocab + 2] = 1.0;
            }
            Ok((logits, StepCost { accel_s: 1e-4, ..StepCost::default() }))
        }
    }

    fn flat() -> Box<dyn DecodeBackend> {
        Box::new(FlatBackend { model: ModelCfg::test_preset() })
    }

    /// Drive one chaos instance through a fixed call sequence and record
    /// the per-call outcome signature.
    fn fault_signature(cfg: ChaosCfg, calls: usize) -> Vec<(bool, bool, bool)> {
        let m = ModelCfg::test_preset();
        let mut b = ChaosBackend::new(flat(), cfg);
        let counters = b.counters();
        let mut kv = KvManager::new(m);
        let toks = vec![0i32; m.decode_batch];
        let pos = vec![0i32; m.decode_batch];
        let active = vec![true; m.decode_batch];
        let mut sig = Vec::with_capacity(calls);
        for _ in 0..calls {
            let (errs0, nan0, spk0) =
                (counters.decode_errs(), counters.nan_rows(), counters.spikes());
            let _ = b.decode(&toks, &pos, &active, &mut kv);
            sig.push((
                counters.decode_errs() > errs0,
                counters.nan_rows() > nan0,
                counters.spikes() > spk0,
            ));
        }
        sig
    }

    #[test]
    fn identical_seeds_produce_identical_fault_patterns() {
        let cfg = ChaosCfg::uniform(0xC4A05, 0.3);
        let a = fault_signature(cfg, 64);
        let b = fault_signature(cfg, 64);
        assert_eq!(a, b, "same seed must replay the same chaos");
        // a different seed gives a different pattern (overwhelmingly)
        let c = fault_signature(ChaosCfg::uniform(0xC4A06, 0.3), 64);
        assert_ne!(a, c, "different seeds should diverge");
        // and some of each fault shape actually fired at rate 0.3
        let (errs, nans, spikes) = a.iter().fold((0, 0, 0), |(e, n, s), &(fe, fn_, fs)| {
            (e + fe as u32, n + fn_ as u32, s + fs as u32)
        });
        assert!(errs > 0 && nans > 0 && spikes > 0, "{errs}/{nans}/{spikes}");
    }

    #[test]
    fn rate_zero_is_a_transparent_passthrough() {
        let m = ModelCfg::test_preset();
        let mut plain = FlatBackend { model: m };
        let mut wrapped = ChaosBackend::new(flat(), ChaosCfg::uniform(7, 0.0));
        let mut kv1 = KvManager::new(m);
        let mut kv2 = KvManager::new(m);
        let toks = vec![0i32; m.decode_batch];
        let pos = vec![0i32; m.decode_batch];
        let active = vec![true; m.decode_batch];
        for _ in 0..8 {
            let (l1, c1) = plain.decode(&toks, &pos, &active, &mut kv1).unwrap();
            let (l2, c2) = wrapped.decode(&toks, &pos, &active, &mut kv2).unwrap();
            assert_eq!(l1, l2);
            assert_eq!(c1.accel_s, c2.accel_s);
        }
        let p = wrapped.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(p.plen, 3);
        assert_eq!(wrapped.counters().hard_errors(), 0);
    }

    #[test]
    fn fault_budget_bounds_hard_errors_only() {
        let m = ModelCfg::test_preset();
        let cfg = ChaosCfg {
            fault_budget: 2,
            ..ChaosCfg::uniform(11, 1.0) // every call would fault
        };
        let mut b = ChaosBackend::new(flat(), cfg);
        let counters = b.counters();
        let mut kv = KvManager::new(m);
        let toks = vec![0i32; m.decode_batch];
        let pos = vec![0i32; m.decode_batch];
        let active = vec![true; m.decode_batch];
        let mut errors = 0;
        for _ in 0..10 {
            if b.decode(&toks, &pos, &active, &mut kv).is_err() {
                errors += 1;
            }
        }
        assert_eq!(errors, 2, "budget caps hard errors");
        assert_eq!(counters.hard_errors(), 2);
        // NaN rows and spikes keep firing after the budget is spent
        assert!(counters.nan_rows() >= 8 - 2, "nan_rows {}", counters.nan_rows());
        assert!(counters.spikes() >= 8 - 2, "spikes {}", counters.spikes());
    }

    #[test]
    fn nan_injection_poisons_exactly_one_active_row() {
        let m = ModelCfg::test_preset();
        let cfg = ChaosCfg {
            prefill_err_rate: 0.0,
            decode_err_rate: 0.0,
            spike_rate: 0.0,
            nan_rate: 1.0,
            ..ChaosCfg::uniform(3, 0.0)
        };
        let mut b = ChaosBackend::new(flat(), cfg);
        let mut kv = KvManager::new(m);
        let toks = vec![0i32; m.decode_batch];
        let pos = vec![0i32; m.decode_batch];
        // only slot 0 active: the victim draw must respect activity
        let mut active = vec![false; m.decode_batch];
        active[0] = true;
        let (logits, _) = b.decode(&toks, &pos, &active, &mut kv).unwrap();
        assert!(logits[..m.vocab].iter().all(|v| v.is_nan()), "active row poisoned");
        assert!(
            logits[m.vocab..].iter().all(|v| !v.is_nan()),
            "inactive rows untouched"
        );
        // no active slots → nothing to poison, call still succeeds
        let none = vec![false; m.decode_batch];
        let (clean, _) = b.decode(&toks, &pos, &none, &mut kv).unwrap();
        assert!(clean.iter().all(|v| !v.is_nan()));
        assert_eq!(b.counters().nan_rows(), 1);
    }

    #[test]
    fn spike_adds_modeled_time_without_touching_logits() {
        let m = ModelCfg::test_preset();
        let cfg = ChaosCfg {
            prefill_err_rate: 0.0,
            decode_err_rate: 0.0,
            nan_rate: 0.0,
            spike_rate: 1.0,
            spike_s: 0.25,
            ..ChaosCfg::uniform(5, 0.0)
        };
        let mut b = ChaosBackend::new(flat(), cfg);
        let mut kv = KvManager::new(m);
        let toks = vec![0i32; m.decode_batch];
        let pos = vec![0i32; m.decode_batch];
        let active = vec![true; m.decode_batch];
        let (logits, cost) = b.decode(&toks, &pos, &active, &mut kv).unwrap();
        assert!((cost.accel_s - (1e-4 + 0.25)).abs() < 1e-12);
        assert!(logits.iter().all(|v| !v.is_nan()));
        assert_eq!(b.counters().spikes(), 1);
    }

    #[test]
    fn kv_pressure_evicts_index_only_blocks_deterministically() {
        use crate::kvcache::KvPrecision;
        let m = ModelCfg::test_preset();
        // Build a prefix-cache-enabled manager and park one prompt's blocks
        // in the index with no live slot holding them (refcount 1 each).
        let mut kv = KvManager::with_precision_opts(m, KvPrecision::Fp32, true);
        let prompt = [1i32, 2, 3, 4];
        let matched = kv.admit_prefix(0, 1, &prompt, prompt.len()).unwrap();
        assert_eq!(matched.tokens, 0, "cold index: nothing to alias");
        let d = m.n_heads * m.head_dim;
        for l in 0..m.n_layers {
            for p in 0..prompt.len() {
                kv.append_token(l, 0, p, &vec![0.5; d], &vec![0.25; d]).unwrap();
            }
        }
        kv.set_position(0, prompt.len()).unwrap();
        kv.register_prefix(0, &prompt);
        kv.release(0);
        let parked = kv.cache().in_use_blocks();
        assert_eq!(parked, m.n_layers, "one block per layer parked in the index");

        // rate 1.0 pressure fires on the first decode and drains the index
        let cfg = ChaosCfg::uniform(0xE71C, 0.0).with_kv_pressure(1.0, 8);
        let mut b = ChaosBackend::new(flat(), cfg);
        let toks = vec![0i32; m.decode_batch];
        let pos = vec![0i32; m.decode_batch];
        let active = vec![false; m.decode_batch];
        b.decode(&toks, &pos, &active, &mut kv).unwrap();
        assert_eq!(kv.cache().in_use_blocks(), 0, "pressure freed the parked blocks");
        assert_eq!(b.counters().kv_evictions(), parked as u64);
        assert_eq!(kv.cache().evictions(), parked as u64);
        // identical seed + profile replays the identical eviction count
        let mut kv2 = KvManager::with_precision_opts(m, KvPrecision::Fp32, true);
        let mut b2 = ChaosBackend::new(flat(), cfg);
        b2.decode(&toks, &pos, &active, &mut kv2).unwrap();
        assert_eq!(b2.counters().kv_evictions(), 0, "empty index: nothing to evict");
    }

    #[test]
    fn burst_prefill_draws_once_per_burst() {
        // budget 1 + rate 1.0: the first burst faults, the second (and
        // every later call) runs clean — proving one draw/fault per burst,
        // not one per prompt
        let cfg = ChaosCfg { fault_budget: 1, ..ChaosCfg::uniform(9, 1.0) };
        let mut b = ChaosBackend::new(flat(), cfg);
        let prompts: Vec<&[i32]> = vec![&[1, 2], &[3, 4], &[5]];
        assert!(b.prefill_batch(&prompts).is_err());
        let out = b.prefill_batch(&prompts).expect("budget spent, burst ok");
        assert_eq!(out.len(), 3);
        assert_eq!(b.counters().prefill_errs(), 1);
    }
}
