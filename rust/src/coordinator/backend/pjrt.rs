//! The PJRT decode backend: a thin wrapper over the AOT-artifact flow the
//! engine used to hardwire — compiled `prefill`/`decode_step` HLO modules,
//! device-resident weight buffers uploaded once, KV caches round-tripped
//! per step. The configured WAQ kernel does not execute here; it selects
//! the modeled host-datapath clock (`CpuWaqModel`) reported per step.
//! Admission bursts use the trait's default `prefill_batch` (one artifact
//! invocation per request — the prefill HLO module is lowered for a
//! single prompt), so this backend is the "sequential side" of the
//! batched-prefill parity tests.
//!
//! [`PjrtBackend::stub`] builds an artifact-contract test double instead:
//! deterministic single-peaked pseudo-logits and zero caches, no `Runtime`
//! at all. It exists so engine bookkeeping (slots, admission, finish
//! reasons, stats) is exercisable in offline builds where the `pjrt`
//! feature is absent, and is the "PJRT side" of the backend-parity tests.

use anyhow::{anyhow, bail, Result};

use super::{batch_occupancy, BackendSpec, CostModel, DecodeBackend, PrefillOut, StepCost};
use crate::coordinator::kv::KvManager;
use crate::gemm::WaqBackend;
use crate::runtime::artifacts::ModelCfg;
use crate::runtime::{DeviceBuffer, HostTensor, ParamSet, Runtime};
use crate::sim::OasisMode;

/// The real artifact executor (boxed to keep the enum variants balanced).
struct ArtifactExec {
    rt: Runtime,
    weight_buffers: Vec<DeviceBuffer>,
}

enum Exec {
    Artifacts(Box<ArtifactExec>),
    Stub,
}

pub struct PjrtBackend {
    model: ModelCfg,
    waq: WaqBackend,
    cost: CostModel,
    exec: Exec,
}

impl PjrtBackend {
    /// Wrap a runtime: compile the serving artifacts up front and upload
    /// the parameter tensors once (the per-step hot path reuses them).
    pub fn new(
        mut rt: Runtime,
        params: &ParamSet,
        waq: WaqBackend,
        mode: OasisMode,
    ) -> Result<PjrtBackend> {
        let model = rt.manifest.model;
        rt.load("decode_step")?;
        rt.load("prefill")?;
        let weight_buffers = params
            .tensors
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(PjrtBackend {
            model,
            waq,
            cost: CostModel::new(model, mode, waq),
            exec: Exec::Artifacts(Box::new(ArtifactExec { rt, weight_buffers })),
        })
    }

    /// The artifact-contract test double: same shapes, costs, and engine
    /// bookkeeping as the real path, deterministic pseudo-logits, zero KV
    /// caches, and no `Runtime` (so it works in builds without the `pjrt`
    /// feature).
    pub fn stub(model: ModelCfg, waq: WaqBackend, mode: OasisMode) -> PjrtBackend {
        PjrtBackend { model, waq, cost: CostModel::new(model, mode, waq), exec: Exec::Stub }
    }
}

/// Deterministic single-peaked logits: argmax at a token-and-position
/// dependent channel, so greedy decode through the stub is reproducible.
fn stub_logits(tok: i32, pos: i32, vocab: usize) -> Vec<f32> {
    let peak = (tok as i64 * 7 + pos as i64 * 13).rem_euclid(vocab as i64) as usize;
    (0..vocab)
        .map(|v| if v == peak { 1.0 } else { -1.0 - (v as f32) / vocab as f32 })
        .collect()
}

impl DecodeBackend for PjrtBackend {
    fn spec(&self) -> BackendSpec {
        BackendSpec::Pjrt(self.waq)
    }

    fn model(&self) -> ModelCfg {
        self.model
    }

    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
        let m = self.model;
        // clamp into the context window; an empty prompt degrades to the
        // pad token instead of panicking the engine thread
        let plen = prompt.len().clamp(1, m.seq_len - 1);
        let mut padded = vec![0i32; m.seq_len];
        for (dst, &src) in padded.iter_mut().zip(prompt.iter().take(plen)) {
            *dst = src;
        }
        let (logits, k_cache, v_cache) = match &mut self.exec {
            Exec::Artifacts(a) => {
                let exe = a.rt.load("prefill")?;
                let mut bufs: Vec<&DeviceBuffer> = a.weight_buffers.iter().collect();
                let ptoks = a.rt.upload(&HostTensor::i32(padded, &[1, m.seq_len]))?;
                let plen_b = a.rt.upload(&HostTensor::scalar_i32(plen as i32))?;
                bufs.push(&ptoks);
                bufs.push(&plen_b);
                let mut out = exe.run_buffers(&bufs)?;
                if out.len() != 3 {
                    bail!("prefill artifact returned {} outputs, expected 3", out.len());
                }
                let v = out.pop().unwrap();
                let k = out.pop().unwrap();
                let logits = out.pop().unwrap().into_f32()?;
                (logits, k, v)
            }
            Exec::Stub => {
                let last = padded[plen - 1];
                let shape = [m.n_layers, 1, m.n_heads, m.seq_len, m.head_dim];
                (
                    stub_logits(last, plen as i32 - 1, m.vocab),
                    HostTensor::zeros(&shape),
                    HostTensor::zeros(&shape),
                )
            }
        };
        Ok(PrefillOut { plen, logits, k_cache, v_cache, cost: self.cost.prefill(plen) })
    }

    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> Result<(Vec<f32>, StepCost)> {
        let m = self.model;
        let b = m.decode_batch;
        let logits = match &mut self.exec {
            Exec::Artifacts(a) => {
                let exe = a.rt.load("decode_step")?;
                let mut bufs: Vec<&DeviceBuffer> = a.weight_buffers.iter().collect();
                // one dense materialization pass for both tensors
                let (kt, vt) = kv.dense_tensors();
                let kb = a.rt.upload(&kt)?;
                let vb = a.rt.upload(&vt)?;
                let tb = a.rt.upload(&HostTensor::i32(toks.to_vec(), &[b]))?;
                let pb = a.rt.upload(&HostTensor::i32(pos.to_vec(), &[b]))?;
                bufs.push(&kb);
                bufs.push(&vb);
                bufs.push(&tb);
                bufs.push(&pb);
                let out = exe.run_buffers(&bufs)?;
                if out.len() != 3 {
                    bail!("decode_step artifact returned {} outputs, expected 3", out.len());
                }
                // scatter only the active slots' newly written positions
                // into the paged cache (the artifact passes every other
                // region through unchanged)
                kv.update_from_step(&out[1], &out[2], pos, active)
                    .map_err(|e| anyhow!(e))?;
                out[0].as_f32()?.to_vec()
            }
            Exec::Stub => {
                let mut logits = vec![0f32; b * m.vocab];
                for slot in 0..b {
                    if active[slot] {
                        let row = stub_logits(toks[slot], pos[slot], m.vocab);
                        logits[slot * m.vocab..(slot + 1) * m.vocab]
                            .copy_from_slice(&row);
                    }
                }
                logits
            }
        };
        let (active_n, mean_ctx) = batch_occupancy(pos, active);
        Ok((logits, self.cost.decode(active_n, mean_ctx)))
    }
}
