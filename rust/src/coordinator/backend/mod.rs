//! The decode-backend API: the narrow trait the serving engine drives.
//!
//! `Engine` owns the *orchestration* of continuous batching — admission,
//! KV slot lifecycle, sampling, stats — and delegates the whole per-step
//! *compute* to a [`DecodeBackend`]: `prefill(prompt)` produces the first
//! token's logits plus the request's KV cache pair, `prefill_batch`
//! prefills a whole admission burst in one call (the engine's admission
//! path; default = loop over `prefill`, native backends run each linear
//! once for the stacked burst), `decode(tokens, positions, ...)` runs
//! one batched decode step over all slots, and `schedule` runs one
//! iteration-level mixed step (budgeted prefill chunks + decode,
//! `--sched chunked`) with a default built on the former two so every
//! backend and wrapper composes unchanged. Every call also returns a
//! [`StepCost`] so responses report modeled accelerator time/energy and
//! the host software-datapath seconds regardless of which engine
//! executed.
//!
//! Two implementations ship:
//!   * [`PjrtBackend`] — the AOT-artifact path: decode runs the compiled
//!     `prefill`/`decode_step` HLO modules through the PJRT runtime, and
//!     the WAQ backend choice only drives a modeled host clock
//!     (`baselines::cpu::CpuWaqModel`). Also provides a deterministic
//!     artifact-contract stub for engine tests and offline benches.
//!   * [`NativeWaqBackend`] — the paper's datapath, executed natively:
//!     K-Means-quantized weights + per-linear Cartesian LUTs, online
//!     activation quantization with Orizuru outlier detection feeding the
//!     error-compensation branch, batched through the packed/tiled WAQ
//!     LUT-GEMM kernel. No PJRT involved; its host seconds are measured,
//!     not modeled.
//!
//!   * [`ShardedWaqBackend`] — the native datapath with every WAQ
//!     LUT-GEMM linear split into tensor-parallel column shards on a
//!     persistent worker pool; bit-exact with `NativeWaqBackend` at any
//!     shard count (`--backend native-sharded --shards N`).
//!
//!   * [`SpeculativeBackend`] — speculative decoding: a low-bit packed
//!     draft twin of the same manifest (`--draft-wbits {2,3,4}`, 2 by
//!     default) proposes up to `--spec-k` tokens
//!     per round against a private KV cache, the target scores every
//!     proposal in one stacked [`DecodeBackend::verify_paged`] pass per
//!     layer, and greedy acceptance keeps the longest matching prefix —
//!     bit-exact with the target alone (`--backend native-spec`).
//!
//! Plus one wrapper: [`ChaosBackend`] (module [`chaos`]) composes over any
//! of the above, injecting seeded deterministic faults (errors, NaN
//! rows, latency spikes) for robustness testing — `--chaos-seed` /
//! `--chaos-rate`.
//!
//! Future backends (multi-node) target this trait instead of the engine
//! internals.

pub mod chaos;
mod native;
mod pjrt;
mod sharded;
mod speculative;

pub use chaos::{ChaosBackend, ChaosCfg, ChaosCounters};
pub use native::{NativeCfg, NativeWaqBackend, WbitsSpec};
pub use pjrt::PjrtBackend;
pub use sharded::ShardedWaqBackend;
pub use speculative::SpeculativeBackend;

use anyhow::Result;

use super::kv::KvManager;
use crate::baselines::CpuWaqModel;
use crate::gemm::WaqBackend;
use crate::kvcache::{KvPrecision, KvQuantizer};
use crate::models::LlmSpec;
use crate::runtime::artifacts::ModelCfg;
use crate::runtime::HostTensor;
use crate::sim::{self, HwConfig, OasisMode};

/// Which execution engine owns the decode datapath, and which software WAQ
/// GEMM kernel it runs (`native-*`, measured) or models (`pjrt`, the
/// `CpuWaqModel` clock).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSpec {
    /// Decode through the AOT PJRT artifacts; the WAQ backend selects the
    /// modeled host-datapath clock reported alongside.
    Pjrt(WaqBackend),
    /// Decode through the native K-Means WAQ LUT-GEMM datapath with the
    /// selected software kernel; serving throughput is measured on it.
    Native(WaqBackend),
    /// Tensor-parallel sharded native serving: every linear's packed WAQ
    /// GEMM split into `EngineConfig::shards` column shards executed on a
    /// persistent worker pool — bit-exact with `Native(Packed)`.
    NativeSharded,
    /// Speculative decoding: a low-bit packed draft proposes, the
    /// native packed target verifies in one stacked pass — bit-exact with
    /// `Native(Packed)` under greedy sampling (`--spec-k`, `--draft-wbits`).
    NativeSpec,
}

impl Default for BackendSpec {
    fn default() -> Self {
        BackendSpec::Pjrt(WaqBackend::default())
    }
}

impl BackendSpec {
    /// The software WAQ GEMM kernel this spec runs or models.
    pub fn waq(&self) -> WaqBackend {
        match self {
            BackendSpec::Pjrt(b) | BackendSpec::Native(b) => *b,
            // shards stream nibble-packed column slices of the packed form
            BackendSpec::NativeSharded => WaqBackend::Packed,
            // target runs packed; the draft's denser stream rides underneath
            BackendSpec::NativeSpec => WaqBackend::Packed,
        }
    }

    pub fn is_native(&self) -> bool {
        matches!(
            self,
            BackendSpec::Native(_) | BackendSpec::NativeSharded | BackendSpec::NativeSpec
        )
    }

    /// Canonical CLI/stats name (`packed`, `native-packed`, ...).
    pub fn name(&self) -> &'static str {
        match self {
            BackendSpec::Pjrt(b) => b.name(),
            BackendSpec::Native(WaqBackend::Direct) => "native-direct",
            BackendSpec::Native(WaqBackend::Histogram) => "native-histogram",
            BackendSpec::Native(WaqBackend::Packed) => "native-packed",
            BackendSpec::NativeSharded => "native-sharded",
            BackendSpec::NativeSpec => "native-spec",
        }
    }

    /// Every accepted `--backend` value, derived from [`WaqBackend::ALL`]
    /// plus the sharded serving path (so new kernels surface in CLI error
    /// text automatically).
    pub fn accepted() -> String {
        WaqBackend::ALL
            .iter()
            .map(|b| b.name().to_string())
            .chain(WaqBackend::ALL.iter().map(|b| format!("native-{b}")))
            .chain(std::iter::once(BackendSpec::NativeSharded.name().to_string()))
            .chain(std::iter::once(BackendSpec::NativeSpec.name().to_string()))
            .collect::<Vec<_>>()
            .join("|")
    }
}

impl std::fmt::Display for BackendSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for BackendSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<BackendSpec, String> {
        if s == BackendSpec::NativeSharded.name() {
            return Ok(BackendSpec::NativeSharded);
        }
        if s == BackendSpec::NativeSpec.name() {
            return Ok(BackendSpec::NativeSpec);
        }
        let parsed = match s.strip_prefix("native-") {
            Some(rest) => rest.parse().map(BackendSpec::Native),
            None => s.parse().map(BackendSpec::Pjrt),
        };
        parsed.map_err(|_| {
            format!("unknown backend '{s}' (expected {})", BackendSpec::accepted())
        })
    }
}

/// Per-step cost report from a backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    /// Modeled OASIS accelerator seconds for the step (the sim clock).
    pub accel_s: f64,
    /// Modeled OASIS accelerator energy for the step.
    pub accel_j: f64,
    /// Host software WAQ-datapath seconds: measured wall-clock of the
    /// WAQ LUT-GEMM linears (quantize + main branch + compensation) for
    /// the native backends — decode steps AND prefills, so the batched
    /// admission path's amortization is visible in the stat — or the
    /// `CpuWaqModel` roofline for PJRT (decode only; PJRT prefill reports
    /// zero). For a batched prefill the burst is measured once and split
    /// per request proportionally to token counts.
    pub host_waq_s: f64,
    /// Tensor-parallel critical path: the sum over this step's sharded
    /// GEMMs of the slowest shard's measured wall-clock seconds — the
    /// latency floor the column split cannot beat. 0.0 for unsharded
    /// backends (their whole GEMM is already counted in `host_waq_s`).
    pub shard_crit_s: f64,
    /// Speculative split of `host_waq_s`: measured host seconds the draft
    /// model spent proposing this step. 0.0 for non-speculative backends.
    pub draft_s: f64,
    /// Speculative split of `host_waq_s`: measured host seconds the target
    /// spent verifying proposals this step. 0.0 for non-speculative
    /// backends.
    pub verify_s: f64,
}

/// Result of one request's prefill (one element of a batch for
/// [`DecodeBackend::prefill_batch`], whose per-request `cost` fields
/// carry this request's share of the burst: modeled accelerator cost for
/// its own `plen`, measured host/shard seconds split proportionally to
/// token counts).
pub struct PrefillOut {
    /// Prompt length actually consumed (clamped to the context window;
    /// when `plen < prompt.len()` the engine marks the response
    /// `truncated_prompt`).
    pub plen: usize,
    /// Logits at the last prompt position (length `vocab`).
    pub logits: Vec<f32>,
    /// KV cache pair for the request, shaped (L, 1, H, S, hd) — handed to
    /// `KvManager::install_prefill`.
    pub k_cache: HostTensor,
    pub v_cache: HostTensor,
    pub cost: StepCost,
}

/// One request of a paged-prefill burst ([`DecodeBackend::prefill_paged`]):
/// the slot is already claimed, the first `cached` prompt positions are
/// served by aliased prefix-cache blocks, and the backend computes (and
/// appends through `kv`) only the uncached tail `prompt[cached..plen]`.
pub struct PagedPrefill<'a> {
    pub prompt: &'a [i32],
    pub slot: usize,
    /// prompt positions already present in the slot's block tables
    pub cached: usize,
}

/// Per-request result of [`DecodeBackend::prefill_paged`]. Unlike
/// [`PrefillOut`] there is no dense KV pair — the K/V rows were appended
/// straight into the paged cache (quantized in place for n-bit storage).
pub struct PagedPrefillOut {
    /// Prompt length actually consumed (clamped to the context window).
    pub plen: usize,
    /// Logits at the last prompt position (length `vocab`).
    pub logits: Vec<f32>,
    /// This request's share of the burst cost. Both the modeled
    /// accelerator cost and the measured host/shard seconds cover only
    /// the *uncached tail* — aliased prefix positions cost no compute,
    /// which is the whole point of the prefix cache.
    pub cost: StepCost,
}

/// One iteration-level scheduler step (`--sched chunked`): the decode
/// rows of every active slot plus a budgeted chunk of pending prefill
/// work, handed to the backend as one unit so implementations may fuse
/// the two phases when they can.
pub struct ScheduleWork<'a> {
    /// Budgeted prefill chunks: prompt *slices* resuming at `cached`
    /// (the per-request chunk cursor). Empty when nothing is prefilling.
    pub chunks: Vec<PagedPrefill<'a>>,
    /// Decode rows, `decode_batch`-shaped exactly like
    /// [`DecodeBackend::decode`]; `active` marks live decode slots
    /// (mid-prefill slots are *not* active — they join once their final
    /// chunk lands).
    pub toks: &'a [i32],
    pub pos: &'a [i32],
    pub active: &'a [bool],
}

/// Result of [`DecodeBackend::schedule`]. The chunk burst and the decode
/// step carry *separate* `Result`s so the engine can contain each fault
/// to the requests it affects: a chunk fault aborts only the chunking
/// requests while every in-flight decode survives, and a decode fault
/// leaves mid-prefill requests untouched.
pub struct ScheduleOut {
    /// One [`PagedPrefillOut`] per chunk, in order. `Err` means the
    /// whole chunk burst failed (all-or-nothing, like `prefill_paged`).
    pub chunks: Result<Vec<PagedPrefillOut>>,
    /// `None` when no slot was active — the decode phase never ran (no
    /// backend call, and for [`ChaosBackend`] no fault draw either).
    pub decode: Option<Result<(Vec<f32>, StepCost)>>,
}

/// One slot's outcome of a speculative decode round, drained by the
/// engine via [`DecodeBackend::take_spec_rounds`] right after `decode`.
/// The backend has already committed `accepted` into the paged cache
/// (and truncated away every rejected position); the engine's job is to
/// emit those tokens — running its normal per-token stop checks — and
/// then sample the returned logits row (the target's distribution at the
/// first divergent position) as the round's final token.
#[derive(Clone, Debug)]
pub struct SpecRound {
    /// Slot index this round belongs to.
    pub slot: usize,
    /// How many draft tokens were proposed this round.
    pub proposed: u64,
    /// The draft tokens the target confirmed, in emission order. May be
    /// empty (the round then degenerates to an ordinary decode step).
    pub accepted: Vec<i32>,
}

/// One slot's run of a stacked verification pass
/// ([`DecodeBackend::verify_paged`]): score `tokens` (the last committed
/// token followed by the draft proposals) at consecutive cache positions
/// `start..start + tokens.len()`, appending each position's K/V through
/// the paged cache.
pub struct VerifyRun<'a> {
    pub slot: usize,
    /// First input position == the slot's current written length.
    pub start: usize,
    /// Input tokens, scored in order; logits are returned for every one.
    pub tokens: &'a [i32],
}

/// The per-step datapath behind the serving engine. Implementations own
/// compute; the engine owns slots, admission, sampling, and stats.
pub trait DecodeBackend {
    /// Which execution engine + WAQ kernel this is.
    fn spec(&self) -> BackendSpec;

    /// The model configuration being served (slot count, context, vocab).
    fn model(&self) -> ModelCfg;

    /// Codebooks for an n-bit K-Means-quantized KV cache (the engine
    /// builds its `KvManager` with these when `--kv-bits < 32`). The
    /// default is a uniform grid over the normalized row range (RTN-like,
    /// no calibration needed); backends that run a calibration pass
    /// override this with learned per-layer/per-head codebooks.
    fn kv_quantizer(&self, bits: u32) -> KvQuantizer {
        let m = self.model();
        KvQuantizer::uniform(m.n_layers, m.n_heads, m.head_dim, bits)
    }

    /// Run one request's prefill and return its first logits + KV pair.
    fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut>;

    /// Prefill a whole admission burst in one call, returning exactly one
    /// [`PrefillOut`] per prompt, in order. The default implementation
    /// loops over [`Self::prefill`], so single-request backends (PJRT)
    /// keep working unchanged; the native backends override it to stack
    /// every prompt's token rows into one activation matrix per layer and
    /// run each WAQ LUT-GEMM linear *once* for the burst — amortizing LUT
    /// builds, weight-tile streaming, and thread/shard fan-out the same
    /// way the batched decode step does. Per-request results must be
    /// **bit-exact** with the sequential `prefill` path (enforced by
    /// `tests/backend_parity.rs`).
    ///
    /// All-or-nothing: on `Err` no per-request state may have been
    /// committed anywhere — the engine then answers every admitted
    /// request with an `Aborted` response instead of dropping it.
    fn prefill_batch(&mut self, prompts: &[&[i32]]) -> Result<Vec<PrefillOut>> {
        prompts.iter().map(|p| self.prefill(p)).collect()
    }

    /// Whether [`Self::prefill_paged`] is implemented. The engine only
    /// routes admission through the paged path (and therefore only
    /// honors `--prefix-cache on`) when this is true; backends that
    /// produce dense KV pairs (PJRT, test fixtures) keep the
    /// `prefill_batch` + `install_prefill` admission path.
    fn supports_paged_prefill(&self) -> bool {
        false
    }

    /// Prefill an admission burst *through the paged cache*: for each
    /// request, append K/V rows for the uncached tail positions directly
    /// into `kv` (slot already claimed at `cached`) and compute the tail's
    /// attention by reading the cache's stored representation — the same
    /// fused-dequant gathers decode uses. That makes a cold run and a
    /// prefix-hit run bit-exact by construction at every `--kv-bits`:
    /// both read identical stored payloads. Returns one result per
    /// request, in order.
    ///
    /// All-or-nothing like `prefill_batch`: on `Err` the engine releases
    /// every burst slot (partial appends are reclaimed with the slots)
    /// and answers `Aborted`.
    fn prefill_paged(
        &mut self,
        reqs: &[PagedPrefill<'_>],
        kv: &mut KvManager,
    ) -> Result<Vec<PagedPrefillOut>> {
        let _ = (reqs, kv);
        Err(anyhow::anyhow!(
            "backend {} does not implement paged prefill",
            self.spec().name()
        ))
    }

    /// Run one batched decode step over all `decode_batch` slots.
    /// `toks[b]`/`pos[b]` are the last generated token and its cache
    /// position for slot `b`; `active[b]` marks live slots (inactive slots
    /// may produce garbage logits the engine ignores). Reads and updates
    /// the slot caches through `kv`. Returns row-major logits of shape
    /// (decode_batch, vocab).
    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> Result<(Vec<f32>, StepCost)>;

    /// Score every run's token sequence against the paged cache in one
    /// stacked pass: for each [`VerifyRun`], append K/V for
    /// `tokens[0..len]` at positions `start..start + len` through `kv`
    /// and return row-major `(len, vocab)` logits per run, in order.
    /// Position `start + j`'s logits must be bit-exact with what a plain
    /// `decode` of `tokens[j]` at that position would produce — the
    /// contract speculative verification rides on. Default: unsupported.
    fn verify_paged(
        &mut self,
        runs: &[VerifyRun<'_>],
        kv: &mut KvManager,
    ) -> Result<(Vec<Vec<f32>>, StepCost)> {
        let _ = (runs, kv);
        Err(anyhow::anyhow!(
            "backend {} does not implement stacked verification",
            self.spec().name()
        ))
    }

    /// The per-linear weight bit plan this backend serves (layer-major,
    /// four linears per layer: qkv, attn_out, mlp_up, mlp_down), when it
    /// quantizes weights at all. `--wbits auto` surfaces the planner's
    /// choice here (and `EngineStats::to_json` reports it); uniform
    /// configurations report the flat plan. Default: `None` (the PJRT
    /// path serves compiled artifacts, not live-quantized weights).
    fn wbits_plan(&self) -> Option<Vec<u32>> {
        None
    }

    /// Drain the speculative rounds of the latest `decode` call, if this
    /// backend runs speculative decoding. `Some(rounds)` tells the engine
    /// the backend already advanced/truncated the cache itself — the
    /// engine must emit each round's accepted tokens (per-token stop
    /// checks) and sample the logits row as usual, but must NOT call
    /// `KvManager::advance`. Default: `None` (ordinary decode semantics).
    fn take_spec_rounds(&mut self) -> Option<Vec<SpecRound>> {
        None
    }

    /// Whether the engine must route admission through the paged path
    /// even when the prefix cache is off. Speculative decoding needs
    /// every slot resident in the shared paged cache (its rollback is
    /// `KvManager::truncate`), so it cannot accept dense-KV admission.
    fn requires_paged_admission(&self) -> bool {
        false
    }

    /// Run one mixed iteration-level step (`--sched chunked`): the
    /// budgeted prefill chunks, then the batched decode over the active
    /// slots. The default executes the two phases as separate calls —
    /// chunks through [`Self::prefill_paged`], whose resume-cursor
    /// contract (`cached` positions already written, compute only the
    /// tail of the prompt slice) is exactly a chunk — so every paged
    /// backend composes without an override, and wrappers like
    /// [`ChaosBackend`] keep their per-call fault draws because the
    /// inner calls dispatch through the vtable. `PjrtBackend` is
    /// untouched: the engine never schedules chunked work on a backend
    /// without paged prefill. An empty chunk list skips the prefill
    /// call entirely and a step with no active slot skips decode, so
    /// neither phase consumes chaos randomness it didn't need.
    ///
    /// Chunked scheduling is bit-exact per request with the burst path
    /// because each chunk replays the identical per-row float sequence
    /// `prefill_paged` would run for those positions inside one call —
    /// attention reads the same stored cache payloads either way; only
    /// the interleaving across *requests* changes.
    fn schedule(&mut self, work: &ScheduleWork<'_>, kv: &mut KvManager) -> ScheduleOut {
        let chunks = if work.chunks.is_empty() {
            Ok(Vec::new())
        } else {
            self.prefill_paged(&work.chunks, kv)
        };
        let decode = work
            .active
            .iter()
            .any(|&a| a)
            .then(|| self.decode(work.toks, work.pos, work.active, kv));
        ScheduleOut { chunks, decode }
    }
}

/// Shared modeled-cost clock: both backends report the same OASIS
/// simulator numbers for the same work, so responses stay comparable
/// across execution engines; only `host_waq_s` semantics differ.
pub(crate) struct CostModel {
    hw: HwConfig,
    spec: LlmSpec,
    mode: OasisMode,
    host: CpuWaqModel,
}

impl CostModel {
    pub(crate) fn new(m: ModelCfg, mode: OasisMode, waq: WaqBackend) -> CostModel {
        let spec = LlmSpec {
            name: "served",
            n_layers: m.n_layers,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_heads,
            d_ff: m.d_ff,
            vocab: m.vocab,
            gated_mlp: false,
        };
        CostModel { hw: HwConfig::default(), spec, mode, host: CpuWaqModel::host(waq) }
    }

    pub(crate) fn prefill(&self, plen: usize) -> StepCost {
        let c = sim::llm::prefill_cost(&self.hw, &self.spec, self.mode, plen.max(1));
        StepCost { accel_s: c.seconds, accel_j: c.energy_j, ..StepCost::default() }
    }

    pub(crate) fn decode(&self, active_n: usize, mean_ctx: usize) -> StepCost {
        let n = active_n.max(1);
        let c = sim::decode_step_cost(&self.hw, &self.spec, self.mode, n, mean_ctx.max(1));
        StepCost {
            accel_s: c.seconds,
            accel_j: c.energy_j,
            host_waq_s: self.host.decode_step_seconds(&self.spec, n),
            ..StepCost::default()
        }
    }
}

/// One decode step's logits for slot 0 against a freshly prefilled cache
/// stored at `precision`: prefill `prompt`, install into slot 0, decode
/// `next_tok` at the next position (other slots padded/inactive). This is
/// the shared probe behind the KV-cache accuracy tests and the
/// `kv_cache` bench's `attn_rel_err` rows — one definition, so the
/// tested metric and the benchmarked metric cannot diverge.
pub fn probe_decode_logits(
    backend: &mut dyn DecodeBackend,
    precision: KvPrecision,
    prompt: &[i32],
    next_tok: i32,
) -> Result<Vec<f32>> {
    let m = backend.model();
    let pre = backend.prefill(prompt)?;
    let mut kv = KvManager::with_precision(m, precision);
    kv.install_prefill(0, 1, pre.plen, &pre.k_cache, &pre.v_cache)
        .map_err(anyhow::Error::msg)?;
    let mut toks = vec![0i32; m.decode_batch];
    let mut pos = vec![0i32; m.decode_batch];
    let mut active = vec![false; m.decode_batch];
    toks[0] = next_tok;
    pos[0] = pre.plen as i32;
    active[0] = true;
    let (logits, _) = backend.decode(&toks, &pos, &active, &mut kv)?;
    Ok(logits[..m.vocab].to_vec())
}

/// (active slot count, mean context length) of one decode step.
pub(crate) fn batch_occupancy(pos: &[i32], active: &[bool]) -> (usize, usize) {
    let mut n = 0usize;
    let mut ctx = 0usize;
    for (&p, &a) in pos.iter().zip(active) {
        if a {
            n += 1;
            ctx += p as usize;
        }
    }
    (n, ctx / n.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_roundtrip_and_accepted_list_derive_from_all() {
        for b in WaqBackend::ALL {
            assert_eq!(b.name().parse::<BackendSpec>(), Ok(BackendSpec::Pjrt(b)));
            let native = format!("native-{b}");
            assert_eq!(native.parse::<BackendSpec>(), Ok(BackendSpec::Native(b)));
            assert_eq!(native.parse::<BackendSpec>().unwrap().to_string(), native);
            assert_eq!(BackendSpec::Native(b).waq(), b);
            assert!(BackendSpec::Native(b).is_native());
            assert!(!BackendSpec::Pjrt(b).is_native());
        }
        assert_eq!(
            BackendSpec::accepted(),
            "direct|histogram|packed|native-direct|native-histogram|native-packed|\
             native-sharded|native-spec"
        );
        let err = "tpu".parse::<BackendSpec>().unwrap_err();
        assert!(err.contains("native-packed") && err.contains("histogram"), "{err}");
        // an unknown native kernel is rejected too
        assert!("native-tpu".parse::<BackendSpec>().is_err());
        assert_eq!(BackendSpec::default(), BackendSpec::Pjrt(WaqBackend::Packed));
    }

    #[test]
    fn sharded_spec_roundtrips_and_is_advertised() {
        // the sharded serving path: FromStr/Display round-trip, packed
        // kernel underneath, surfaced in the CLI help/error text
        let sh: BackendSpec = "native-sharded".parse().expect("parse");
        assert_eq!(sh, BackendSpec::NativeSharded);
        assert_eq!(sh.to_string(), "native-sharded");
        assert_eq!(sh.name().parse::<BackendSpec>(), Ok(sh));
        assert_eq!(sh.waq(), WaqBackend::Packed);
        assert!(sh.is_native());
        assert!(BackendSpec::accepted().contains("native-sharded"));
        let err = "tpu".parse::<BackendSpec>().unwrap_err();
        assert!(err.contains("native-sharded"), "{err}");
    }

    #[test]
    fn speculative_spec_roundtrips_and_is_advertised() {
        let sp: BackendSpec = "native-spec".parse().expect("parse");
        assert_eq!(sp, BackendSpec::NativeSpec);
        assert_eq!(sp.to_string(), "native-spec");
        assert_eq!(sp.name().parse::<BackendSpec>(), Ok(sp));
        assert_eq!(sp.waq(), WaqBackend::Packed);
        assert!(sp.is_native());
        assert!(BackendSpec::accepted().contains("native-spec"));
        let err = "tpu".parse::<BackendSpec>().unwrap_err();
        assert!(err.contains("native-spec"), "{err}");
    }

    #[test]
    fn batch_occupancy_counts_active_only() {
        let pos = [4, 0, 8, 2];
        let act = [true, false, true, false];
        assert_eq!(batch_occupancy(&pos, &act), (2, 6));
        assert_eq!(batch_occupancy(&pos, &[false; 4]), (0, 0));
    }
}
