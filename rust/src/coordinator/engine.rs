//! The serving engine: continuous-batched decode over a pluggable
//! [`DecodeBackend`].
//!
//! The engine owns orchestration only — the KV slot manager, the batcher,
//! sampling, and stats. All per-step compute lives behind the
//! `coordinator::backend::DecodeBackend` trait: `PjrtBackend` (AOT
//! artifacts) or `NativeWaqBackend` (the K-Means WAQ LUT-GEMM datapath,
//! executed natively). Each `step()`:
//!   1. admits queued requests into free slots (backend prefill),
//!   2. runs one backend decode step for all slots (inactive slots padded),
//!   3. samples next tokens, advances slots, completes finished requests.
//! A simulated-OASIS clock advances alongside from the backend's
//! `StepCost` reports, so every response carries both measured
//! wall-clock and modeled accelerator latency/energy.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::backend::{BackendSpec, DecodeBackend};
use super::batcher::{AdmitPolicy, Batcher};
use super::kv::KvManager;
use super::request::{EngineStats, FinishReason, Request, Response};
use crate::gemm::WaqBackend;
use crate::kvcache::{KvBits, KvPrecision};
use crate::sim::OasisMode;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: AdmitPolicy,
    pub seed: u64,
    pub mode: OasisMode,
    /// Which execution engine serves decode compute, and which software
    /// WAQ GEMM kernel it runs (`native-*`: measured on the native K-Means
    /// WAQ datapath) or models (`direct|histogram|packed`: PJRT artifacts
    /// with a `CpuWaqModel` host clock). This is a real datapath switch:
    /// `native-*` serving throughput is measured on the LUT-GEMM kernels.
    pub backend: BackendSpec,
    /// KV-cache storage precision (`--kv-bits {32,4,3,2}`): FP32 keeps
    /// the cache bit-exact with the dense layout it replaced; n-bit
    /// stores K-Means index streams with codebooks supplied by the
    /// backend's `kv_quantizer`.
    pub kv_bits: KvBits,
    /// Column-shard count for the tensor-parallel sharded backend
    /// (`--backend native-sharded --shards N`); ignored by the other
    /// backends. Must be >= 1 — `ShardedWaqBackend::new` rejects 0 with a
    /// real error (and `kllm serve` refuses `--shards 0` up front).
    pub shards: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AdmitPolicy::OnePerStep,
            seed: 0xE116,
            mode: OasisMode::a4(),
            backend: BackendSpec::default(),
            kv_bits: KvBits::Fp32,
            shards: 2,
        }
    }
}

struct ActiveReq {
    req: Request,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
    /// sim-clock marks at admission, so responses report per-request
    /// deltas (not the engine's running totals)
    modeled_start_s: f64,
    modeled_start_j: f64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SimTotals {
    pub seconds: f64,
    pub energy_j: f64,
}

pub struct Engine {
    backend: Box<dyn DecodeBackend>,
    kv: KvManager,
    batcher: Batcher,
    active: Vec<Option<ActiveReq>>,
    pub stats: EngineStats,
    pub sim: SimTotals,
    rng: Rng,
}

impl Engine {
    /// Build an engine over an already-constructed backend. (`cfg.backend`
    /// describes how a `Coordinator` constructs one; here the caller has.)
    pub fn new(backend: Box<dyn DecodeBackend>, cfg: &EngineConfig) -> Engine {
        let m = backend.model();
        let precision = match cfg.kv_bits {
            KvBits::Fp32 => KvPrecision::Fp32,
            quantized => KvPrecision::Quant(backend.kv_quantizer(quantized.bits())),
        };
        let kv = KvManager::with_precision(m, precision);
        let stats = EngineStats {
            waq_backend: backend.spec().name(),
            kv_bits: cfg.kv_bits.bits(),
            kv_bytes_per_token: kv.bytes_per_token(),
            ..Default::default()
        };
        Engine {
            kv,
            batcher: Batcher::new(cfg.policy),
            active: (0..m.decode_batch).map(|_| None).collect(),
            stats,
            sim: SimTotals::default(),
            rng: Rng::new(cfg.seed),
            backend,
        }
    }

    /// Which execution engine + WAQ kernel this engine decodes with.
    pub fn backend_spec(&self) -> BackendSpec {
        self.backend.spec()
    }

    /// The software WAQ GEMM kernel the backend runs or models.
    pub fn waq_backend(&self) -> WaqBackend {
        self.backend.spec().waq()
    }

    pub fn model(&self) -> crate::runtime::artifacts::ModelCfg {
        self.backend.model()
    }

    /// The KV slot manager (paged-cache introspection for invariant
    /// checks and benches; the engine retains ownership).
    pub fn kv(&self) -> &KvManager {
        &self.kv
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.enqueue(r);
    }

    pub fn has_work(&self) -> bool {
        self.batcher.pending() > 0 || self.kv.active_count() > 0
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    pub fn active_count(&self) -> usize {
        self.kv.active_count()
    }

    /// One engine iteration; returns completed responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- admission (prefill) ---------------------------------------
        let free = self.kv.decode_batch_free();
        for req in self.batcher.admit(free) {
            let slot = self
                .kv
                .free_slot()
                .ok_or_else(|| anyhow!("admit with no free slot"))?;
            // the sim-clock marks are taken before the prefill cost lands,
            // so each response's modeled delta includes its own prefill
            let (start_s, start_j) = (self.sim.seconds, self.sim.energy_j);
            let pre = self
                .backend
                .prefill(&req.prompt)
                .map_err(|e| anyhow!("prefill failed: {e}"))?;
            self.kv
                .install_prefill(slot, req.id, pre.plen, &pre.k_cache, &pre.v_cache)
                .map_err(|e| anyhow!(e))?;
            self.stats.prefills += 1;
            self.sim.seconds += pre.cost.accel_s;
            self.sim.energy_j += pre.cost.accel_j;
            self.stats.host_waq_s += pre.cost.host_waq_s;
            self.stats.host_shard_crit_s += pre.cost.shard_crit_s;
            // the prefill's last-position logits give token #1
            let tok = self.sample(&pre.logits, req.temperature);
            let mut ar = ActiveReq {
                req,
                generated: vec![tok],
                first_token_at: Some(Instant::now()),
                modeled_start_s: start_s,
                modeled_start_j: start_j,
            };
            self.stats.generated_tokens += 1;
            // completion checks on the very first token
            if let Some(resp) = self.maybe_finish(slot, &mut ar) {
                self.kv.release(slot);
                done.push(resp);
            } else {
                self.active[slot] = Some(ar);
            }
        }

        // ---- decode ------------------------------------------------------
        if self.kv.active_count() > 0 {
            let responses = self.decode_step()?;
            done.extend(responses);
        }
        // peak_cache_bytes is monotone; the running max just makes the
        // stat robust to any future non-monotone accounting
        self.stats.peak_kv_bytes =
            self.stats.peak_kv_bytes.max(self.kv.peak_cache_bytes() as u64);
        Ok(done)
    }

    /// Drain everything (used by benches/tests): step until idle.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn decode_step(&mut self) -> Result<Vec<Response>> {
        let m = self.backend.model();
        let b = m.decode_batch;
        // last generated token + write position per slot (pads elsewhere)
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        let mut occupancy = 0u64;
        for slot in 0..b {
            if let Some(ar) = &self.active[slot] {
                toks[slot] = *ar.generated.last().unwrap();
                pos[slot] = self.kv.position(slot).unwrap() as i32;
                active[slot] = true;
                occupancy += 1;
            }
        }

        let (logits, cost) = self
            .backend
            .decode(&toks, &pos, &active, &mut self.kv)?;

        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += occupancy;
        self.sim.seconds += cost.accel_s;
        self.sim.energy_j += cost.accel_j;
        // host software-datapath seconds: measured for native backends,
        // the CpuWaqModel roofline for PJRT; the shard critical path is
        // the slowest-shard sum for the tensor-parallel backend
        self.stats.host_waq_s += cost.host_waq_s;
        self.stats.host_shard_crit_s += cost.shard_crit_s;

        let mut done = Vec::new();
        for slot in 0..b {
            let Some(mut ar) = self.active[slot].take() else { continue };
            self.kv.advance(slot).map_err(|e| anyhow!(e))?;
            let lrow = &logits[slot * m.vocab..(slot + 1) * m.vocab];
            let tok = self.sample(lrow, ar.req.temperature);
            ar.generated.push(tok);
            self.stats.generated_tokens += 1;
            if ar.first_token_at.is_none() {
                ar.first_token_at = Some(Instant::now());
            }
            if let Some(resp) = self.maybe_finish(slot, &mut ar) {
                self.kv.release(slot);
                done.push(resp);
            } else {
                self.active[slot] = Some(ar);
            }
        }
        Ok(done)
    }

    fn maybe_finish(&mut self, slot: usize, ar: &mut ActiveReq) -> Option<Response> {
        let last = *ar.generated.last().unwrap();
        let reason = if ar.req.eos_token == Some(last) {
            Some(FinishReason::Eos)
        } else if ar.generated.len() >= ar.req.max_new_tokens {
            Some(FinishReason::MaxTokens)
        } else if self.kv.exhausted(slot) {
            Some(FinishReason::Length)
        } else {
            None
        };
        reason.map(|fr| {
            self.stats.completed += 1;
            Response {
                id: ar.req.id,
                prompt_len: ar.req.prompt.len(),
                tokens: std::mem::take(&mut ar.generated),
                finish_reason: fr,
                ttft_s: ar
                    .first_token_at
                    .map(|t| (t - ar.req.arrived).as_secs_f64())
                    .unwrap_or(0.0),
                total_s: ar.req.arrived.elapsed().as_secs_f64(),
                modeled_accel_s: self.sim.seconds - ar.modeled_start_s,
                modeled_accel_j: self.sim.energy_j - ar.modeled_start_j,
            }
        })
    }

    fn sample(&mut self, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
        }
        // softmax sample
        let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let exps: Vec<f64> = logits
            .iter()
            .map(|&x| (((x - maxv) / temperature) as f64).exp())
            .collect();
        let total: f64 = exps.iter().sum();
        let mut u = self.rng.f64() * total;
        for (i, e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (logits.len() - 1) as i32
    }

    /// Abort everything in flight (shutdown path). In-flight requests
    /// report their real TTFT (if a first token was emitted) and their
    /// modeled-cost deltas so far; queued requests report zeros.
    pub fn abort_all(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        for slot in 0..self.active.len() {
            if let Some(mut ar) = self.active[slot].take() {
                self.kv.release(slot);
                out.push(Response {
                    id: ar.req.id,
                    prompt_len: ar.req.prompt.len(),
                    tokens: std::mem::take(&mut ar.generated),
                    finish_reason: FinishReason::Aborted,
                    ttft_s: ar
                        .first_token_at
                        .map(|t| (t - ar.req.arrived).as_secs_f64())
                        .unwrap_or(0.0),
                    total_s: ar.req.arrived.elapsed().as_secs_f64(),
                    modeled_accel_s: self.sim.seconds - ar.modeled_start_s,
                    modeled_accel_j: self.sim.energy_j - ar.modeled_start_j,
                });
            }
        }
        for req in self.batcher.drain() {
            out.push(Response {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: vec![],
                finish_reason: FinishReason::Aborted,
                ttft_s: 0.0,
                total_s: req.arrived.elapsed().as_secs_f64(),
                modeled_accel_s: 0.0,
                modeled_accel_j: 0.0,
            });
        }
        out
    }
}

impl KvManager {
    /// free-slot count helper used by the batcher handshake
    pub fn decode_batch_free(&self) -> usize {
        self.slots.iter().filter(|s| **s == super::kv::Slot::Free).count()
    }
}
