//! The serving engine: continuous-batched decode over a pluggable
//! [`DecodeBackend`].
//!
//! The engine owns orchestration only — the KV slot manager, the batcher,
//! sampling, and stats. All per-step compute lives behind the
//! `coordinator::backend::DecodeBackend` trait: `PjrtBackend` (AOT
//! artifacts) or `NativeWaqBackend` (the K-Means WAQ LUT-GEMM datapath,
//! executed natively). Each `step()`:
//!   1. admits queued requests into free slots (backend prefill),
//!   2. runs one backend decode step for all slots (inactive slots padded),
//!   3. samples next tokens, advances slots, completes finished requests.
//! A simulated-OASIS clock advances alongside from the backend's
//! `StepCost` reports, so every response carries both measured
//! wall-clock and modeled accelerator latency/energy.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::backend::{BackendSpec, DecodeBackend};
use super::batcher::{AdmitPolicy, Batcher};
use super::kv::KvManager;
use super::request::{EngineStats, FinishReason, Request, Response};
use crate::gemm::WaqBackend;
use crate::kvcache::{KvBits, KvPrecision};
use crate::sim::OasisMode;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: AdmitPolicy,
    pub seed: u64,
    pub mode: OasisMode,
    /// Which execution engine serves decode compute, and which software
    /// WAQ GEMM kernel it runs (`native-*`: measured on the native K-Means
    /// WAQ datapath) or models (`direct|histogram|packed`: PJRT artifacts
    /// with a `CpuWaqModel` host clock). This is a real datapath switch:
    /// `native-*` serving throughput is measured on the LUT-GEMM kernels.
    pub backend: BackendSpec,
    /// KV-cache storage precision (`--kv-bits {32,4,3,2}`): FP32 keeps
    /// the cache bit-exact with the dense layout it replaced; n-bit
    /// stores K-Means index streams with codebooks supplied by the
    /// backend's `kv_quantizer`.
    pub kv_bits: KvBits,
    /// Column-shard count for the tensor-parallel sharded backend
    /// (`--backend native-sharded --shards N`); ignored by the other
    /// backends. Must be >= 1 — `ShardedWaqBackend::new` rejects 0 with a
    /// real error (and `kllm serve` refuses `--shards 0` up front).
    pub shards: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AdmitPolicy::OnePerStep,
            seed: 0xE116,
            mode: OasisMode::a4(),
            backend: BackendSpec::default(),
            kv_bits: KvBits::Fp32,
            shards: 2,
        }
    }
}

struct ActiveReq {
    req: Request,
    generated: Vec<i32>,
    /// when admission sampled the prefill's token — a request is only
    /// active after its first token exists, so this is never "pending"
    first_token_at: Instant,
    /// the backend consumed fewer prompt tokens than submitted
    truncated_prompt: bool,
    /// sim-clock marks at admission, so responses report per-request
    /// deltas (not the engine's running totals)
    modeled_start_s: f64,
    modeled_start_j: f64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SimTotals {
    pub seconds: f64,
    pub energy_j: f64,
}

pub struct Engine {
    backend: Box<dyn DecodeBackend>,
    kv: KvManager,
    batcher: Batcher,
    active: Vec<Option<ActiveReq>>,
    pub stats: EngineStats,
    pub sim: SimTotals,
    rng: Rng,
}

impl Engine {
    /// Build an engine over an already-constructed backend. (`cfg.backend`
    /// describes how a `Coordinator` constructs one; here the caller has.)
    pub fn new(backend: Box<dyn DecodeBackend>, cfg: &EngineConfig) -> Engine {
        let m = backend.model();
        let precision = match cfg.kv_bits {
            KvBits::Fp32 => KvPrecision::Fp32,
            quantized => KvPrecision::Quant(backend.kv_quantizer(quantized.bits())),
        };
        let kv = KvManager::with_precision(m, precision);
        let stats = EngineStats {
            waq_backend: backend.spec().name(),
            kv_bits: cfg.kv_bits.bits(),
            kv_bytes_per_token: kv.bytes_per_token(),
            ..Default::default()
        };
        Engine {
            kv,
            batcher: Batcher::new(cfg.policy),
            active: (0..m.decode_batch).map(|_| None).collect(),
            stats,
            sim: SimTotals::default(),
            rng: Rng::new(cfg.seed),
            backend,
        }
    }

    /// Which execution engine + WAQ kernel this engine decodes with.
    pub fn backend_spec(&self) -> BackendSpec {
        self.backend.spec()
    }

    /// The software WAQ GEMM kernel the backend runs or models.
    pub fn waq_backend(&self) -> WaqBackend {
        self.backend.spec().waq()
    }

    pub fn model(&self) -> crate::runtime::artifacts::ModelCfg {
        self.backend.model()
    }

    /// The KV slot manager (paged-cache introspection for invariant
    /// checks and benches; the engine retains ownership).
    pub fn kv(&self) -> &KvManager {
        &self.kv
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.enqueue(r);
    }

    pub fn has_work(&self) -> bool {
        self.batcher.pending() > 0 || self.kv.active_count() > 0
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    pub fn active_count(&self) -> usize {
        self.kv.active_count()
    }

    /// One engine iteration; returns completed responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- admission (batched prefill) -------------------------------
        // The whole admit burst goes through ONE backend call: the native
        // backends stack every prompt's rows and run each WAQ LUT-GEMM
        // linear once per layer for the burst (bit-exact per request with
        // the sequential path); the PJRT default loops internally.
        let free = self.kv.decode_batch_free();
        let admitted = self.batcher.admit(free);
        if !admitted.is_empty() {
            let prompts: Vec<&[i32]> = admitted.iter().map(|r| r.prompt.as_slice()).collect();
            match self.backend.prefill_batch(&prompts) {
                Ok(pres) if pres.len() == admitted.len() => {
                    for (req, pre) in admitted.into_iter().zip(pres) {
                        let slot = self
                            .kv
                            .free_slot()
                            .ok_or_else(|| anyhow!("admit with no free slot"))?;
                        // the sim-clock marks are taken before the prefill
                        // cost lands, so each response's modeled delta
                        // includes its own prefill (per-request costs come
                        // from the backend even for a batched burst)
                        let (start_s, start_j) = (self.sim.seconds, self.sim.energy_j);
                        let truncated = pre.plen < req.prompt.len();
                        self.kv
                            .install_prefill(slot, req.id, pre.plen, &pre.k_cache, &pre.v_cache)
                            .map_err(|e| anyhow!(e))?;
                        self.stats.prefills += 1;
                        if truncated {
                            self.stats.truncated_prompts += 1;
                        }
                        self.sim.seconds += pre.cost.accel_s;
                        self.sim.energy_j += pre.cost.accel_j;
                        self.stats.host_waq_s += pre.cost.host_waq_s;
                        self.stats.host_shard_crit_s += pre.cost.shard_crit_s;
                        // the prefill's last-position logits give token #1
                        let tok = self.sample(&pre.logits, req.temperature);
                        let mut ar = ActiveReq {
                            req,
                            generated: vec![tok],
                            first_token_at: Instant::now(),
                            truncated_prompt: truncated,
                            modeled_start_s: start_s,
                            modeled_start_j: start_j,
                        };
                        self.stats.generated_tokens += 1;
                        // completion checks on the very first token
                        if let Some(resp) = self.maybe_finish(slot, &mut ar) {
                            self.kv.release(slot);
                            done.push(resp);
                        } else {
                            self.active[slot] = Some(ar);
                        }
                    }
                }
                // a failed (or arity-broken) burst prefill must not drop
                // admitted requests on the floor: nothing was installed,
                // so every request gets an Aborted response and the
                // engine keeps serving
                fail => {
                    let err = match fail {
                        Err(e) => e.to_string(),
                        Ok(p) => format!(
                            "backend returned {} prefill results for {} prompts",
                            p.len(),
                            admitted.len()
                        ),
                    };
                    eprintln!(
                        "engine: burst prefill failed ({err}); aborting {} admitted request(s)",
                        admitted.len()
                    );
                    self.stats.prefill_failures += 1;
                    done.extend(admitted.iter().map(aborted_response));
                }
            }
        }

        // ---- decode ------------------------------------------------------
        if self.kv.active_count() > 0 {
            let responses = self.decode_step()?;
            done.extend(responses);
        }
        // peak_cache_bytes is monotone; the running max just makes the
        // stat robust to any future non-monotone accounting
        self.stats.peak_kv_bytes =
            self.stats.peak_kv_bytes.max(self.kv.peak_cache_bytes() as u64);
        Ok(done)
    }

    /// Drain everything (used by benches/tests): step until idle.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn decode_step(&mut self) -> Result<Vec<Response>> {
        let m = self.backend.model();
        let b = m.decode_batch;
        // last generated token + write position per slot (pads elsewhere)
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        let mut occupancy = 0u64;
        for slot in 0..b {
            if let Some(ar) = &self.active[slot] {
                toks[slot] = *ar.generated.last().unwrap();
                pos[slot] = self.kv.position(slot).unwrap() as i32;
                active[slot] = true;
                occupancy += 1;
            }
        }

        let (logits, cost) = self
            .backend
            .decode(&toks, &pos, &active, &mut self.kv)?;

        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += occupancy;
        self.sim.seconds += cost.accel_s;
        self.sim.energy_j += cost.accel_j;
        // host software-datapath seconds: measured for native backends,
        // the CpuWaqModel roofline for PJRT; the shard critical path is
        // the slowest-shard sum for the tensor-parallel backend
        self.stats.host_waq_s += cost.host_waq_s;
        self.stats.host_shard_crit_s += cost.shard_crit_s;

        let mut done = Vec::new();
        for slot in 0..b {
            let Some(mut ar) = self.active[slot].take() else { continue };
            self.kv.advance(slot).map_err(|e| anyhow!(e))?;
            let lrow = &logits[slot * m.vocab..(slot + 1) * m.vocab];
            let tok = self.sample(lrow, ar.req.temperature);
            ar.generated.push(tok);
            self.stats.generated_tokens += 1;
            // no first-token bookkeeping here: admission always records
            // `first_token_at` when it samples the prefill's token, so a
            // decode step can never produce a request's first token
            if let Some(resp) = self.maybe_finish(slot, &mut ar) {
                self.kv.release(slot);
                done.push(resp);
            } else {
                self.active[slot] = Some(ar);
            }
        }
        Ok(done)
    }

    fn maybe_finish(&mut self, slot: usize, ar: &mut ActiveReq) -> Option<Response> {
        let last = *ar.generated.last().unwrap();
        let reason = if ar.req.eos_token == Some(last) {
            Some(FinishReason::Eos)
        } else if ar.generated.len() >= ar.req.max_new_tokens {
            Some(FinishReason::MaxTokens)
        } else if self.kv.exhausted(slot) {
            Some(FinishReason::Length)
        } else {
            None
        };
        reason.map(|fr| {
            self.stats.completed += 1;
            self.response_for(ar, fr)
        })
    }

    /// Build the response for a request leaving the engine (completion or
    /// abort): ONE construction site, so response fields cannot diverge
    /// between the finish and abort paths.
    fn response_for(&self, ar: &mut ActiveReq, fr: FinishReason) -> Response {
        Response {
            id: ar.req.id,
            prompt_len: ar.req.prompt.len(),
            tokens: std::mem::take(&mut ar.generated),
            finish_reason: fr,
            truncated_prompt: ar.truncated_prompt,
            ttft_s: (ar.first_token_at - ar.req.arrived).as_secs_f64(),
            total_s: ar.req.arrived.elapsed().as_secs_f64(),
            modeled_accel_s: self.sim.seconds - ar.modeled_start_s,
            modeled_accel_j: self.sim.energy_j - ar.modeled_start_j,
        }
    }

    /// Sample the next token from one logit row. NaN-safe in both
    /// branches: a numerically poisoned row (overflowed accumulator, bad
    /// weights) must never panic the engine thread — see
    /// [`greedy_argmax`] and the zero-weighting of NaN entries below.
    fn sample(&mut self, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return greedy_argmax(logits);
        }
        // softmax sample; NaN logits carry zero probability mass (f32::max
        // already ignores NaN, so `maxv` is the finite max when one exists)
        let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let exps: Vec<f64> = logits
            .iter()
            .map(|&x| {
                if x.is_nan() {
                    0.0
                } else {
                    (((x - maxv) / temperature) as f64).exp()
                }
            })
            .collect();
        let total: f64 = exps.iter().sum();
        let mut u = self.rng.f64() * total;
        for (i, e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (logits.len() - 1) as i32
    }

    /// Abort everything in flight (shutdown path). In-flight requests
    /// always report a real TTFT (their first token was sampled at
    /// admission) and their modeled-cost deltas so far; queued requests
    /// report zeros.
    pub fn abort_all(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        for slot in 0..self.active.len() {
            if let Some(mut ar) = self.active[slot].take() {
                self.kv.release(slot);
                out.push(self.response_for(&mut ar, FinishReason::Aborted));
            }
        }
        for req in self.batcher.drain() {
            out.push(aborted_response(&req));
        }
        out
    }
}

/// Response for a request aborted before any compute landed for it (a
/// failed burst prefill, or a queued request drained at shutdown): no
/// tokens, zero TTFT, zero modeled deltas.
fn aborted_response(req: &Request) -> Response {
    Response {
        id: req.id,
        prompt_len: req.prompt.len(),
        tokens: vec![],
        finish_reason: FinishReason::Aborted,
        truncated_prompt: false,
        ttft_s: 0.0,
        total_s: req.arrived.elapsed().as_secs_f64(),
        modeled_accel_s: 0.0,
        modeled_accel_j: 0.0,
    }
}

/// Greedy argmax over one logit row, NaN-safe: NaN entries are skipped
/// (a poisoned channel cannot hijack the argmax), the comparator is the
/// total order `f32::total_cmp` (ties resolve to the highest index, as
/// the old `partial_cmp` argmax did), and an all-NaN row falls back to
/// token 0 instead of panicking the engine thread.
fn greedy_argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

impl KvManager {
    /// free-slot count helper used by the batcher handshake
    pub fn decode_batch_free(&self) -> usize {
        self.slots.iter().filter(|s| **s == super::kv::Slot::Free).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::PrefillOut;
    use crate::coordinator::backend::StepCost;
    use crate::runtime::artifacts::ModelCfg;
    use crate::runtime::HostTensor;

    #[test]
    fn greedy_argmax_skips_nan_and_never_panics() {
        // plain rows behave exactly like the old partial_cmp argmax
        assert_eq!(greedy_argmax(&[0.1, 2.0, -1.0]), 1);
        // ties resolve to the highest index (max_by keeps the last max)
        assert_eq!(greedy_argmax(&[3.0, 3.0, 1.0]), 1);
        // a NaN-poisoned channel cannot hijack the argmax
        assert_eq!(greedy_argmax(&[0.5, f32::NAN, 2.0, f32::NAN, -7.0]), 2);
        assert_eq!(greedy_argmax(&[f32::NAN, 1.0]), 1);
        // -inf rows still pick a real index; an all-NaN row falls back to 0
        assert_eq!(greedy_argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 1);
        assert_eq!(greedy_argmax(&[f32::NAN, f32::NAN, f32::NAN]), 0);
        assert_eq!(greedy_argmax(&[]), 0);
    }

    /// Backend that emits NaN-poisoned logit rows: one finite channel at
    /// prefill (index 3), all-NaN rows at decode — the shape of a
    /// numerically blown-up datapath.
    struct NanBackend {
        model: ModelCfg,
    }

    impl DecodeBackend for NanBackend {
        fn spec(&self) -> BackendSpec {
            BackendSpec::Native(WaqBackend::Packed)
        }

        fn model(&self) -> ModelCfg {
            self.model
        }

        fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
            let m = self.model;
            let plen = prompt.len().clamp(1, m.seq_len - 1);
            let shape = [m.n_layers, 1, m.n_heads, m.seq_len, m.head_dim];
            let mut logits = vec![f32::NAN; m.vocab];
            logits[3] = 1.0;
            Ok(PrefillOut {
                plen,
                logits,
                k_cache: HostTensor::zeros(&shape),
                v_cache: HostTensor::zeros(&shape),
                cost: StepCost::default(),
            })
        }

        fn decode(
            &mut self,
            _toks: &[i32],
            _pos: &[i32],
            _active: &[bool],
            _kv: &mut KvManager,
        ) -> Result<(Vec<f32>, StepCost)> {
            let m = self.model;
            Ok((vec![f32::NAN; m.decode_batch * m.vocab], StepCost::default()))
        }
    }

    /// NaN logits must never panic the engine thread — greedy picks the
    /// finite channel (prefill) or falls back to token 0 (all-NaN decode
    /// rows), and the softmax branch treats NaN as zero probability mass.
    #[test]
    fn nan_logits_never_panic_sampling() {
        let cfg = ModelCfg::test_preset();
        let mut e = Engine::new(Box::new(NanBackend { model: cfg }), &EngineConfig::default());
        e.submit(Request::new(1, vec![1, 2, 3], 3));
        let mut greedy = e.run_to_completion().expect("greedy run");
        let r = greedy.remove(0);
        assert_eq!(r.tokens.len(), 3);
        assert_eq!(r.tokens[0], 3, "greedy must find the finite channel");
        assert!(r.tokens[1..].iter().all(|&t| t == 0), "all-NaN rows fall back to 0");

        // softmax branch: all-NaN decode rows carry zero mass, sampling
        // stays in-vocab without panicking
        let mut req = Request::new(2, vec![4, 5], 4);
        req.temperature = 1.0;
        e.submit(req);
        let sampled = e.run_to_completion().expect("softmax run").remove(0);
        assert_eq!(sampled.tokens.len(), 4);
        assert!(sampled
            .tokens
            .iter()
            .all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    }
}
