//! The serving engine: continuous-batched decode over a pluggable
//! [`DecodeBackend`].
//!
//! The engine owns orchestration only — the KV slot manager, the batcher,
//! sampling, and stats. All per-step compute lives behind the
//! `coordinator::backend::DecodeBackend` trait: `PjrtBackend` (AOT
//! artifacts) or `NativeWaqBackend` (the K-Means WAQ LUT-GEMM datapath,
//! executed natively). Two schedulers share the engine
//! (`--sched {burst,chunked}`, [`SchedPolicy`]):
//!
//! - **Burst** (default, the original phased loop): each `step()`
//!   1. admits queued requests into free slots (backend prefill, whole
//!      prompts),
//!   2. runs one backend decode step for all slots (inactive slots padded),
//!   3. samples next tokens, advances slots, completes finished requests.
//!
//! - **Chunked** (iteration-level, vLLM-style): each `step()` assembles
//!   ONE mixed backend pass — the active decode slots plus a budgeted
//!   *chunk* of pending prefill rows ([`DecodeBackend::schedule`]).
//!   Prompts prefill incrementally across steps behind per-request
//!   cursors, so per-step work — and therefore decode inter-token
//!   latency — stays bounded no matter how long the queued prompts are.
//!   The chunk budget follows the measured datapath (shard critical
//!   path, EWMA-tracked) unless pinned by `--prefill-chunk`. Token
//!   streams are bit-exact with Burst: paged prefill attention is
//!   row-independent, so splitting a prompt across chunks replays the
//!   identical float sequence — and sampling draws from a *per-request*
//!   RNG stream (seeded from the engine seed and the request id), so
//!   sampled (temperature > 0) streams match too, no matter how the
//!   schedulers interleave the batch.
//!
//! A simulated-OASIS clock advances alongside from the backend's
//! `StepCost` reports, so every response carries both measured
//! wall-clock and modeled accelerator latency/energy.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::backend::chaos::ChaosCfg;
use super::backend::{
    BackendSpec, CostModel, DecodeBackend, PagedPrefill, PagedPrefillOut, ScheduleWork, SpecRound,
    StepCost, WbitsSpec,
};
use super::batcher::{AdmitPolicy, Batcher};
use super::kv::KvManager;
use super::request::{EngineStats, FinishReason, Request, Response};
use crate::gemm::WaqBackend;
use crate::kvcache::{KvBits, KvPrecision};
use crate::sim::OasisMode;
use crate::util::rng::Rng;

/// Scheduler shape for [`Engine::step`] (`--sched {burst,chunked}`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// The phased loop: admit a burst, prefill every admitted prompt
    /// whole, then decode — one long prompt stalls every in-flight
    /// decode for its entire prefill.
    #[default]
    Burst,
    /// Iteration-level scheduling: every step runs ONE mixed backend
    /// pass of the active decode slots plus a budgeted chunk of pending
    /// prefill rows, so per-step work — and decode inter-token latency —
    /// stays bounded while prompts of any length stream in. Requires a
    /// paged-prefill backend (falls back to `Burst` with a logged
    /// warning otherwise). Greedy token streams are bit-exact with
    /// `Burst`: same tokens, different interleaving.
    Chunked,
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SchedPolicy::Burst => "burst",
            SchedPolicy::Chunked => "chunked",
        })
    }
}

impl std::str::FromStr for SchedPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "burst" => Ok(SchedPolicy::Burst),
            "chunked" => Ok(SchedPolicy::Chunked),
            other => Err(format!("unknown scheduler '{other}' (expected burst|chunked)")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: AdmitPolicy,
    /// Sampling seed. Each request draws from its own RNG stream seeded
    /// from `(seed, request id)`, so a sampled request's tokens are a
    /// pure function of its id and its own logits — independent of batch
    /// composition, admission order, and scheduler policy.
    pub seed: u64,
    pub mode: OasisMode,
    /// Which execution engine serves decode compute, and which software
    /// WAQ GEMM kernel it runs (`native-*`: measured on the native K-Means
    /// WAQ datapath) or models (`direct|histogram|packed`: PJRT artifacts
    /// with a `CpuWaqModel` host clock). This is a real datapath switch:
    /// `native-*` serving throughput is measured on the LUT-GEMM kernels.
    pub backend: BackendSpec,
    /// KV-cache storage precision (`--kv-bits {32,4,3,2}`): FP32 keeps
    /// the cache bit-exact with the dense layout it replaced; n-bit
    /// stores K-Means index streams with codebooks supplied by the
    /// backend's `kv_quantizer`.
    pub kv_bits: KvBits,
    /// Column-shard count for the tensor-parallel sharded backend
    /// (`--backend native-sharded --shards N`); ignored by the other
    /// backends. Must be >= 1 — `ShardedWaqBackend::new` rejects 0 with a
    /// real error (and `kllm serve` refuses `--shards 0` up front).
    pub shards: usize,
    /// Bounded admission (`--queue-cap`): maximum queued (not-yet-admitted)
    /// requests. A submit arriving with the queue at cap is answered
    /// *immediately* with [`FinishReason::Rejected`] — backpressure, never
    /// a silent drop. `0` (default) keeps the queue unbounded.
    pub queue_cap: usize,
    /// Default per-request deadline (`--default-deadline-ms`), applied at
    /// submit to requests that didn't set their own. `0` (default) means
    /// no deadline. Per-request overrides come through the TCP JSON field
    /// `deadline_ms` or `Request::with_deadline_ms`.
    pub default_deadline_ms: u64,
    /// Deterministic fault injection (`--chaos-seed`/`--chaos-rate`):
    /// when set, the coordinator wraps the constructed backend in a
    /// [`super::backend::chaos::ChaosBackend`] injecting seeded prefill /
    /// decode errors, NaN logit rows, and latency spikes. `None` (default)
    /// = no injection. Composes with every backend and every `kv_bits`.
    pub chaos: Option<ChaosCfg>,
    /// Prompt-prefix KV sharing (`--prefix-cache on`): admission consults
    /// a radix index over prior prompts and aliases the matched prefix's
    /// KV blocks (refcounted, copy-on-write) so only the uncached tail is
    /// prefilled. Requires a backend implementing
    /// [`DecodeBackend::prefill_paged`]; silently disabled (with a logged
    /// warning) otherwise. Composes with every `--kv-bits`: shared blocks
    /// keep their stored payloads, so a hit never dequantizes or re-rounds.
    pub prefix_cache: bool,
    /// Speculative decoding window (`--spec-k N`, `--backend native-spec`
    /// only): up to `N` draft tokens are proposed per decode round and
    /// verified in one stacked target pass. Ignored by the other backends.
    pub spec_k: usize,
    /// Draft-model weight width in bits (`--draft-wbits {2,3,4}`,
    /// `--backend native-spec` only): the draft is the SAME manifest
    /// re-quantized at this width through the unified packed stream —
    /// 2-bit streams four reduction rows per LUT byte, halving draft
    /// weight traffic vs 4-bit. Ignored by the other backends.
    pub draft_wbits: u32,
    /// Weight bit-width for the native backends (`--wbits {2,3,4,auto}`):
    /// `Uniform(b)` quantizes every linear at `b` bits; `Auto { budget }`
    /// runs the calibration-driven per-layer planner against an
    /// average-bits budget (`--wbits-budget`). The served plan is
    /// reported in [`EngineStats::wbits_plan`]. Ignored by PJRT.
    pub wbits: WbitsSpec,
    /// Per-group weight-scale group size in reduction rows
    /// (`--wbits-group`, FineQuant-style; must be a multiple of 4, `0` =
    /// one scale per column). Ignored by PJRT.
    pub w_group: usize,
    /// Scheduler shape (`--sched {burst,chunked}`): `Burst` keeps the
    /// phased admit-all → prefill-whole → decode loop; `Chunked` runs
    /// iteration-level scheduling with budgeted prefill chunks mixed
    /// into every decode step. See [`SchedPolicy`].
    pub sched: SchedPolicy,
    /// Prefill rows per chunked step (`--prefill-chunk N`, chunked
    /// scheduler only). `0` (default) auto-budgets from the measured
    /// datapath: the chunk is sized so its prefill time ≈ one decode
    /// step (EWMA of `StepCost::shard_crit_s`, falling back to
    /// `host_waq_s` for unsharded backends).
    pub prefill_chunk: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AdmitPolicy::OnePerStep,
            seed: 0xE116,
            mode: OasisMode::a4(),
            backend: BackendSpec::default(),
            kv_bits: KvBits::Fp32,
            shards: 2,
            queue_cap: 0,
            default_deadline_ms: 0,
            chaos: None,
            prefix_cache: false,
            spec_k: 4,
            draft_wbits: 2,
            wbits: WbitsSpec::Uniform(4),
            w_group: 128,
            sched: SchedPolicy::Burst,
            prefill_chunk: 0,
        }
    }
}

struct ActiveReq {
    req: Request,
    generated: Vec<i32>,
    /// this request's private sampling stream, seeded from the engine
    /// seed and the request id when its first token is sampled: every
    /// later draw consumes only this stream, so sampled token sequences
    /// never depend on which other requests share the batch
    rng: Rng,
    /// when admission sampled the prefill's token — a request is only
    /// active after its first token exists, so this is never "pending"
    first_token_at: Instant,
    /// when this request's latest token was sampled — the anchor for the
    /// decode inter-token latency histogram (`EngineStats::decode_lat`).
    /// Initialized alongside `first_token_at` (token #1's latency is
    /// TTFT, recorded separately), advanced on every decode emission.
    last_token_at: Instant,
    /// arrival → admission wall-clock (time spent queued), frozen at
    /// admission so the response reports it regardless of outcome
    queue_wait_s: f64,
    /// the backend consumed fewer prompt tokens than submitted
    truncated_prompt: bool,
    /// sim-clock marks at admission, so responses report per-request
    /// deltas (not the engine's running totals)
    modeled_start_s: f64,
    modeled_start_j: f64,
}

/// One request whose prompt is prefilling chunk-by-chunk across engine
/// iterations (`--sched chunked`). Its KV slot is claimed (Active at
/// `done` tokens) for the whole span — index-aliased prefix blocks stay
/// pinned, COW fires normally if a shared block is appended into — and
/// `done` is the resume cursor the next chunk starts from. No first
/// token exists yet: a deadline expiring here answers the request with
/// `DeadlineExpired` before any token and releases the partial slot.
struct PendingPrefill {
    req: Request,
    slot: usize,
    /// prompt tokens already resident in the cache (index-served prefix
    /// at claim + every chunk completed since)
    done: usize,
    /// prompt tokens the prefill will consume in total (clamped to
    /// `seq_len - 1`, exactly as burst admission clamps)
    plen: usize,
    /// arrival → slot-claim wall-clock, frozen at claim (the chunked
    /// analogue of burst admission's queue wait)
    queue_wait_s: f64,
    /// sim-clock marks at claim, so the response's modeled delta spans
    /// every chunk of its own prefill
    modeled_start_s: f64,
    modeled_start_j: f64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SimTotals {
    pub seconds: f64,
    pub energy_j: f64,
}

pub struct Engine {
    backend: Box<dyn DecodeBackend>,
    kv: KvManager,
    batcher: Batcher,
    active: Vec<Option<ActiveReq>>,
    pub stats: EngineStats,
    pub sim: SimTotals,
    /// base sampling seed; per-request streams derive from it (see
    /// [`Engine::request_rng`])
    seed: u64,
    /// deadline applied at submit to requests without one (None = none)
    default_deadline: Option<Duration>,
    /// effective prefix-cache switch: `cfg.prefix_cache` AND the backend
    /// implements paged prefill (the KvManager's radix index is enabled,
    /// and intra-burst duplicates dedup by aliasing, only when true)
    prefix_cache: bool,
    /// admission routes through `prefill_paged` (vs the legacy dense
    /// `prefill_batch` path): the prefix cache is on, OR the backend
    /// demands paged admission regardless (the speculative backend's
    /// verification appends into the paged cache, so its slots must be
    /// paged-admitted even with the index off)
    paged_admission: bool,
    /// EWMA of natural completions' wall-clock service time (queue wait +
    /// compute), feeding the `retry_after_ms` backpressure hint. 0.0
    /// until the first natural completion.
    recent_service_s: f64,
    /// modeled cost clock for this backend's work — the cold-start
    /// fallback for `retry_after_ms` before any completion has primed
    /// the service-time EWMA
    cost_model: CostModel,
    /// effective scheduler: `cfg.sched` downgraded to `Burst` (with a
    /// logged warning) when the backend has no paged prefill — chunk
    /// resume needs the paged cache's append/cursor machinery
    sched: SchedPolicy,
    /// pinned chunk size (`--prefill-chunk`); 0 = auto-budget from the
    /// measured-datapath EWMAs below
    prefill_chunk: usize,
    /// requests mid-prefill under the chunked scheduler, FIFO by claim
    /// order (head-of-line receives chunk budget first)
    prefilling: Vec<PendingPrefill>,
    /// EWMA of measured datapath seconds per prefill row (shard critical
    /// path when reported, host WAQ seconds otherwise); 0.0 until primed
    prefill_row_ewma: f64,
    /// EWMA of measured datapath seconds per decode step; 0.0 until primed
    decode_step_ewma: f64,
}

impl Engine {
    /// Build an engine over an already-constructed backend. (`cfg.backend`
    /// describes how a `Coordinator` constructs one; here the caller has.)
    pub fn new(backend: Box<dyn DecodeBackend>, cfg: &EngineConfig) -> Engine {
        let m = backend.model();
        let precision = match cfg.kv_bits {
            KvBits::Fp32 => KvPrecision::Fp32,
            quantized => KvPrecision::Quant(backend.kv_quantizer(quantized.bits())),
        };
        let prefix_cache = cfg.prefix_cache && backend.supports_paged_prefill();
        if cfg.prefix_cache && !prefix_cache {
            eprintln!(
                "engine: --prefix-cache on requested but backend {} has no paged \
                 prefill; running without prefix sharing",
                backend.spec().name()
            );
        }
        let mut sched = cfg.sched;
        if sched == SchedPolicy::Chunked && !backend.supports_paged_prefill() {
            eprintln!(
                "engine: --sched chunked requested but backend {} has no paged \
                 prefill; falling back to burst scheduling",
                backend.spec().name()
            );
            sched = SchedPolicy::Burst;
        }
        let paged_admission = backend.supports_paged_prefill()
            && (prefix_cache
                || backend.requires_paged_admission()
                || sched == SchedPolicy::Chunked);
        let kv = KvManager::with_precision_opts(m, precision, prefix_cache);
        let stats = EngineStats {
            waq_backend: backend.spec().name(),
            kv_bits: cfg.kv_bits.bits(),
            kv_bytes_per_token: kv.bytes_per_token(),
            wbits_plan: backend.wbits_plan().unwrap_or_default(),
            ..Default::default()
        };
        Engine {
            kv,
            batcher: Batcher::with_cap(cfg.policy, cfg.queue_cap),
            active: (0..m.decode_batch).map(|_| None).collect(),
            stats,
            sim: SimTotals::default(),
            seed: cfg.seed,
            default_deadline: (cfg.default_deadline_ms > 0)
                .then(|| Duration::from_millis(cfg.default_deadline_ms)),
            prefix_cache,
            paged_admission,
            recent_service_s: 0.0,
            cost_model: CostModel::new(m, cfg.mode, backend.spec().waq()),
            sched,
            prefill_chunk: cfg.prefill_chunk,
            prefilling: Vec::new(),
            prefill_row_ewma: 0.0,
            decode_step_ewma: 0.0,
            backend,
        }
    }

    /// Whether admission runs through the prefix-sharing paged path
    /// (requested AND supported by the backend).
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_cache
    }

    /// Which execution engine + WAQ kernel this engine decodes with.
    pub fn backend_spec(&self) -> BackendSpec {
        self.backend.spec()
    }

    /// The software WAQ GEMM kernel the backend runs or models.
    pub fn waq_backend(&self) -> WaqBackend {
        self.backend.spec().waq()
    }

    pub fn model(&self) -> crate::runtime::artifacts::ModelCfg {
        self.backend.model()
    }

    /// The KV slot manager (paged-cache introspection for invariant
    /// checks and benches; the engine retains ownership).
    pub fn kv(&self) -> &KvManager {
        &self.kv
    }

    /// Unconditional submit (tests/benches): applies the default deadline
    /// but bypasses the queue cap — the request always enqueues. The
    /// production path (the coordinator's `Cmd::Submit`) goes through
    /// [`Engine::try_submit`] so overload produces backpressure.
    pub fn submit(&mut self, r: Request) {
        self.batcher.enqueue(self.with_default_deadline(r));
    }

    /// Bounded submit: enqueues (returning `None`) unless the queue is at
    /// `EngineConfig::queue_cap`, in which case the request is answered
    /// *immediately* with the returned [`FinishReason::Rejected`] response
    /// (counted in `EngineStats::rejected`). Rejected requests never touch
    /// queue or KV capacity and are never silently dropped.
    pub fn try_submit(&mut self, r: Request) -> Option<Response> {
        let r = self.with_default_deadline(r);
        match self.batcher.try_enqueue(r) {
            Ok(()) => None,
            Err(req) => {
                self.stats.rejected += 1;
                let mut resp = queued_response(&req, FinishReason::Rejected);
                resp.retry_after_ms = self.retry_after_ms(&req);
                Some(resp)
            }
        }
    }

    /// Refuse a request outright (admission closed — e.g. the engine is
    /// draining): counted in `stats.rejected`, answered immediately with
    /// a [`FinishReason::Rejected`] response. Unlike [`Engine::try_submit`]
    /// this never enqueues.
    pub fn reject(&mut self, req: Request) -> Response {
        self.stats.rejected += 1;
        let mut resp = queued_response(&req, FinishReason::Rejected);
        resp.retry_after_ms = self.retry_after_ms(&req);
        resp
    }

    /// Backpressure hint for rejected submits: estimated milliseconds
    /// until the queue has drained enough to accept a resubmit — queue
    /// depth x per-request service time, divided by the decode batch
    /// width (requests drain `decode_batch` at a time once admitted).
    /// Service time is the EWMA of recent natural completions once any
    /// exist; before the first completion it falls back to the modeled
    /// cost of serving `req` itself (prefill + `max_new_tokens` decode
    /// steps at full batch), so a cold engine's rejections still carry a
    /// usable hint instead of `0`.
    pub fn retry_after_ms(&self, req: &Request) -> u64 {
        let service_s = if self.recent_service_s > 0.0 {
            self.recent_service_s
        } else {
            let plen = req.prompt.len().clamp(1, self.kv.cfg.seq_len - 1);
            let pre = self.cost_model.prefill(plen);
            let dec = self.cost_model.decode(self.kv.cfg.decode_batch, plen);
            pre.accel_s + req.max_new_tokens as f64 * (dec.accel_s + dec.host_waq_s)
        };
        if service_s <= 0.0 {
            return 0;
        }
        let depth = self.batcher.pending().max(1) as f64;
        let batch = self.kv.cfg.decode_batch.max(1) as f64;
        (1000.0 * depth * service_s / batch).ceil() as u64
    }

    fn with_default_deadline(&self, mut r: Request) -> Request {
        if r.deadline.is_none() {
            if let Some(d) = self.default_deadline {
                r.deadline = Some(r.arrived + d);
            }
        }
        r
    }

    pub fn has_work(&self) -> bool {
        // mid-prefill slots are Active in the KV manager, so the second
        // clause already covers `prefilling`; the third keeps drain
        // correct even if slot accounting ever diverges
        self.batcher.pending() > 0 || self.kv.active_count() > 0 || !self.prefilling.is_empty()
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    pub fn active_count(&self) -> usize {
        self.kv.active_count()
    }

    /// The effective scheduler (after any unsupported-backend fallback).
    pub fn sched(&self) -> SchedPolicy {
        self.sched
    }

    /// Requests currently mid-prefill under the chunked scheduler
    /// (claimed slot, incomplete cursor). Always 0 under `Burst`.
    pub fn prefilling_count(&self) -> usize {
        self.prefilling.len()
    }

    /// One engine iteration; returns completed responses.
    ///
    /// Fault containment (both schedulers): a failed prefill (burst or
    /// chunk), per-request install, or decode step answers the affected
    /// requests with `Aborted` (counted in `prefill_failures` /
    /// `step_failures`) and returns `Ok` — the engine keeps serving.
    /// `step()` only returns `Err` for engine-state corruption no
    /// response can paper over.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        match self.sched {
            SchedPolicy::Burst => self.step_burst(),
            SchedPolicy::Chunked => self.step_chunked(),
        }
    }

    /// The phased scheduler (`--sched burst`): admit a burst, prefill
    /// every admitted prompt whole, then run one decode step.
    fn step_burst(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- deadline sweep (in-queue expiry) --------------------------
        // Queued requests past deadline are answered now, before they
        // can consume a prefill nobody is waiting for. Mid-decode expiry
        // is handled in `maybe_finish` (partial tokens returned there).
        let now = Instant::now();
        for req in self.batcher.take_expired(now) {
            self.stats.expired += 1;
            done.push(queued_response(&req, FinishReason::DeadlineExpired));
        }

        // ---- admission (batched prefill) -------------------------------
        // The whole admit burst goes through ONE backend call: the native
        // backends stack every prompt's rows and run each WAQ LUT-GEMM
        // linear once per layer for the burst (bit-exact per request with
        // the sequential path); the PJRT default loops internally.
        let free = self.kv.decode_batch_free();
        let admitted = self.batcher.admit(free);
        if !admitted.is_empty() && self.paged_admission {
            self.admit_paged(admitted, &mut done);
        } else if !admitted.is_empty() {
            // intra-burst duplicate collapse: identical prompts in one
            // admission burst prefill ONCE — every clone reuses the
            // computed K/V tensors and last-position logits (bit-exact:
            // prefill is deterministic in the prompt). The first
            // occurrence is always the unique, so it pays the modeled
            // cost before any of its clones take their sim-clock marks.
            let mut unique_of: Vec<usize> = Vec::with_capacity(admitted.len());
            let mut uniques: Vec<usize> = Vec::new();
            for (i, r) in admitted.iter().enumerate() {
                match uniques.iter().position(|&u| admitted[u].prompt == r.prompt) {
                    Some(j) => {
                        unique_of.push(j);
                        self.stats.burst_dedup_hits += 1;
                    }
                    None => {
                        unique_of.push(uniques.len());
                        uniques.push(i);
                    }
                }
            }
            let prompts: Vec<&[i32]> =
                uniques.iter().map(|&u| admitted[u].prompt.as_slice()).collect();
            let n_unique = uniques.len();
            match self.backend.prefill_batch(&prompts) {
                Ok(pres) if pres.len() == n_unique => {
                    let admitted_at = Instant::now();
                    let mut charged = vec![false; n_unique];
                    for (i, req) in admitted.into_iter().enumerate() {
                        let pre = &pres[unique_of[i]];
                        let queue_wait_s = (admitted_at - req.arrived).as_secs_f64();
                        let Some(slot) = self.kv.free_slot() else {
                            // unreachable (admit is bounded by free slots)
                            // — but an accounting bug must still answer
                            // the request, not drop it
                            self.stats.step_failures += 1;
                            done.push(queued_response(&req, FinishReason::Aborted));
                            continue;
                        };
                        // the sim-clock marks are taken before the prefill
                        // cost lands, so each response's modeled delta
                        // includes its own prefill (per-request costs come
                        // from the backend even for a batched burst)
                        let (start_s, start_j) = (self.sim.seconds, self.sim.energy_j);
                        let truncated = pre.plen < req.prompt.len();
                        if let Err(e) = self
                            .kv
                            .install_prefill(slot, req.id, pre.plen, &pre.k_cache, &pre.v_cache)
                        {
                            // contained: reclaim any partially-appended
                            // blocks, answer this request, keep the burst
                            eprintln!(
                                "engine: prefill install failed for request {} ({e}); aborting it",
                                req.id
                            );
                            self.stats.step_failures += 1;
                            self.kv.release(slot);
                            done.push(queued_response(&req, FinishReason::Aborted));
                            continue;
                        }
                        self.stats.prefills += 1;
                        if truncated {
                            self.stats.truncated_prompts += 1;
                        }
                        // a duplicate charges nothing: its unique (always
                        // processed first) already paid the burst row
                        if !charged[unique_of[i]] {
                            charged[unique_of[i]] = true;
                            self.sim.seconds += pre.cost.accel_s;
                            self.sim.energy_j += pre.cost.accel_j;
                            self.stats.host_waq_s += pre.cost.host_waq_s;
                            self.stats.host_shard_crit_s += pre.cost.shard_crit_s;
                        }
                        // the prefill's last-position logits give token #1
                        let mut rng = self.request_rng(req.id);
                        let tok = Self::sample(&mut rng, &pre.logits, req.temperature);
                        let first_at = Instant::now();
                        let mut ar = ActiveReq {
                            req,
                            generated: vec![tok],
                            rng,
                            first_token_at: first_at,
                            last_token_at: first_at,
                            queue_wait_s,
                            truncated_prompt: truncated,
                            modeled_start_s: start_s,
                            modeled_start_j: start_j,
                        };
                        self.stats.generated_tokens += 1;
                        // completion checks on the very first token
                        if let Some(resp) = self.maybe_finish(slot, &mut ar, admitted_at) {
                            self.kv.release(slot);
                            done.push(resp);
                        } else {
                            self.active[slot] = Some(ar);
                        }
                    }
                }
                // a failed (or arity-broken) burst prefill must not drop
                // admitted requests on the floor: nothing was installed,
                // so every request gets an Aborted response and the
                // engine keeps serving
                fail => {
                    let err = match fail {
                        Err(e) => e.to_string(),
                        Ok(p) => format!(
                            "backend returned {} prefill results for {} prompts",
                            p.len(),
                            n_unique
                        ),
                    };
                    eprintln!(
                        "engine: burst prefill failed ({err}); aborting {} admitted request(s)",
                        admitted.len()
                    );
                    self.stats.prefill_failures += 1;
                    done.extend(
                        admitted
                            .iter()
                            .map(|r| queued_response(r, FinishReason::Aborted)),
                    );
                }
            }
        }

        // ---- decode ------------------------------------------------------
        // Contained: a failed decode step aborts the in-flight requests
        // (every waiter still gets a response, every KV slot is released)
        // but does NOT propagate — the engine thread survives and keeps
        // admitting. Counted in `EngineStats::step_failures`.
        if self.kv.active_count() > 0 {
            match self.decode_step() {
                Ok(responses) => done.extend(responses),
                Err(e) => {
                    eprintln!(
                        "engine: decode step failed ({e}); aborting {} in-flight request(s)",
                        self.kv.active_count()
                    );
                    self.stats.step_failures += 1;
                    done.extend(self.abort_inflight());
                }
            }
        }
        // peak_cache_bytes is monotone; the running max just makes the
        // stat robust to any future non-monotone accounting
        self.stats.peak_kv_bytes =
            self.stats.peak_kv_bytes.max(self.kv.peak_cache_bytes() as u64);
        // eviction count lives on the cache (allocation-pressure and chaos
        // evictions both land there); mirror it into the stats snapshot
        self.stats.evictions = self.kv.cache().evictions();
        Ok(done)
    }

    /// The iteration-level scheduler (`--sched chunked`): ONE mixed
    /// backend pass per step — active decode slots plus a budgeted chunk
    /// of pending prefill rows ([`DecodeBackend::schedule`]). Admission
    /// claims a slot (aliasing any index-served prefix) and parks the
    /// request in `prefilling`; chunks advance its cursor across steps;
    /// the final chunk samples token #1 and promotes it to a decode slot.
    /// Greedy streams are bit-exact with burst: paged prefill attention
    /// is row-independent, so a prompt split across chunks replays the
    /// identical float sequence, and decode logits depend only on the
    /// slot's own cache contents — never on which step computed them.
    fn step_chunked(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- deadline sweeps -------------------------------------------
        // In-queue expiry first (identical to burst), then mid-prefill
        // expiry: a deadline passing between chunks answers the request
        // BEFORE its first token — no partial tokens exist — and releases
        // the partially filled slot (aliased/COW blocks return to the
        // index or pool).
        let now = Instant::now();
        for req in self.batcher.take_expired(now) {
            self.stats.expired += 1;
            done.push(queued_response(&req, FinishReason::DeadlineExpired));
        }
        let mut i = 0;
        while i < self.prefilling.len() {
            if self.prefilling[i].req.expired(now) {
                let p = self.prefilling.remove(i);
                self.kv.release(p.slot);
                self.stats.expired += 1;
                done.push(queued_response(&p.req, FinishReason::DeadlineExpired));
            } else {
                i += 1;
            }
        }

        // ---- intake (claim slots, no compute yet) ----------------------
        // Intake is additionally capped at the step's chunk budget: a
        // request beyond it couldn't receive a single row this step, and
        // leaving it queued keeps it visible to the cheaper in-queue
        // deadline sweep instead of parking it in a slot.
        let budget = self.chunk_budget();
        let free = self.kv.decode_batch_free();
        let admitted = self.batcher.admit_capped(free, budget.max(1));
        let claimed_at = Instant::now();
        let seq_len = self.kv.cfg.seq_len;
        for req in admitted {
            let Some(slot) = self.kv.free_slot() else {
                // unreachable (admit is bounded by free slots) — but an
                // accounting bug must still answer the request, not drop it
                self.stats.step_failures += 1;
                done.push(queued_response(&req, FinishReason::Aborted));
                continue;
            };
            let plen = req.prompt.len().clamp(1, seq_len - 1);
            match self.kv.admit_prefix(slot, req.id, &req.prompt, plen) {
                Ok(m) => {
                    if m.tokens > 0 {
                        self.stats.prefix_hits += 1;
                    }
                    self.stats.prefix_blocks_reused += m.blocks as u64;
                    self.prefilling.push(PendingPrefill {
                        slot,
                        done: m.tokens,
                        plen,
                        queue_wait_s: (claimed_at - req.arrived).as_secs_f64(),
                        modeled_start_s: self.sim.seconds,
                        modeled_start_j: self.sim.energy_j,
                        req,
                    });
                }
                Err(e) => {
                    eprintln!(
                        "engine: prefix admission failed for request {} ({e}); aborting it",
                        req.id
                    );
                    self.stats.step_failures += 1;
                    done.push(queued_response(&req, FinishReason::Aborted));
                }
            }
        }

        // ---- chunk plan (budget spent FIFO, head-of-line first) --------
        // (pending index, chunk end cursor); `cached` in the plan is the
        // resume cursor, so the backend computes only rows done..end.
        let mut plans: Vec<(usize, usize)> = Vec::new();
        let mut rows = 0usize;
        for (idx, p) in self.prefilling.iter().enumerate() {
            if rows >= budget {
                break;
            }
            let take = (budget - rows).min(p.plen - p.done);
            plans.push((idx, p.done + take));
            rows += take;
        }
        let chunks: Vec<PagedPrefill<'_>> = plans
            .iter()
            .map(|&(idx, end)| {
                let p = &self.prefilling[idx];
                PagedPrefill {
                    // the slice end never exceeds the real prompt (plen
                    // is clamped to seq_len-1 but also to the backend's
                    // own clamp of the full prompt)
                    prompt: &p.req.prompt[..end.min(p.req.prompt.len())],
                    slot: p.slot,
                    cached: p.done,
                }
            })
            .collect();

        // ---- decode inputs (pre-chunk actives) -------------------------
        // Built BEFORE the backend pass: a request finishing its prefill
        // this step starts decoding next step. Token values are
        // unaffected (decode logits depend only on the slot's cache, not
        // on which step runs it); mid-prefill slots are Active in the KV
        // manager but not in `self.active`, so they pad as inactive.
        let (toks, pos, active, occupancy) = self.decode_inputs();

        // ---- ONE mixed backend pass ------------------------------------
        let work = ScheduleWork { chunks, toks: &toks, pos: &pos, active: &active };
        let out = self.backend.schedule(&work, &mut self.kv);
        drop(work);

        // ---- chunk results ---------------------------------------------
        match out.chunks {
            Ok(outs) if outs.len() == plans.len() => {
                // pass 1: charge costs, advance cursors, classify each
                // planned request (None = bookkeeping failure, Some(out)
                // = final chunk) — removals deferred so indices stay valid
                let mut meas = 0.0f64;
                let mut leaving: Vec<(usize, Option<PagedPrefillOut>)> = Vec::new();
                for (&(idx, _), out) in plans.iter().zip(outs.into_iter()) {
                    self.sim.seconds += out.cost.accel_s;
                    self.sim.energy_j += out.cost.accel_j;
                    self.stats.host_waq_s += out.cost.host_waq_s;
                    self.stats.host_shard_crit_s += out.cost.shard_crit_s;
                    meas += if out.cost.shard_crit_s > 0.0 {
                        out.cost.shard_crit_s
                    } else {
                        out.cost.host_waq_s
                    };
                    if let Err(e) = self.kv.set_position(self.prefilling[idx].slot, out.plen) {
                        eprintln!(
                            "engine: chunk bookkeeping failed for request {} ({e}); aborting it",
                            self.prefilling[idx].req.id
                        );
                        self.stats.step_failures += 1;
                        leaving.push((idx, None));
                        continue;
                    }
                    self.prefilling[idx].done = out.plen;
                    if out.plen >= self.prefilling[idx].plen {
                        leaving.push((idx, Some(out)));
                    }
                }
                if rows > 0 && meas > 0.0 {
                    let per_row = meas / rows as f64;
                    self.prefill_row_ewma = if self.prefill_row_ewma == 0.0 {
                        per_row
                    } else {
                        0.8 * self.prefill_row_ewma + 0.2 * per_row
                    };
                }
                // pass 2: detach leavers in FIFO order (ascending indices;
                // each removal shifts the rest down by one) so first-token
                // sampling order matches burst admission order
                let mut removed = 0usize;
                for (idx, outcome) in leaving {
                    let p = self.prefilling.remove(idx - removed);
                    removed += 1;
                    let Some(out) = outcome else {
                        self.kv.release(p.slot);
                        done.push(queued_response(&p.req, FinishReason::Aborted));
                        continue;
                    };
                    // final chunk: the tail's last-position logits give
                    // token #1 — from here on the request is an ordinary
                    // decode-slot resident, exactly as if burst-admitted
                    let truncated = p.plen < p.req.prompt.len();
                    self.stats.prefills += 1;
                    if truncated {
                        self.stats.truncated_prompts += 1;
                    }
                    let indexed = p.plen.min(p.req.prompt.len());
                    self.kv.register_prefix(p.slot, &p.req.prompt[..indexed]);
                    let mut rng = self.request_rng(p.req.id);
                    let tok = Self::sample(&mut rng, &out.logits, p.req.temperature);
                    let first_at = Instant::now();
                    let mut ar = ActiveReq {
                        req: p.req,
                        generated: vec![tok],
                        rng,
                        first_token_at: first_at,
                        last_token_at: first_at,
                        queue_wait_s: p.queue_wait_s,
                        truncated_prompt: truncated,
                        modeled_start_s: p.modeled_start_s,
                        modeled_start_j: p.modeled_start_j,
                    };
                    self.stats.generated_tokens += 1;
                    if let Some(resp) = self.maybe_finish(p.slot, &mut ar, first_at) {
                        self.kv.release(p.slot);
                        done.push(resp);
                    } else {
                        self.active[p.slot] = Some(ar);
                    }
                }
            }
            // a failed (or arity-broken) chunk batch aborts exactly the
            // requests that had a chunk in it — mid-prefill requests NOT
            // planned this step keep their cursors and survive, as do all
            // in-flight decodes (their result is handled independently
            // below)
            fail => {
                let err = match fail {
                    Err(e) => e.to_string(),
                    Ok(p) => format!(
                        "backend returned {} chunk results for {} planned chunks",
                        p.len(),
                        plans.len()
                    ),
                };
                eprintln!(
                    "engine: prefill chunk failed ({err}); aborting {} mid-prefill request(s)",
                    plans.len()
                );
                self.stats.prefill_failures += 1;
                let mut removed = 0usize;
                for &(idx, _) in &plans {
                    let p = self.prefilling.remove(idx - removed);
                    removed += 1;
                    self.kv.release(p.slot);
                    done.push(queued_response(&p.req, FinishReason::Aborted));
                }
            }
        }

        // ---- decode result ---------------------------------------------
        // Same containment as burst: a failed decode aborts the batch
        // that was in flight but never the mid-prefill requests (their
        // slots are not in `self.active`, so `abort_inflight` skips them).
        if let Some(dres) = out.decode {
            match dres {
                Ok((logits, cost)) => done.extend(self.apply_decode(logits, cost, &pos, occupancy)),
                Err(e) => {
                    eprintln!(
                        "engine: decode step failed ({e}); aborting {} in-flight request(s)",
                        occupancy
                    );
                    self.stats.step_failures += 1;
                    done.extend(self.abort_inflight());
                }
            }
        }

        self.stats.peak_kv_bytes =
            self.stats.peak_kv_bytes.max(self.kv.peak_cache_bytes() as u64);
        self.stats.evictions = self.kv.cache().evictions();
        Ok(done)
    }

    /// Prefill rows the chunked scheduler may run this step. An explicit
    /// `--prefill-chunk N` pins it; `0` sizes the chunk so its measured
    /// datapath time ≈ one decode step (ratio of the two EWMAs — shard
    /// critical path when the backend reports one, host WAQ seconds
    /// otherwise), which keeps mixed steps roughly as long as pure decode
    /// steps. Cold default before both EWMAs are primed: 16 rows (one KV
    /// block).
    fn chunk_budget(&self) -> usize {
        if self.prefill_chunk > 0 {
            return self.prefill_chunk;
        }
        if self.prefill_row_ewma > 0.0 && self.decode_step_ewma > 0.0 {
            return ((self.decode_step_ewma / self.prefill_row_ewma).round() as usize).max(1);
        }
        16
    }

    /// Paged admission (`--prefix-cache on`, or a backend that requires
    /// paged slots): split the burst into unique prompts and intra-burst
    /// duplicates, run the uniques through ONE paged-prefill burst, then
    /// admit each duplicate by aliasing its (now registered) twin — zero
    /// prefill compute for clones. Dedup needs the radix index, so with
    /// the index off (paged admission forced by the backend alone) every
    /// request takes the cold path.
    fn admit_paged(&mut self, admitted: Vec<Request>, done: &mut Vec<Response>) {
        let mut work = admitted;
        let mut dups: Vec<Request> = Vec::new();
        if self.prefix_cache {
            let mut uniques: Vec<Request> = Vec::with_capacity(work.len());
            for req in work {
                if !req.prompt.is_empty() && uniques.iter().any(|u| u.prompt == req.prompt) {
                    dups.push(req);
                } else {
                    uniques.push(req);
                }
            }
            work = uniques;
        }
        // (prompt, registered length, last-position logits) of burst
        // prompts that have clones waiting — the clone samples its first
        // token from its twin's row
        let mut twins: Vec<(Vec<i32>, usize, Vec<f32>)> = Vec::new();
        self.admit_paged_burst(work, &dups, &mut twins, done);
        for req in dups {
            self.admit_paged_duplicate(req, &twins, done);
        }
    }

    /// Prefix-sharing burst admission: claim a slot per request, alias
    /// whatever prefix the radix index already holds, then run ONE
    /// paged-prefill burst computing only the uncached tails — K/V rows
    /// append straight into the paged cache and attention reads back
    /// through it, so hit and cold paths consume bit-identical stored
    /// payloads at every `--kv-bits`. Prefilled prompts register in the
    /// index afterwards; prompts listed in `dups` additionally record a
    /// `twins` entry for the duplicate pass.
    fn admit_paged_burst(
        &mut self,
        work: Vec<Request>,
        dups: &[Request],
        twins: &mut Vec<(Vec<i32>, usize, Vec<f32>)>,
        done: &mut Vec<Response>,
    ) {
        let seq_len = self.kv.cfg.seq_len;
        // (request, claimed slot, index-served token count)
        let mut planned: Vec<(Request, usize, usize)> = Vec::with_capacity(work.len());
        for req in work {
            let Some(slot) = self.kv.free_slot() else {
                // unreachable (admit is bounded by free slots) — but an
                // accounting bug must still answer the request, not drop it
                self.stats.step_failures += 1;
                done.push(queued_response(&req, FinishReason::Aborted));
                continue;
            };
            let plen = req.prompt.len().clamp(1, seq_len - 1);
            match self.kv.admit_prefix(slot, req.id, &req.prompt, plen) {
                Ok(m) => {
                    if m.tokens > 0 {
                        self.stats.prefix_hits += 1;
                    }
                    self.stats.prefix_blocks_reused += m.blocks as u64;
                    planned.push((req, slot, m.tokens));
                }
                Err(e) => {
                    eprintln!(
                        "engine: prefix admission failed for request {} ({e}); aborting it",
                        req.id
                    );
                    self.stats.step_failures += 1;
                    done.push(queued_response(&req, FinishReason::Aborted));
                }
            }
        }
        if planned.is_empty() {
            return;
        }
        let plans: Vec<PagedPrefill<'_>> = planned
            .iter()
            .map(|(req, slot, cached)| PagedPrefill {
                prompt: &req.prompt,
                slot: *slot,
                cached: *cached,
            })
            .collect();
        match self.backend.prefill_paged(&plans, &mut self.kv) {
            Ok(outs) if outs.len() == planned.len() => {
                drop(plans);
                let admitted_at = Instant::now();
                for ((req, slot, _), out) in planned.into_iter().zip(outs) {
                    let queue_wait_s = (admitted_at - req.arrived).as_secs_f64();
                    let (start_s, start_j) = (self.sim.seconds, self.sim.energy_j);
                    let truncated = out.plen < req.prompt.len();
                    if let Err(e) = self.kv.set_position(slot, out.plen) {
                        eprintln!(
                            "engine: paged prefill bookkeeping failed for request {} ({e}); \
                             aborting it",
                            req.id
                        );
                        self.stats.step_failures += 1;
                        self.kv.release(slot);
                        done.push(queued_response(&req, FinishReason::Aborted));
                        continue;
                    }
                    // index the freshly prefilled prompt so later arrivals
                    // (including this burst's duplicates) hit
                    let indexed = out.plen.min(req.prompt.len());
                    self.kv.register_prefix(slot, &req.prompt[..indexed]);
                    if dups.iter().any(|d| d.prompt == req.prompt) {
                        twins.push((req.prompt.clone(), indexed, out.logits.clone()));
                    }
                    self.stats.prefills += 1;
                    if truncated {
                        self.stats.truncated_prompts += 1;
                    }
                    self.sim.seconds += out.cost.accel_s;
                    self.sim.energy_j += out.cost.accel_j;
                    self.stats.host_waq_s += out.cost.host_waq_s;
                    self.stats.host_shard_crit_s += out.cost.shard_crit_s;
                    // the tail's last-position logits give token #1
                    let mut rng = self.request_rng(req.id);
                    let tok = Self::sample(&mut rng, &out.logits, req.temperature);
                    let first_at = Instant::now();
                    let mut ar = ActiveReq {
                        req,
                        generated: vec![tok],
                        rng,
                        first_token_at: first_at,
                        last_token_at: first_at,
                        queue_wait_s,
                        truncated_prompt: truncated,
                        modeled_start_s: start_s,
                        modeled_start_j: start_j,
                    };
                    self.stats.generated_tokens += 1;
                    if let Some(resp) = self.maybe_finish(slot, &mut ar, admitted_at) {
                        self.kv.release(slot);
                        done.push(resp);
                    } else {
                        self.active[slot] = Some(ar);
                    }
                }
            }
            // all-or-nothing burst contract: nothing was sampled, so
            // release every claimed slot (returning aliased blocks to the
            // index/pool) and answer each request with Aborted
            fail => {
                drop(plans);
                let err = match fail {
                    Err(e) => e.to_string(),
                    Ok(p) => format!(
                        "backend returned {} paged-prefill results for {} requests",
                        p.len(),
                        planned.len()
                    ),
                };
                eprintln!(
                    "engine: paged burst prefill failed ({err}); aborting {} admitted request(s)",
                    planned.len()
                );
                self.stats.prefill_failures += 1;
                for (req, slot, _) in planned {
                    self.kv.release(slot);
                    done.push(queued_response(&req, FinishReason::Aborted));
                }
            }
        }
    }

    /// Admit one intra-burst duplicate by aliasing its twin's freshly
    /// registered prompt: the whole prompt must match the index (a
    /// full-length alias — the clone reuses the twin's last-position
    /// logits, so no uncovered tail is needed) and no prefill compute or
    /// modeled cost is charged. When the twin never registered (it was
    /// aborted, or its blocks were evicted already) the duplicate falls
    /// back to a real singleton paged prefill — correctness never
    /// depends on the dedup hitting.
    fn admit_paged_duplicate(
        &mut self,
        req: Request,
        twins: &[(Vec<i32>, usize, Vec<f32>)],
        done: &mut Vec<Response>,
    ) {
        let Some((_, plen, logits)) = twins.iter().find(|(p, _, _)| *p == req.prompt) else {
            return self.admit_paged_burst(vec![req], &[], &mut Vec::new(), done);
        };
        let Some(slot) = self.kv.free_slot() else {
            // unreachable (admit is bounded by free slots) — but an
            // accounting bug must still answer the request, not drop it
            self.stats.step_failures += 1;
            done.push(queued_response(&req, FinishReason::Aborted));
            return;
        };
        match self.kv.admit_duplicate(slot, req.id, &req.prompt, *plen) {
            Ok(true) => {
                self.stats.burst_dedup_hits += 1;
                self.stats.prefills += 1;
                let admitted_at = Instant::now();
                let queue_wait_s = (admitted_at - req.arrived).as_secs_f64();
                let truncated = *plen < req.prompt.len();
                if truncated {
                    self.stats.truncated_prompts += 1;
                }
                let mut rng = self.request_rng(req.id);
                let tok = Self::sample(&mut rng, logits, req.temperature);
                let first_at = Instant::now();
                let mut ar = ActiveReq {
                    req,
                    generated: vec![tok],
                    rng,
                    first_token_at: first_at,
                    last_token_at: first_at,
                    queue_wait_s,
                    truncated_prompt: truncated,
                    modeled_start_s: self.sim.seconds,
                    modeled_start_j: self.sim.energy_j,
                };
                self.stats.generated_tokens += 1;
                if let Some(resp) = self.maybe_finish(slot, &mut ar, admitted_at) {
                    self.kv.release(slot);
                    done.push(resp);
                } else {
                    self.active[slot] = Some(ar);
                }
            }
            Ok(false) => {
                // the twin's blocks were evicted between registration and
                // now: cold-prefill this clone alone
                self.admit_paged_burst(vec![req], &[], &mut Vec::new(), done);
            }
            Err(e) => {
                eprintln!(
                    "engine: duplicate admission failed for request {} ({e}); aborting it",
                    req.id
                );
                self.stats.step_failures += 1;
                done.push(queued_response(&req, FinishReason::Aborted));
            }
        }
    }

    /// Drain everything (used by benches/tests): step until idle.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn decode_step(&mut self) -> Result<Vec<Response>> {
        let (toks, pos, active, occupancy) = self.decode_inputs();
        let (logits, cost) = self
            .backend
            .decode(&toks, &pos, &active, &mut self.kv)?;
        Ok(self.apply_decode(logits, cost, &pos, occupancy))
    }

    /// Last generated token, write position, and active flag per decode
    /// slot (pads elsewhere), plus the occupancy count — the decode
    /// arrays both schedulers hand the backend.
    fn decode_inputs(&self) -> (Vec<i32>, Vec<i32>, Vec<bool>, u64) {
        let b = self.active.len();
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        let mut occupancy = 0u64;
        for slot in 0..b {
            if let Some(ar) = &self.active[slot] {
                toks[slot] = *ar.generated.last().unwrap();
                pos[slot] = self.kv.position(slot).unwrap() as i32;
                active[slot] = true;
                occupancy += 1;
            }
        }
        (toks, pos, active, occupancy)
    }

    /// Post-decode bookkeeping shared by both schedulers: charge the
    /// step's cost, sample/advance/finish every active slot (or emit
    /// speculative rounds), and record per-token decode latencies.
    fn apply_decode(
        &mut self,
        logits: Vec<f32>,
        cost: StepCost,
        pos: &[i32],
        occupancy: u64,
    ) -> Vec<Response> {
        let m = self.kv.cfg;
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += occupancy;
        self.sim.seconds += cost.accel_s;
        self.sim.energy_j += cost.accel_j;
        // host software-datapath seconds: measured for native backends,
        // the CpuWaqModel roofline for PJRT; the shard critical path is
        // the slowest-shard sum for the tensor-parallel backend
        self.stats.host_waq_s += cost.host_waq_s;
        self.stats.host_shard_crit_s += cost.shard_crit_s;
        // prime the chunk-budget EWMA with this step's measured datapath
        // seconds (harmless under burst: chunked reads it, burst ignores)
        let meas = if cost.shard_crit_s > 0.0 { cost.shard_crit_s } else { cost.host_waq_s };
        if meas > 0.0 {
            self.decode_step_ewma = if self.decode_step_ewma == 0.0 {
                meas
            } else {
                0.8 * self.decode_step_ewma + 0.2 * meas
            };
        }

        let now = Instant::now();
        let mut done = Vec::new();
        // A speculative backend reports per-slot rounds: verification
        // already appended the round's K/V rows and truncated each slot's
        // cache to its accepted length, so the engine must NOT advance —
        // it emits the accepted draft tokens (per-token stop checks at
        // each token's virtual position) and samples from the returned row.
        if let Some(rounds) = self.backend.take_spec_rounds() {
            self.emit_spec_rounds(rounds, pos, &logits, now, &mut done);
            return done;
        }
        for slot in 0..self.active.len() {
            let Some(mut ar) = self.active[slot].take() else { continue };
            if let Err(e) = self.kv.advance(slot) {
                // contained per-slot: the request was already taken off
                // `active`, so failing here without answering it would
                // hang its waiter AND leak the slot — release + Aborted
                eprintln!(
                    "engine: slot {slot} advance failed for request {} ({e}); aborting it",
                    ar.req.id
                );
                self.stats.step_failures += 1;
                self.kv.release(slot);
                done.push(self.response_for(&mut ar, FinishReason::Aborted));
                continue;
            }
            let lrow = &logits[slot * m.vocab..(slot + 1) * m.vocab];
            let tok = Self::sample(&mut ar.rng, lrow, ar.req.temperature);
            ar.generated.push(tok);
            self.stats.generated_tokens += 1;
            // recorded inter-token latency: the gap since this request's
            // previous token — the quantity the chunked scheduler exists
            // to bound (another request's prefill stall lands here)
            self.stats.decode_lat.record((now - ar.last_token_at).as_secs_f64());
            ar.last_token_at = now;
            // no first-token bookkeeping here: admission always records
            // `first_token_at` when it samples the prefill's token, so a
            // decode step can never produce a request's first token
            if let Some(resp) = self.maybe_finish(slot, &mut ar, now) {
                self.kv.release(slot);
                done.push(resp);
            } else {
                self.active[slot] = Some(ar);
            }
        }
        done
    }

    /// Multi-token emission for one speculative decode step. Per round:
    /// count the proposal/acceptance stats, push each accepted draft
    /// token with the SAME stop checks sequential decode would have run —
    /// Eos/MaxTokens from the token stream, Length at the token's
    /// *virtual* cache position (round start `p` + tokens emitted so
    /// far + 1, exactly where `kv.exhausted` would fire had the tokens
    /// decoded one at a time) — then, if still running, sample one token
    /// from the returned logit row (the backend returns each slot's row
    /// at its accepted depth). A stop mid-list discards the remaining
    /// accepted tokens; the backend's truncate already bounded the cache
    /// and the release below frees it either way.
    fn emit_spec_rounds(
        &mut self,
        rounds: Vec<SpecRound>,
        pos: &[i32],
        logits: &[f32],
        now: Instant,
        done: &mut Vec<Response>,
    ) {
        let vocab = self.kv.cfg.vocab;
        let seq_len = self.kv.cfg.seq_len;
        let b = self.active.len();
        let mut by_slot: Vec<Option<SpecRound>> = (0..b).map(|_| None).collect();
        for r in rounds {
            if r.slot < b {
                by_slot[r.slot] = Some(r);
            }
        }
        for slot in 0..b {
            let Some(mut ar) = self.active[slot].take() else { continue };
            let Some(round) = by_slot[slot].take() else {
                // no round for an active slot: its cache position is
                // unknowable, so the only safe answer is a contained abort
                eprintln!(
                    "engine: speculative backend reported no round for slot {slot} \
                     (request {}); aborting it",
                    ar.req.id
                );
                self.stats.step_failures += 1;
                self.kv.release(slot);
                done.push(self.response_for(&mut ar, FinishReason::Aborted));
                continue;
            };
            self.stats.spec_rounds += 1;
            self.stats.spec_proposed += round.proposed;
            self.stats.spec_accepted += round.accepted.len() as u64;
            let p = pos[slot] as usize;
            let acc = round.accepted.len();
            let mut finished = None;
            let mut emitted = 0usize;
            for (j, &tok) in round.accepted.iter().enumerate() {
                ar.generated.push(tok);
                emitted += 1;
                self.stats.generated_tokens += 1;
                // accepted token j was decoded from cache rows 0..=p+j,
                // leaving the cache p+j+1 tokens long
                let exhausted = p + j + 1 >= seq_len - 1;
                if let Some(resp) = self.maybe_finish_at(&mut ar, exhausted, now) {
                    finished = Some(resp);
                    break;
                }
            }
            if finished.is_none() {
                let lrow = &logits[slot * vocab..(slot + 1) * vocab];
                let tok = Self::sample(&mut ar.rng, lrow, ar.req.temperature);
                ar.generated.push(tok);
                emitted += 1;
                self.stats.generated_tokens += 1;
                // the sampled token sits where the backend truncated to
                // (p + acc + 1), so this matches kv.exhausted exactly
                let exhausted = p + acc + 1 >= seq_len - 1;
                finished = self.maybe_finish_at(&mut ar, exhausted, now);
            }
            // a speculative round emits several tokens in one wall-clock
            // gap: split it evenly so the histogram reflects effective
            // per-token latency (what a streaming client observes)
            if emitted > 0 {
                let per = (now - ar.last_token_at).as_secs_f64() / emitted as f64;
                for _ in 0..emitted {
                    self.stats.decode_lat.record(per);
                }
                ar.last_token_at = now;
            }
            match finished {
                Some(resp) => {
                    self.kv.release(slot);
                    done.push(resp);
                }
                None => self.active[slot] = Some(ar),
            }
        }
    }

    /// Terminal-state check after each sampled token. Natural completions
    /// (Eos / MaxTokens / Length) win over deadline expiry when both hold
    /// — the work is done either way, and "completed" is the more useful
    /// label. Mid-decode expiry returns the partial tokens generated so
    /// far; the caller releases the KV slot on any `Some`.
    fn maybe_finish(&mut self, slot: usize, ar: &mut ActiveReq, now: Instant) -> Option<Response> {
        let exhausted = self.kv.exhausted(slot);
        self.maybe_finish_at(ar, exhausted, now)
    }

    /// [`Self::maybe_finish`] with the context-exhaustion test supplied by
    /// the caller: the speculative path checks each accepted token at its
    /// *virtual* position (the cache was already truncated to the round's
    /// final length, so `kv.exhausted` can't be consulted mid-list).
    fn maybe_finish_at(
        &mut self,
        ar: &mut ActiveReq,
        exhausted: bool,
        now: Instant,
    ) -> Option<Response> {
        let last = *ar.generated.last().unwrap();
        let reason = if ar.req.eos_token == Some(last) {
            Some(FinishReason::Eos)
        } else if ar.generated.len() >= ar.req.max_new_tokens {
            Some(FinishReason::MaxTokens)
        } else if exhausted {
            Some(FinishReason::Length)
        } else if ar.req.expired(now) {
            Some(FinishReason::DeadlineExpired)
        } else {
            None
        };
        reason.map(|fr| {
            let resp = self.response_for(ar, fr);
            if fr == FinishReason::DeadlineExpired {
                self.stats.expired += 1;
            } else {
                self.stats.completed += 1;
                // fold this natural completion's measured service time into
                // the EWMA feeding the retry_after_ms backpressure hint
                self.recent_service_s = if self.recent_service_s == 0.0 {
                    resp.total_s
                } else {
                    0.8 * self.recent_service_s + 0.2 * resp.total_s
                };
            }
            resp
        })
    }

    /// Build the response for a request leaving the engine (completion or
    /// abort): ONE construction site, so response fields cannot diverge
    /// between the finish and abort paths.
    fn response_for(&self, ar: &mut ActiveReq, fr: FinishReason) -> Response {
        Response {
            id: ar.req.id,
            prompt_len: ar.req.prompt.len(),
            tokens: std::mem::take(&mut ar.generated),
            finish_reason: fr,
            truncated_prompt: ar.truncated_prompt,
            ttft_s: (ar.first_token_at - ar.req.arrived).as_secs_f64(),
            queue_wait_s: ar.queue_wait_s,
            total_s: ar.req.arrived.elapsed().as_secs_f64(),
            modeled_accel_s: self.sim.seconds - ar.modeled_start_s,
            modeled_accel_j: self.sim.energy_j - ar.modeled_start_j,
            retry_after_ms: 0,
        }
    }

    /// The sampling stream for one request: seeded purely from the engine
    /// seed and the request id (golden-ratio mixed so nearby ids land far
    /// apart in seed space), never from admission order or batch state.
    /// This is what makes sampled token streams scheduler-invariant: a
    /// request's draws are consumed only by its own tokens, in token
    /// order, so `--sched burst` and `--sched chunked` replay the exact
    /// same stream however they interleave the batch.
    fn request_rng(&self, id: super::request::RequestId) -> Rng {
        Rng::new(self.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Sample the next token from one logit row, drawing from `rng` (the
    /// owning request's private stream). NaN-safe in both branches: a
    /// numerically poisoned row (overflowed accumulator, bad weights)
    /// must never panic the engine thread — see [`greedy_argmax`] and the
    /// zero-weighting of NaN entries below.
    fn sample(rng: &mut Rng, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return greedy_argmax(logits);
        }
        // softmax sample; NaN logits carry zero probability mass (f32::max
        // already ignores NaN, so `maxv` is the finite max when one exists)
        let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let exps: Vec<f64> = logits
            .iter()
            .map(|&x| {
                if x.is_nan() {
                    0.0
                } else {
                    (((x - maxv) / temperature) as f64).exp()
                }
            })
            .collect();
        let total: f64 = exps.iter().sum();
        let mut u = rng.f64() * total;
        for (i, e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (logits.len() - 1) as i32
    }

    /// Abort only the *in-flight* (slot-holding) requests, releasing
    /// their KV slots; the queue is untouched. This is the decode-failure
    /// containment path: the blast radius of a bad step is the batch that
    /// was in it, not the requests still waiting.
    pub fn abort_inflight(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        for slot in 0..self.active.len() {
            if let Some(mut ar) = self.active[slot].take() {
                self.kv.release(slot);
                out.push(self.response_for(&mut ar, FinishReason::Aborted));
            }
        }
        out
    }

    /// Abort everything in flight AND queued (shutdown / drain-deadline
    /// path). In-flight requests always report a real TTFT (their first
    /// token was sampled at admission) and their modeled-cost deltas so
    /// far; queued requests report zeros.
    pub fn abort_all(&mut self) -> Vec<Response> {
        let mut out = self.abort_inflight();
        // mid-prefill requests (chunked scheduler) have no tokens yet:
        // release their partial slots and answer like queued requests
        for p in std::mem::take(&mut self.prefilling) {
            self.kv.release(p.slot);
            out.push(queued_response(&p.req, FinishReason::Aborted));
        }
        for req in self.batcher.drain() {
            out.push(queued_response(&req, FinishReason::Aborted));
        }
        out
    }
}

/// Response for a request that never held a KV slot (rejected at submit,
/// expired in-queue, failed burst prefill, or drained at shutdown): no
/// tokens, zero TTFT, zero modeled deltas, and its whole lifetime counts
/// as queue wait.
fn queued_response(req: &Request, fr: FinishReason) -> Response {
    let total_s = req.arrived.elapsed().as_secs_f64();
    Response {
        id: req.id,
        prompt_len: req.prompt.len(),
        tokens: vec![],
        finish_reason: fr,
        truncated_prompt: false,
        ttft_s: 0.0,
        queue_wait_s: total_s,
        total_s,
        modeled_accel_s: 0.0,
        modeled_accel_j: 0.0,
        retry_after_ms: 0,
    }
}

/// Greedy argmax over one logit row, NaN-safe: NaN entries are skipped
/// (a poisoned channel cannot hijack the argmax), the comparator is the
/// total order `f32::total_cmp` (ties resolve to the highest index, as
/// the old `partial_cmp` argmax did), and an all-NaN row falls back to
/// token 0 instead of panicking the engine thread.
pub(crate) fn greedy_argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

impl KvManager {
    /// free-slot count helper used by the batcher handshake
    pub fn decode_batch_free(&self) -> usize {
        self.slots.iter().filter(|s| **s == super::kv::Slot::Free).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::PagedPrefillOut;
    use crate::coordinator::backend::PrefillOut;
    use crate::coordinator::backend::StepCost;
    use crate::runtime::artifacts::ModelCfg;
    use crate::runtime::HostTensor;

    #[test]
    fn greedy_argmax_skips_nan_and_never_panics() {
        // plain rows behave exactly like the old partial_cmp argmax
        assert_eq!(greedy_argmax(&[0.1, 2.0, -1.0]), 1);
        // ties resolve to the highest index (max_by keeps the last max)
        assert_eq!(greedy_argmax(&[3.0, 3.0, 1.0]), 1);
        // a NaN-poisoned channel cannot hijack the argmax
        assert_eq!(greedy_argmax(&[0.5, f32::NAN, 2.0, f32::NAN, -7.0]), 2);
        assert_eq!(greedy_argmax(&[f32::NAN, 1.0]), 1);
        // -inf rows still pick a real index; an all-NaN row falls back to 0
        assert_eq!(greedy_argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 1);
        assert_eq!(greedy_argmax(&[f32::NAN, f32::NAN, f32::NAN]), 0);
        assert_eq!(greedy_argmax(&[]), 0);
    }

    /// Backend that emits NaN-poisoned logit rows: one finite channel at
    /// prefill (index 3), all-NaN rows at decode — the shape of a
    /// numerically blown-up datapath.
    struct NanBackend {
        model: ModelCfg,
    }

    impl DecodeBackend for NanBackend {
        fn spec(&self) -> BackendSpec {
            BackendSpec::Native(WaqBackend::Packed)
        }

        fn model(&self) -> ModelCfg {
            self.model
        }

        fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
            let m = self.model;
            let plen = prompt.len().clamp(1, m.seq_len - 1);
            let shape = [m.n_layers, 1, m.n_heads, m.seq_len, m.head_dim];
            let mut logits = vec![f32::NAN; m.vocab];
            logits[3] = 1.0;
            Ok(PrefillOut {
                plen,
                logits,
                k_cache: HostTensor::zeros(&shape),
                v_cache: HostTensor::zeros(&shape),
                cost: StepCost::default(),
            })
        }

        fn decode(
            &mut self,
            _toks: &[i32],
            _pos: &[i32],
            _active: &[bool],
            _kv: &mut KvManager,
        ) -> Result<(Vec<f32>, StepCost)> {
            let m = self.model;
            Ok((vec![f32::NAN; m.decode_batch * m.vocab], StepCost::default()))
        }
    }

    /// Well-behaved scripted backend that can be told to fail decode on
    /// its Nth call — the minimal engine-fault fixture (the full seeded
    /// fault matrix lives in `backend::chaos`). Counts the prompt rows it
    /// actually prefills, so dedup tests can prove clones computed nothing.
    struct ScriptedBackend {
        model: ModelCfg,
        decode_calls: usize,
        fail_decode_on: Option<usize>,
        prefill_rows: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    }

    impl ScriptedBackend {
        fn ok(model: ModelCfg) -> Self {
            ScriptedBackend {
                model,
                decode_calls: 0,
                fail_decode_on: None,
                prefill_rows: Default::default(),
            }
        }

        /// The fixture plus a handle to its prefill-row counter (the
        /// backend is boxed away into the engine, so the counter must be
        /// cloned out first).
        fn counted(
            model: ModelCfg,
        ) -> (Self, std::sync::Arc<std::sync::atomic::AtomicUsize>) {
            let b = Self::ok(model);
            let rows = b.prefill_rows.clone();
            (b, rows)
        }
    }

    impl DecodeBackend for ScriptedBackend {
        fn spec(&self) -> BackendSpec {
            BackendSpec::Native(WaqBackend::Packed)
        }

        fn model(&self) -> ModelCfg {
            self.model
        }

        fn prefill(&mut self, prompt: &[i32]) -> Result<PrefillOut> {
            self.prefill_rows.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let m = self.model;
            let plen = prompt.len().clamp(1, m.seq_len - 1);
            let shape = [m.n_layers, 1, m.n_heads, m.seq_len, m.head_dim];
            let mut logits = vec![0.0f32; m.vocab];
            logits[1] = 1.0;
            Ok(PrefillOut {
                plen,
                logits,
                k_cache: HostTensor::zeros(&shape),
                v_cache: HostTensor::zeros(&shape),
                cost: StepCost::default(),
            })
        }

        fn decode(
            &mut self,
            _toks: &[i32],
            _pos: &[i32],
            _active: &[bool],
            _kv: &mut KvManager,
        ) -> Result<(Vec<f32>, StepCost)> {
            self.decode_calls += 1;
            if self.fail_decode_on == Some(self.decode_calls) {
                anyhow::bail!("scripted decode fault (call {})", self.decode_calls);
            }
            let m = self.model;
            let mut logits = vec![0.0f32; m.decode_batch * m.vocab];
            for s in 0..m.decode_batch {
                logits[s * m.vocab + 2] = 1.0;
            }
            Ok((logits, StepCost::default()))
        }

        fn supports_paged_prefill(&self) -> bool {
            true
        }

        /// Minimal honest paged prefill: appends constant K/V rows for the
        /// uncached tail (the real contract — the cached prefix is already
        /// in the slot's block table) and returns fixed logits.
        fn prefill_paged(
            &mut self,
            reqs: &[PagedPrefill<'_>],
            kv: &mut KvManager,
        ) -> Result<Vec<PagedPrefillOut>> {
            self.prefill_rows
                .fetch_add(reqs.len(), std::sync::atomic::Ordering::Relaxed);
            let m = self.model;
            let d = m.n_heads * m.head_dim;
            let mut outs = Vec::with_capacity(reqs.len());
            for r in reqs {
                let plen = r.prompt.len().clamp(1, m.seq_len - 1);
                for l in 0..m.n_layers {
                    for p in r.cached..plen {
                        kv.append_token(l, r.slot, p, &vec![0.1; d], &vec![0.2; d])
                            .map_err(anyhow::Error::msg)?;
                    }
                }
                let mut logits = vec![0.0f32; m.vocab];
                logits[1] = 1.0;
                outs.push(PagedPrefillOut { plen, logits, cost: StepCost::default() });
            }
            Ok(outs)
        }
    }

    #[test]
    fn prefix_cache_admission_hits_and_reuses_blocks() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig { prefix_cache: true, ..Default::default() };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        assert!(e.prefix_cache_enabled());
        // one full 16-token block plus a 4-token partial tail block
        let prompt: Vec<i32> = (100..120).collect();
        e.submit(Request::new(1, prompt.clone(), 2));
        let done = e.run_to_completion().expect("cold run");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish_reason, FinishReason::MaxTokens);
        assert_eq!(e.stats.prefix_hits, 0, "cold index: no hit");
        assert_eq!(e.stats.prefix_blocks_reused, 0);
        let parked = e.kv().cache().in_use_blocks();
        assert!(parked > 0, "released slot leaves its prompt parked in the index");
        // same prompt again: the index serves every token but the last
        // (16 full + 3 of the partial chunk = 19 of 20)
        e.submit(Request::new(2, prompt.clone(), 2));
        let done = e.run_to_completion().expect("warm run");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish_reason, FinishReason::MaxTokens);
        assert_eq!(e.stats.prefix_hits, 1, "warm admission hit");
        // 2 blocks aliased per layer (full chunk + partial chunk)
        assert_eq!(e.stats.prefix_blocks_reused, 2 * cfg.n_layers as u64);
        assert_eq!(e.stats.prefills, 2);
        // a divergent prompt sharing only the full block still hits
        let mut fork = prompt[..18].to_vec();
        fork[17] = 999;
        e.submit(Request::new(3, fork, 2));
        e.run_to_completion().expect("fork run");
        assert_eq!(e.stats.prefix_hits, 2);
        assert_eq!(e.stats.step_failures, 0);
        assert_eq!(e.stats.prefill_failures, 0);
    }

    #[test]
    fn rejected_response_always_carries_retry_after_hint() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig { queue_cap: 1, ..Default::default() };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        assert!(e.try_submit(Request::new(1, vec![1, 2], 2)).is_none());
        // nothing has completed yet: the hint falls back to the modeled
        // cost of serving the rejected request itself (prefill +
        // max_new_tokens decode steps) — never a meaningless 0
        let r = e.try_submit(Request::new(2, vec![1, 2], 2)).expect("queue full");
        assert_eq!(r.finish_reason, FinishReason::Rejected);
        assert!(r.retry_after_ms >= 1, "cold hint from the cost model, got 0");
        let done = e.run_to_completion().expect("run");
        assert_eq!(done.len(), 1);
        // EWMA primed by the natural completion: rejections now estimate
        // from measured service time instead of the model
        assert!(e.try_submit(Request::new(3, vec![1, 2], 2)).is_none());
        let r = e.try_submit(Request::new(4, vec![1, 2], 2)).expect("queue full");
        assert_eq!(r.finish_reason, FinishReason::Rejected);
        assert!(r.retry_after_ms >= 1, "hint {}", r.retry_after_ms);
        // the drain-path rejection carries the hint too
        let drained = e.reject(Request::new(5, vec![1], 2));
        assert!(drained.retry_after_ms >= 1);
        assert_eq!(e.stats.rejected, 3);
    }

    /// Satellite: intra-burst duplicate-prompt dedup on the dense
    /// (non-paged) admission path — two identical prompts admitted in one
    /// burst run ONE backend prefill row; the clone reuses the computed
    /// K/V + logits and produces a bit-identical greedy stream.
    #[test]
    fn dense_burst_of_clones_prefills_once_and_matches() {
        let cfg = ModelCfg::test_preset(); // decode_batch 2: one burst
        let ecfg = EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() };
        let (backend, rows) = ScriptedBackend::counted(cfg);
        let mut e = Engine::new(Box::new(backend), &ecfg);
        let prompt: Vec<i32> = (40..52).collect();
        e.submit(Request::new(1, prompt.clone(), 3));
        e.submit(Request::new(2, prompt.clone(), 3));
        let done = e.run_to_completion().expect("run");
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|r| r.finish_reason == FinishReason::MaxTokens));
        let a = done.iter().find(|r| r.id == 1).unwrap();
        let b = done.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(a.tokens, b.tokens, "clones sample identical greedy streams");
        let computed = rows.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(computed, 1, "one prefill row serves both clones");
        assert_eq!(e.stats.burst_dedup_hits, 1);
        assert_eq!(e.stats.prefills, 2, "prefills keeps per-request semantics");
        assert_eq!(e.stats.completed, 2);
        assert_eq!(e.kv().cache().in_use_blocks(), 0);
    }

    /// Satellite: the same collapse on the paged (prefix-cache) path —
    /// the unique prefills + registers, the clone admits as a full-length
    /// alias of the freshly indexed prompt (zero tail compute) and samples
    /// from its twin's logit row.
    #[test]
    fn paged_burst_of_clones_aliases_twin_blocks() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig {
            prefix_cache: true,
            policy: AdmitPolicy::FillAll,
            ..Default::default()
        };
        let (backend, rows) = ScriptedBackend::counted(cfg);
        let mut e = Engine::new(Box::new(backend), &ecfg);
        // one full 16-token block plus a 2-token partial tail block
        let prompt: Vec<i32> = (300..318).collect();
        e.submit(Request::new(1, prompt.clone(), 2));
        e.submit(Request::new(2, prompt.clone(), 2));
        let done = e.run_to_completion().expect("run");
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|r| r.finish_reason == FinishReason::MaxTokens));
        let a = done.iter().find(|r| r.id == 1).unwrap();
        let b = done.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(a.tokens, b.tokens, "clone decodes over aliased blocks bit-exactly");
        let computed = rows.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(computed, 1, "the clone never reached the backend");
        assert_eq!(e.stats.burst_dedup_hits, 1);
        assert_eq!(e.stats.prefills, 2);
        // dedup is its own counter, not a prefix hit (the unique was cold)
        assert_eq!(e.stats.prefix_hits, 0);
        assert_eq!(e.stats.completed, 2);
        assert_eq!(e.stats.step_failures, 0);
        assert_eq!(e.stats.prefill_failures, 0);
    }

    #[test]
    fn queue_cap_rejects_immediately_and_counts() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig { queue_cap: 2, ..Default::default() };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        assert!(e.try_submit(Request::new(1, vec![1, 2], 2)).is_none());
        assert!(e.try_submit(Request::new(2, vec![1, 2], 2)).is_none());
        let r = e.try_submit(Request::new(3, vec![1, 2], 2)).expect("queue full");
        assert_eq!(r.id, 3);
        assert_eq!(r.finish_reason, FinishReason::Rejected);
        assert!(r.tokens.is_empty());
        assert_eq!(e.stats.rejected, 1);
        // the two admitted requests still complete; the rejected one is
        // not counted as completed
        let done = e.run_to_completion().expect("run");
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|r| r.finish_reason == FinishReason::MaxTokens));
        assert_eq!(e.stats.completed, 2);
        assert_eq!(e.kv().cache().in_use_blocks(), 0);
    }

    #[test]
    fn deadline_expires_in_queue_before_any_compute() {
        let cfg = ModelCfg::test_preset();
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &EngineConfig::default());
        e.submit(Request::new(1, vec![1, 2], 4).with_deadline_ms(0));
        e.submit(Request::new(2, vec![1, 2], 4)); // no deadline
        let done = e.run_to_completion().expect("run");
        let exp = done.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(exp.finish_reason, FinishReason::DeadlineExpired);
        assert!(exp.tokens.is_empty(), "expired in-queue: no tokens");
        assert!(exp.queue_wait_s > 0.0 && (exp.queue_wait_s - exp.total_s).abs() < 1e-9);
        let ok = done.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(ok.finish_reason, FinishReason::MaxTokens);
        assert_eq!(e.stats.expired, 1);
        assert_eq!(e.stats.prefills, 1, "expired request never prefilled");
        assert_eq!(e.kv().cache().in_use_blocks(), 0);
    }

    #[test]
    fn deadline_expires_mid_decode_with_partial_tokens_and_slot_reclaim() {
        let cfg = ModelCfg::test_preset();
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &EngineConfig::default());
        // deadline passes after admission but long before 1000 tokens
        e.submit(Request::new(1, vec![1, 2, 3], 1000).with_deadline_ms(30));
        let first = e.step().expect("admit step");
        assert!(first.is_empty(), "still decoding");
        assert_eq!(e.active_count(), 1);
        std::thread::sleep(std::time::Duration::from_millis(60));
        let mut done = Vec::new();
        while e.has_work() {
            done.extend(e.step().expect("step"));
        }
        assert_eq!(done.len(), 1);
        let r = &done[0];
        assert_eq!(r.finish_reason, FinishReason::DeadlineExpired);
        assert!(!r.tokens.is_empty(), "mid-decode expiry returns partial tokens");
        assert!(r.tokens.len() < 1000);
        assert_eq!(e.stats.expired, 1);
        assert_eq!(e.stats.completed, 0);
        assert_eq!(e.kv().cache().in_use_blocks(), 0, "KV slot reclaimed");
    }

    #[test]
    fn default_deadline_applies_only_when_request_has_none() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig { default_deadline_ms: 60_000, ..Default::default() };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        e.submit(Request::new(1, vec![1], 2)); // gets the 60s default
        e.submit(Request::new(2, vec![1], 2).with_deadline_ms(0)); // keeps its own
        let done = e.run_to_completion().expect("run");
        assert_eq!(
            done.iter().find(|r| r.id == 1).unwrap().finish_reason,
            FinishReason::MaxTokens
        );
        assert_eq!(
            done.iter().find(|r| r.id == 2).unwrap().finish_reason,
            FinishReason::DeadlineExpired
        );
    }

    /// The engine-fault containment contract: a decode error aborts the
    /// batch that was in flight (each waiter answered `Aborted`, slots
    /// released) but the engine keeps serving — the next submit completes.
    #[test]
    fn decode_fault_aborts_inflight_but_engine_survives() {
        let cfg = ModelCfg::test_preset();
        let backend = ScriptedBackend {
            model: cfg,
            decode_calls: 0,
            fail_decode_on: Some(2),
            prefill_rows: Default::default(),
        };
        let mut e = Engine::new(
            Box::new(backend),
            &EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() },
        );
        e.submit(Request::new(1, vec![1, 2], 50));
        e.submit(Request::new(2, vec![3, 4], 50));
        let done = e.run_to_completion().expect("contained run");
        assert_eq!(done.len(), 2);
        for r in &done {
            assert_eq!(r.finish_reason, FinishReason::Aborted, "request {}", r.id);
            assert!(!r.tokens.is_empty(), "partial tokens survive the abort");
        }
        assert_eq!(e.stats.step_failures, 1);
        assert_eq!(e.kv().cache().in_use_blocks(), 0, "slots released on abort");
        // the engine is still alive: a fresh request completes normally
        e.submit(Request::new(3, vec![5], 3));
        let after = e.run_to_completion().expect("post-fault run");
        assert_eq!(after.len(), 1);
        assert_eq!(after[0].finish_reason, FinishReason::MaxTokens);
        assert_eq!(e.stats.completed, 1);
    }

    /// NaN logits must never panic the engine thread — greedy picks the
    /// finite channel (prefill) or falls back to token 0 (all-NaN decode
    /// rows), and the softmax branch treats NaN as zero probability mass.
    #[test]
    fn nan_logits_never_panic_sampling() {
        let cfg = ModelCfg::test_preset();
        let mut e = Engine::new(Box::new(NanBackend { model: cfg }), &EngineConfig::default());
        e.submit(Request::new(1, vec![1, 2, 3], 3));
        let mut greedy = e.run_to_completion().expect("greedy run");
        let r = greedy.remove(0);
        assert_eq!(r.tokens.len(), 3);
        assert_eq!(r.tokens[0], 3, "greedy must find the finite channel");
        assert!(r.tokens[1..].iter().all(|&t| t == 0), "all-NaN rows fall back to 0");

        // softmax branch: all-NaN decode rows carry zero mass, sampling
        // stays in-vocab without panicking
        let mut req = Request::new(2, vec![4, 5], 4);
        req.temperature = 1.0;
        e.submit(req);
        let sampled = e.run_to_completion().expect("softmax run").remove(0);
        assert_eq!(sampled.tokens.len(), 4);
        assert!(sampled
            .tokens
            .iter()
            .all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    }

    /// Tentpole: the chunked scheduler prefills a prompt incrementally
    /// across steps (cursor resume through the paged cache), samples the
    /// first token only on the final chunk, and produces the same tokens
    /// as a burst run of the same request.
    #[test]
    fn chunked_prefill_resumes_across_steps_and_matches_burst() {
        let cfg = ModelCfg::test_preset();
        let prompt: Vec<i32> = (500..510).collect(); // 10 tokens
        let mut burst =
            Engine::new(Box::new(ScriptedBackend::ok(cfg)), &EngineConfig::default());
        burst.submit(Request::new(1, prompt.clone(), 3));
        let bresp = burst.run_to_completion().expect("burst").remove(0);

        let ecfg = EngineConfig {
            sched: SchedPolicy::Chunked,
            prefill_chunk: 4,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        assert_eq!(e.sched(), SchedPolicy::Chunked);
        e.submit(Request::new(1, prompt.clone(), 3));
        assert!(e.step().expect("chunk 1").is_empty());
        assert_eq!(e.prefilling_count(), 1, "mid-prefill after 4/10 rows");
        assert_eq!(e.stats.generated_tokens, 0, "no token before the final chunk");
        assert!(e.step().expect("chunk 2").is_empty());
        assert_eq!(e.prefilling_count(), 1, "mid-prefill after 8/10 rows");
        let done = e.run_to_completion().expect("finish");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish_reason, FinishReason::MaxTokens);
        assert_eq!(done[0].tokens, bresp.tokens, "chunked == burst token stream");
        assert_eq!(e.stats.prefills, 1);
        assert_eq!(e.stats.completed, 1);
        assert_eq!(e.kv().cache().in_use_blocks(), 0);
    }

    /// Tentpole: in-flight decodes advance every mixed step while a long
    /// prompt prefills chunk-by-chunk — the starvation the iteration-level
    /// scheduler exists to prevent — and their inter-token gaps land in
    /// the recorded latency histogram.
    #[test]
    fn chunked_decode_advances_while_long_prompt_prefills() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig {
            sched: SchedPolicy::Chunked,
            prefill_chunk: 2,
            policy: AdmitPolicy::FillAll,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        // A: 1-token prompt, promoted by its first chunk
        e.submit(Request::new(1, vec![7], 40));
        assert!(e.step().expect("admit A").is_empty());
        assert_eq!(e.prefilling_count(), 0, "A promoted in one chunk");
        // B: 6-token prompt → three 2-row chunks
        e.submit(Request::new(2, (600..606).collect(), 2));
        for expect in [1usize, 1, 0] {
            let g0 = e.stats.generated_tokens;
            assert!(e.step().expect("mixed step").is_empty());
            assert_eq!(e.prefilling_count(), expect);
            assert!(e.stats.generated_tokens > g0, "A decoded during B's prefill");
        }
        assert!(e.stats.decode_lat.count() > 0, "inter-token gaps recorded");
        e.abort_all();
        assert_eq!(e.kv().cache().in_use_blocks(), 0);
    }

    /// Satellite regression (engine-level): a deadline expiring *between
    /// chunks* answers `DeadlineExpired` before any token exists and
    /// reclaims the partially filled KV slot.
    #[test]
    fn chunked_deadline_expires_between_chunks_before_first_token() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig {
            sched: SchedPolicy::Chunked,
            prefill_chunk: 1,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        e.submit(Request::new(1, (700..710).collect(), 4).with_deadline_ms(30));
        assert!(e.step().expect("chunk 1").is_empty());
        assert_eq!(e.prefilling_count(), 1);
        std::thread::sleep(std::time::Duration::from_millis(60));
        let done = e.run_to_completion().expect("expire");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish_reason, FinishReason::DeadlineExpired);
        assert!(done[0].tokens.is_empty(), "expired before the first token");
        assert_eq!(e.stats.expired, 1);
        assert_eq!(e.stats.prefills, 0, "the prefill never completed");
        assert_eq!(e.prefilling_count(), 0);
        assert_eq!(e.kv().cache().in_use_blocks(), 0, "partial KV slot reclaimed");
    }

    #[test]
    fn chunked_without_paged_backend_falls_back_to_burst() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig { sched: SchedPolicy::Chunked, ..Default::default() };
        let mut e = Engine::new(Box::new(NanBackend { model: cfg }), &ecfg);
        assert_eq!(e.sched(), SchedPolicy::Burst, "no paged prefill → burst");
        e.submit(Request::new(1, vec![1, 2], 2));
        assert_eq!(e.run_to_completion().expect("fallback run").len(), 1);
    }

    /// `--prefill-chunk 0`: before the datapath EWMAs are primed the
    /// auto-budget falls back to one KV block (16 rows).
    #[test]
    fn auto_chunk_budget_defaults_to_one_block_cold() {
        let cfg = ModelCfg::test_preset();
        let ecfg = EngineConfig {
            sched: SchedPolicy::Chunked,
            prefill_chunk: 0,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(ScriptedBackend::ok(cfg)), &ecfg);
        let prompt: Vec<i32> = (800..820).collect(); // 20 tokens
        e.submit(Request::new(1, prompt, 2));
        assert!(e.step().expect("chunk 1").is_empty());
        assert_eq!(e.prefilling_count(), 1, "16/20 rows after the cold chunk");
        assert!(e.step().expect("chunk 2").is_empty());
        assert_eq!(e.prefilling_count(), 0, "second chunk completes the prompt");
        let done = e.run_to_completion().expect("finish");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 2);
    }
}
