//! The serving engine: continuous-batched decode over the PJRT runtime.
//!
//! Owns the Runtime (not Send — the engine lives on one thread), the
//! device-resident weight buffers (uploaded once), the KV slot manager and
//! the batcher. Each `step()`:
//!   1. admits queued requests into free slots (prefill artifact),
//!   2. runs one `decode_step` for all slots (inactive slots padded),
//!   3. samples next tokens, advances slots, completes finished requests.
//! A simulated-OASIS clock advances alongside, so every response reports
//! both measured CPU latency and modeled accelerator latency/energy.

use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{AdmitPolicy, Batcher};
use super::kv::KvManager;
use super::request::{EngineStats, FinishReason, Request, Response};
use crate::baselines::CpuWaqModel;
use crate::gemm::WaqBackend;
use crate::models::LlmSpec;
use crate::runtime::{DeviceBuffer, HostTensor, ParamSet, Runtime};
use crate::sim::{self, HwConfig, OasisMode};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub policy: AdmitPolicy,
    pub seed: u64,
    pub mode: OasisMode,
    /// Which software WAQ GEMM backend the host-datapath *model* assumes
    /// (`baselines::cpu::CpuWaqModel`, reported as `stats.host_waq_s`).
    /// Decode compute itself always runs the PJRT artifact; this knob does
    /// not change measured serving throughput.
    pub waq_backend: WaqBackend,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: AdmitPolicy::OnePerStep,
            seed: 0xE116,
            mode: OasisMode::a4(),
            waq_backend: WaqBackend::default(),
        }
    }
}

struct ActiveReq {
    req: Request,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
    modeled_start_s: f64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SimTotals {
    pub seconds: f64,
    pub energy_j: f64,
}

pub struct Engine {
    rt: Runtime,
    params_host: Vec<HostTensor>,
    weight_buffers: Vec<DeviceBuffer>,
    kv: KvManager,
    batcher: Batcher,
    active: Vec<Option<ActiveReq>>,
    pub stats: EngineStats,
    pub sim: SimTotals,
    hw: HwConfig,
    host_model: CpuWaqModel,
    spec: LlmSpec,
    mode: OasisMode,
    rng: Rng,
}

impl Engine {
    pub fn new(mut rt: Runtime, params: ParamSet, cfg: EngineConfig) -> Result<Engine> {
        let m = rt.manifest.model;
        // compile the serving artifacts up front
        rt.load("decode_step")?;
        rt.load("prefill")?;
        let weight_buffers = params
            .tensors
            .iter()
            .map(|t| rt.upload(t))
            .collect::<Result<Vec<_>>>()?;
        let spec = LlmSpec {
            name: "served",
            n_layers: m.n_layers,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_heads,
            d_ff: m.d_ff,
            vocab: m.vocab,
            gated_mlp: false,
        };
        let stats =
            EngineStats { waq_backend: cfg.waq_backend.name(), ..Default::default() };
        Ok(Engine {
            kv: KvManager::new(m),
            batcher: Batcher::new(cfg.policy),
            active: (0..m.decode_batch).map(|_| None).collect(),
            stats,
            sim: SimTotals::default(),
            hw: HwConfig::default(),
            host_model: CpuWaqModel::host(cfg.waq_backend),
            spec,
            mode: cfg.mode,
            rng: Rng::new(cfg.seed),
            params_host: params.tensors,
            rt,
            weight_buffers,
        })
    }

    /// The software WAQ GEMM backend this engine models the host datapath
    /// with.
    pub fn waq_backend(&self) -> WaqBackend {
        self.host_model.backend
    }

    pub fn model(&self) -> crate::runtime::artifacts::ModelCfg {
        self.rt.manifest.model
    }

    pub fn submit(&mut self, r: Request) {
        self.batcher.enqueue(r);
    }

    pub fn has_work(&self) -> bool {
        self.batcher.pending() > 0 || self.kv.active_count() > 0
    }

    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    pub fn active_count(&self) -> usize {
        self.kv.active_count()
    }

    /// One engine iteration; returns completed responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- admission (prefill) ---------------------------------------
        let free = self.kv.decode_batch_free();
        for req in self.batcher.admit(free) {
            match self.prefill(&req) {
                Ok(first_logits_slot) => {
                    let (slot, logits) = first_logits_slot;
                    // the prefill's last-position logits give token #1
                    let tok = self.sample(&logits, req.temperature);
                    let mut ar = ActiveReq {
                        req,
                        generated: vec![tok],
                        first_token_at: Some(Instant::now()),
                        modeled_start_s: self.sim.seconds,
                    };
                    self.stats.generated_tokens += 1;
                    // completion checks on the very first token
                    if let Some(resp) = self.maybe_finish(slot, &mut ar) {
                        self.kv.release(slot);
                        done.push(resp);
                    } else {
                        self.active[slot] = Some(ar);
                    }
                }
                Err(e) => return Err(anyhow!("prefill failed: {e}")),
            }
        }

        // ---- decode ------------------------------------------------------
        if self.kv.active_count() > 0 {
            let responses = self.decode_step()?;
            done.extend(responses);
        }
        Ok(done)
    }

    /// Drain everything (used by benches/tests): step until idle.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    fn prefill(&mut self, req: &Request) -> Result<(usize, Vec<f32>)> {
        let m = self.rt.manifest.model;
        let slot = self
            .kv
            .free_slot()
            .ok_or_else(|| anyhow!("admit with no free slot"))?;
        let plen = req.prompt.len().min(m.seq_len - 1).max(1);
        let mut padded = vec![0i32; m.seq_len];
        padded[..plen].copy_from_slice(&req.prompt[..plen]);

        let exe = self.rt.load("prefill")?;
        let mut bufs: Vec<&DeviceBuffer> = self.weight_buffers.iter().collect();
        let ptoks = self.rt.upload(&HostTensor::i32(padded, &[1, m.seq_len]))?;
        let plen_b = self.rt.upload(&HostTensor::scalar_i32(plen as i32))?;
        bufs.push(&ptoks);
        bufs.push(&plen_b);
        let out = exe.run_buffers(&bufs)?;
        let logits = out[0].as_f32()?.to_vec();
        self.kv
            .install_prefill(slot, req.id, plen, &out[1], &out[2])
            .map_err(|e| anyhow!(e))?;
        self.stats.prefills += 1;
        // modeled accelerator cost of this prefill
        let c = sim::llm::prefill_cost(&self.hw, &self.spec, self.mode, plen);
        self.sim.seconds += c.seconds;
        self.sim.energy_j += c.energy_j;
        Ok((slot, logits))
    }

    fn decode_step(&mut self) -> Result<Vec<Response>> {
        let m = self.rt.manifest.model;
        let b = m.decode_batch;
        // last generated token (or pad) + position per slot
        let mut toks = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut occupancy = 0u64;
        let mut mean_ctx = 0usize;
        for slot in 0..b {
            if let Some(ar) = &self.active[slot] {
                toks[slot] = *ar.generated.last().unwrap();
                pos[slot] = self.kv.position(slot).unwrap() as i32;
                occupancy += 1;
                mean_ctx += pos[slot] as usize;
            }
        }
        let active_n = occupancy as usize;
        mean_ctx /= active_n.max(1);

        let exe = self.rt.load("decode_step")?;
        let mut bufs: Vec<&DeviceBuffer> = self.weight_buffers.iter().collect();
        let kb = self.rt.upload(&self.kv.k_tensor())?;
        let vb = self.rt.upload(&self.kv.v_tensor())?;
        let tb = self.rt.upload(&HostTensor::i32(toks, &[b]))?;
        let pb = self.rt.upload(&HostTensor::i32(pos, &[b]))?;
        bufs.push(&kb);
        bufs.push(&vb);
        bufs.push(&tb);
        bufs.push(&pb);
        let out = exe.run_buffers(&bufs)?;
        let logits = out[0].as_f32()?;
        self.kv
            .update_from_step(&out[1], &out[2])
            .map_err(|e| anyhow!(e))?;

        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += occupancy;
        // modeled accelerator cost of this batched decode step
        let c = sim::decode_step_cost(&self.hw, &self.spec, self.mode, active_n.max(1), mean_ctx.max(1));
        self.sim.seconds += c.seconds;
        self.sim.energy_j += c.energy_j;
        // ... and the modeled host software-datapath cost under the
        // configured WAQ backend (packed/tiled vs direct vs histogram)
        self.stats.host_waq_s += self.host_model.decode_step_seconds(&self.spec, active_n.max(1));

        let mut done = Vec::new();
        for slot in 0..b {
            let Some(mut ar) = self.active[slot].take() else { continue };
            self.kv.advance(slot).map_err(|e| anyhow!(e))?;
            let lrow = &logits[slot * m.vocab..(slot + 1) * m.vocab];
            let tok = self.sample(lrow, ar.req.temperature);
            ar.generated.push(tok);
            self.stats.generated_tokens += 1;
            if ar.first_token_at.is_none() {
                ar.first_token_at = Some(Instant::now());
            }
            if let Some(resp) = self.maybe_finish(slot, &mut ar) {
                self.kv.release(slot);
                done.push(resp);
            } else {
                self.active[slot] = Some(ar);
            }
        }
        Ok(done)
    }

    fn maybe_finish(&mut self, slot: usize, ar: &mut ActiveReq) -> Option<Response> {
        let last = *ar.generated.last().unwrap();
        let reason = if ar.req.eos_token == Some(last) {
            Some(FinishReason::Eos)
        } else if ar.generated.len() >= ar.req.max_new_tokens {
            Some(FinishReason::MaxTokens)
        } else if self.kv.exhausted(slot) {
            Some(FinishReason::Length)
        } else {
            None
        };
        reason.map(|fr| {
            self.stats.completed += 1;
            Response {
                id: ar.req.id,
                prompt_len: ar.req.prompt.len(),
                tokens: std::mem::take(&mut ar.generated),
                finish_reason: fr,
                ttft_s: ar
                    .first_token_at
                    .map(|t| (t - ar.req.arrived).as_secs_f64())
                    .unwrap_or(0.0),
                total_s: ar.req.arrived.elapsed().as_secs_f64(),
                modeled_accel_s: self.sim.seconds - ar.modeled_start_s,
                modeled_accel_j: self.sim.energy_j,
            }
        })
    }

    fn sample(&mut self, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
        }
        // softmax sample
        let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let exps: Vec<f64> = logits
            .iter()
            .map(|&x| (((x - maxv) / temperature) as f64).exp())
            .collect();
        let total: f64 = exps.iter().sum();
        let mut u = self.rng.f64() * total;
        for (i, e) in exps.iter().enumerate() {
            u -= e;
            if u <= 0.0 {
                return i as i32;
            }
        }
        (logits.len() - 1) as i32
    }

    /// Abort everything in flight (shutdown path).
    pub fn abort_all(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        for slot in 0..self.active.len() {
            if let Some(mut ar) = self.active[slot].take() {
                self.kv.release(slot);
                out.push(Response {
                    id: ar.req.id,
                    prompt_len: ar.req.prompt.len(),
                    tokens: std::mem::take(&mut ar.generated),
                    finish_reason: FinishReason::Aborted,
                    ttft_s: 0.0,
                    total_s: ar.req.arrived.elapsed().as_secs_f64(),
                    modeled_accel_s: 0.0,
                    modeled_accel_j: 0.0,
                });
            }
        }
        for req in self.batcher.drain() {
            out.push(Response {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: vec![],
                finish_reason: FinishReason::Aborted,
                ttft_s: 0.0,
                total_s: req.arrived.elapsed().as_secs_f64(),
                modeled_accel_s: 0.0,
                modeled_accel_j: 0.0,
            });
        }
        out
    }

    /// Host parameter tensors (e.g. for eval reuse).
    pub fn params(&self) -> &[HostTensor] {
        &self.params_host
    }
}

impl KvManager {
    /// free-slot count helper used by the batcher handshake
    pub fn decode_batch_free(&self) -> usize {
        self.slots.iter().filter(|s| **s == super::kv::Slot::Free).count()
    }
}
