//! KV-cache slot manager: slot lifecycle (free -> prefilled -> decoding ->
//! free) over the paged, precision-pluggable [`PagedKvCache`]. The decode
//! artifact is lowered for a fixed slot count B and max context S; this
//! module owns the admission-facing view of the cache — which request
//! holds which slot, at which position — while block allocation and
//! payload storage (FP32 or n-bit K-Means) live in `crate::kvcache`.
//! Slot state is the coordinator invariant most heavily property-tested
//! (no leaks, no double-assignments, position bounds).

use crate::kvcache::{KvPrecision, PagedKvCache, PrefixMatch};
use crate::runtime::artifacts::ModelCfg;
use crate::runtime::HostTensor;

use super::request::RequestId;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Slot {
    Free,
    Active { request: RequestId, pos: usize },
}

pub struct KvManager {
    pub cfg: ModelCfg,
    pub slots: Vec<Slot>,
    cache: PagedKvCache,
}

impl KvManager {
    /// FP32 storage (bit-exact with the dense cache this replaced).
    pub fn new(cfg: ModelCfg) -> Self {
        Self::with_precision(cfg, KvPrecision::Fp32)
    }

    pub fn with_precision(cfg: ModelCfg, precision: KvPrecision) -> Self {
        Self::with_precision_opts(cfg, precision, false)
    }

    /// Full-option constructor: storage precision plus the prompt-prefix
    /// radix index (`--prefix-cache on`).
    pub fn with_precision_opts(
        cfg: ModelCfg,
        precision: KvPrecision,
        prefix_cache: bool,
    ) -> Self {
        KvManager {
            cache: PagedKvCache::new_with_prefix(&cfg, precision, prefix_cache),
            slots: vec![Slot::Free; cfg.decode_batch],
            cfg,
        }
    }

    /// The paged storage behind the slots (fused-dequant gather surface
    /// and block-table introspection).
    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }

    /// Mutable cache access (chaos injection and prefix maintenance).
    pub fn cache_mut(&mut self) -> &mut PagedKvCache {
        &mut self.cache
    }

    /// Whether the prompt-prefix index is enabled on the cache.
    pub fn prefix_enabled(&self) -> bool {
        self.cache.prefix_enabled()
    }

    /// Claim `slot` for `request` and serve as much of `prompt` as the
    /// prefix index holds by aliasing shared blocks (at most `plen - 1`
    /// tokens, so the tail always computes logits). The slot comes up
    /// `Active` at the matched position; the paged-prefill path then
    /// appends the remaining tokens. With the index disabled this just
    /// claims the slot at position 0.
    pub fn admit_prefix(
        &mut self,
        slot: usize,
        request: RequestId,
        prompt: &[i32],
        plen: usize,
    ) -> Result<PrefixMatch, String> {
        if self.slots[slot] != Slot::Free {
            return Err(format!("slot {slot} not free"));
        }
        if plen == 0 || plen > self.cfg.seq_len {
            return Err(format!("prompt_len {plen} out of range"));
        }
        let m = self.cache.admit_prefix(slot, prompt, plen - 1);
        self.slots[slot] = Slot::Active { request, pos: m.tokens };
        Ok(m)
    }

    /// Claim `slot` as a *full alias* of an already-indexed identical
    /// prompt (intra-burst duplicate dedup): unlike [`Self::admit_prefix`]
    /// the match is allowed to cover every one of `plen` tokens — the
    /// duplicate reuses its twin's logits instead of recomputing a tail,
    /// so no uncovered position is needed. Returns `Ok(true)` with the
    /// slot `Active` at `plen` when the index served the whole prompt;
    /// on a partial match (the twin was evicted or never registered) the
    /// aliased blocks are returned and the slot stays `Free` —
    /// `Ok(false)` tells the caller to fall back to a real prefill.
    pub fn admit_duplicate(
        &mut self,
        slot: usize,
        request: RequestId,
        prompt: &[i32],
        plen: usize,
    ) -> Result<bool, String> {
        if self.slots[slot] != Slot::Free {
            return Err(format!("slot {slot} not free"));
        }
        if plen == 0 || plen > self.cfg.seq_len || plen > prompt.len() {
            return Err(format!("prompt_len {plen} out of range"));
        }
        let m = self.cache.admit_prefix(slot, prompt, plen);
        if m.tokens == plen {
            self.slots[slot] = Slot::Active { request, pos: plen };
            Ok(true)
        } else {
            // partial coverage is useless to a duplicate (its logits come
            // from the twin): hand the aliased blocks straight back
            self.cache.release(slot);
            Ok(false)
        }
    }

    /// Set an active slot's position: the paged-prefill path has written
    /// `new_pos` tokens. Under the chunked scheduler this is the prefill
    /// *cursor* — a slot stays `Active` mid-prompt across engine steps,
    /// each chunk advancing it, until the final chunk lands at the full
    /// (clamped) prompt length. Aliased prefix blocks stay pinned for the
    /// whole span and COW fires normally if a chunk appends into a shared
    /// block; `release` mid-prefill reclaims everything.
    pub fn set_position(&mut self, slot: usize, new_pos: usize) -> Result<(), String> {
        match &mut self.slots[slot] {
            Slot::Active { pos, .. } => {
                *pos = new_pos;
                Ok(())
            }
            Slot::Free => Err(format!("set_position on free slot {slot}")),
        }
    }

    /// Register the slot's prefilled prompt prefix in the prefix index
    /// (no-op when disabled).
    pub fn register_prefix(&mut self, slot: usize, tokens: &[i32]) {
        self.cache.register_prefix(slot, tokens);
    }

    /// Stored bits per cache element (32 = FP32).
    pub fn bits(&self) -> u32 {
        self.cache.bits()
    }

    pub fn kv_shape(&self) -> Vec<usize> {
        vec![
            self.cfg.n_layers,
            self.cfg.decode_batch,
            self.cfg.n_heads,
            self.cfg.seq_len,
            self.cfg.head_dim,
        ]
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| *s == Slot::Free)
    }

    pub fn active_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s, Slot::Active { .. }))
            .count()
    }

    /// Install a prefilled (L, 1, H, S, hd) cache pair into `slot`: only
    /// positions `0..prompt_len` are read (and quantized, for n-bit
    /// storage) — the tail of the dense tensors is ignored.
    pub fn install_prefill(
        &mut self,
        slot: usize,
        request: RequestId,
        prompt_len: usize,
        kc: &HostTensor,
        vc: &HostTensor,
    ) -> Result<(), String> {
        if self.slots[slot] != Slot::Free {
            return Err(format!("slot {slot} not free"));
        }
        if prompt_len == 0 || prompt_len > self.cfg.seq_len {
            return Err(format!("prompt_len {prompt_len} out of range"));
        }
        let (kc, vc) = (
            kc.as_f32().map_err(|e| e.to_string())?,
            vc.as_f32().map_err(|e| e.to_string())?,
        );
        let (h, hd, s) = (self.cfg.n_heads, self.cfg.head_dim, self.cfg.seq_len);
        if kc.len() != self.cfg.n_layers * h * s * hd || vc.len() != kc.len() {
            return Err("prefill kv size mismatch".into());
        }
        let mut krow = vec![0f32; h * hd];
        let mut vrow = vec![0f32; h * hd];
        for l in 0..self.cfg.n_layers {
            for t in 0..prompt_len {
                for head in 0..h {
                    let src = (l * h + head) * s * hd + t * hd;
                    krow[head * hd..(head + 1) * hd].copy_from_slice(&kc[src..src + hd]);
                    vrow[head * hd..(head + 1) * hd].copy_from_slice(&vc[src..src + hd]);
                }
                self.cache.append(l, slot, t, &krow, &vrow)?;
            }
        }
        self.slots[slot] = Slot::Active { request, pos: prompt_len };
        Ok(())
    }

    /// Scatter a decode step's output caches into the paged store: only
    /// each *active* slot's row at its write position `pos[slot]` is read
    /// from the dense (L, B, H, S, hd) tensors — every other region is
    /// ignored, so untouched slots are preserved verbatim (the step
    /// artifact passes them through unchanged).
    pub fn update_from_step(
        &mut self,
        kc: &HostTensor,
        vc: &HostTensor,
        pos: &[i32],
        active: &[bool],
    ) -> Result<(), String> {
        let k = kc.as_f32().map_err(|e| e.to_string())?;
        let v = vc.as_f32().map_err(|e| e.to_string())?;
        let (b, h, hd, s) = (
            self.cfg.decode_batch,
            self.cfg.n_heads,
            self.cfg.head_dim,
            self.cfg.seq_len,
        );
        if k.len() != self.cfg.n_layers * b * h * s * hd || v.len() != k.len() {
            return Err("kv size mismatch".into());
        }
        if pos.len() != b || active.len() != b {
            return Err("kv slot arity mismatch".into());
        }
        let mut krow = vec![0f32; h * hd];
        let mut vrow = vec![0f32; h * hd];
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let p = pos[slot] as usize;
            if p >= s {
                return Err(format!("step pos {p} beyond context {s}"));
            }
            for l in 0..self.cfg.n_layers {
                for head in 0..h {
                    let src = ((l * b + slot) * h + head) * s * hd + p * hd;
                    krow[head * hd..(head + 1) * hd].copy_from_slice(&k[src..src + hd]);
                    vrow[head * hd..(head + 1) * hd].copy_from_slice(&v[src..src + hd]);
                }
                self.cache.append(l, slot, p, &krow, &vrow)?;
            }
        }
        Ok(())
    }

    /// Append one token's K/V rows (head-major, length H * hd each) for
    /// `(layer, slot)` at cache position `pos` — the native backend's
    /// in-place quantizing write path.
    pub fn append_token(
        &mut self,
        layer: usize,
        slot: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) -> Result<(), String> {
        self.cache.append(layer, slot, pos, k_row, v_row)
    }

    /// Fused-dequant key gather through the slot's block table (see
    /// [`PagedKvCache::key_scores`]).
    pub fn key_scores(
        &self,
        layer: usize,
        slot: usize,
        head: usize,
        n: usize,
        q: &[f32],
        scores: &mut [f32],
    ) {
        self.cache.key_scores(layer, slot, head, n, q, scores)
    }

    /// Fused-dequant value mix through the slot's block table (see
    /// [`PagedKvCache::value_mix`]).
    pub fn value_mix(
        &self,
        layer: usize,
        slot: usize,
        head: usize,
        n: usize,
        w: &[f32],
        out: &mut [f32],
    ) {
        self.cache.value_mix(layer, slot, head, n, w, out)
    }

    /// Roll an active slot back to `new_len` written positions (the
    /// speculative-decode rejection path): truncates the paged storage —
    /// reference-dropping only, COW-safe for shared prefix blocks, see
    /// [`PagedKvCache::truncate`] — and rewinds the slot position to
    /// match, so the next append lands at `new_len`.
    pub fn truncate(&mut self, slot: usize, new_len: usize) -> Result<(), String> {
        match self.slots[slot] {
            Slot::Active { .. } => {
                self.cache.truncate(slot, new_len)?;
                self.set_position(slot, new_len)
            }
            Slot::Free => Err(format!("truncate on free slot {slot}")),
        }
    }

    pub fn advance(&mut self, slot: usize) -> Result<usize, String> {
        match &mut self.slots[slot] {
            Slot::Active { pos, .. } => {
                *pos += 1;
                Ok(*pos)
            }
            Slot::Free => Err(format!("advance on free slot {slot}")),
        }
    }

    pub fn position(&self, slot: usize) -> Option<usize> {
        match self.slots[slot] {
            Slot::Active { pos, .. } => Some(pos),
            Slot::Free => None,
        }
    }

    pub fn request_of(&self, slot: usize) -> Option<RequestId> {
        match self.slots[slot] {
            Slot::Active { request, .. } => Some(request),
            Slot::Free => None,
        }
    }

    /// Slot is out of context space (pos at the last cache line).
    pub fn exhausted(&self, slot: usize) -> bool {
        self.position(slot)
            .map(|p| p >= self.cfg.seq_len - 1)
            .unwrap_or(false)
    }

    /// Free the slot and return its blocks to the pool — copy-free: no
    /// zero-fill. Stale keys still can't leak into the next request:
    /// reads are bounded by written counts, which reset to zero here, and
    /// dense materialization emits zeros for unmapped positions.
    pub fn release(&mut self, slot: usize) {
        self.slots[slot] = Slot::Free;
        self.cache.release(slot);
    }

    /// Peak reserved cache bytes (lazy pool growth: reflects real usage).
    pub fn peak_cache_bytes(&self) -> usize {
        self.cache.peak_bytes()
    }

    /// Ideal storage bytes per token position (all layers, K + V).
    pub fn bytes_per_token(&self) -> f64 {
        self.cache.bytes_per_token()
    }

    /// Materialize both dense (L, B, H, S, hd) cache tensors in one pass
    /// — the PJRT artifact contract (callers needing both should use this
    /// rather than `k_tensor()` + `v_tensor()`, which would walk and
    /// dequantize the whole cache twice).
    pub fn dense_tensors(&self) -> (HostTensor, HostTensor) {
        let shape = self.kv_shape();
        let total: usize = shape.iter().product();
        let mut k = vec![0f32; total];
        let mut v = vec![0f32; total];
        self.cache.fill_dense(&mut k, &mut v);
        (HostTensor::f32(k, &shape), HostTensor::f32(v, &shape))
    }

    pub fn k_tensor(&self) -> HostTensor {
        self.dense_tensors().0
    }

    pub fn v_tensor(&self) -> HostTensor {
        self.dense_tensors().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            seq_len: 32,
            batch: 2,
            decode_batch: 2,
            head_dim: 16,
            d_ff: 256,
            n_linears: 8,
        }
    }

    fn prefill_pair(c: &ModelCfg, fill: f32) -> (HostTensor, HostTensor) {
        let shape = [c.n_layers, 1, c.n_heads, c.seq_len, c.head_dim];
        let n: usize = shape.iter().product();
        (
            HostTensor::f32(vec![fill; n], &shape),
            HostTensor::f32(vec![-fill; n], &shape),
        )
    }

    fn dense_k(kv: &KvManager) -> Vec<f32> {
        kv.k_tensor().as_f32().unwrap().to_vec()
    }

    #[test]
    fn slot_lifecycle() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        assert_eq!(kv.free_slot(), Some(0));
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(0, 77, 5, &kc, &vc).unwrap();
        assert_eq!(kv.position(0), Some(5));
        assert_eq!(kv.request_of(0), Some(77));
        assert_eq!(kv.free_slot(), Some(1));
        assert_eq!(kv.advance(0).unwrap(), 6);
        kv.release(0);
        assert_eq!(kv.free_slot(), Some(0));
        // stale-key-leak guard: a released slot materializes as zeros
        // (blocks are unmapped, not zero-filled — release is copy-free)
        assert!(dense_k(&kv).iter().all(|&x| x == 0.0));
        assert_eq!(kv.cache().in_use_blocks(), 0);
    }

    #[test]
    fn install_into_occupied_fails() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(1, 1, 3, &kc, &vc).unwrap();
        assert!(kv.install_prefill(1, 2, 3, &kc, &vc).is_err());
    }

    #[test]
    fn prefill_lands_in_right_slot_region() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 2.5);
        kv.install_prefill(1, 9, 4, &kc, &vc).unwrap();
        let k = dense_k(&kv);
        let per_slot = c.n_heads * c.seq_len * c.head_dim;
        // slot 0 region still zero, slot 1 filled at positions 0..4 only
        assert!(k[..per_slot].iter().all(|&x| x == 0.0));
        let slot1 = &k[per_slot..2 * per_slot];
        for head in 0..c.n_heads {
            for t in 0..c.seq_len {
                let off = (head * c.seq_len + t) * c.head_dim;
                let want = if t < 4 { 2.5 } else { 0.0 };
                assert!(
                    slot1[off..off + c.head_dim].iter().all(|&x| x == want),
                    "head {head} pos {t}"
                );
            }
        }
    }

    #[test]
    fn update_from_step_writes_only_active_slots_new_position() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(0, 1, 3, &kc, &vc).unwrap();
        let (kc2, vc2) = prefill_pair(&c, 4.0);
        kv.install_prefill(1, 2, 5, &kc2, &vc2).unwrap();
        let before = dense_k(&kv);

        // a step tensor full of marker values; only slot 0 is active at
        // position 3, so exactly one (H, hd) row per layer may change
        let shape = kv.kv_shape();
        let n: usize = shape.iter().product();
        let step_k = HostTensor::f32(vec![9.0; n], &shape);
        let step_v = HostTensor::f32(vec![-9.0; n], &shape);
        kv.update_from_step(&step_k, &step_v, &[3, 0], &[true, false]).unwrap();

        let after = dense_k(&kv);
        let (h, hd, s) = (c.n_heads, c.head_dim, c.seq_len);
        let mut changed = 0usize;
        for (i, (b, a)) in before.iter().zip(&after).enumerate() {
            if b != a {
                changed += 1;
                // decompose the dense index: (l, slot, head, t, ch)
                let t = (i / hd) % s;
                let slot = (i / (h * s * hd)) % c.decode_batch;
                assert_eq!(slot, 0, "inactive slot region modified at {i}");
                assert_eq!(t, 3, "wrong position written at {i}");
                assert_eq!(*a, 9.0);
            }
        }
        assert_eq!(changed, c.n_layers * h * hd, "exactly one row per layer");
    }

    #[test]
    fn update_from_step_rejects_bad_shapes() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let bad = HostTensor::f32(vec![0.0; 8], &[8]);
        assert!(kv.update_from_step(&bad, &bad, &[0, 0], &[false, false]).is_err());
        let shape = kv.kv_shape();
        let n: usize = shape.iter().product();
        let ok = HostTensor::f32(vec![0.0; n], &shape);
        assert!(kv.update_from_step(&ok, &ok, &[0], &[false]).is_err(), "arity");
        // inactive slots are skipped entirely, so garbage pos is fine there
        assert!(kv
            .update_from_step(&ok, &ok, &[1 << 20, 0], &[false, false])
            .is_ok());
    }

    #[test]
    fn truncate_rewinds_position_and_storage_together() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(0, 7, 20, &kc, &vc).unwrap();
        assert!(kv.truncate(1, 3).is_err(), "free slot");
        assert!(kv.truncate(0, 21).is_err(), "beyond written");
        kv.truncate(0, 17).unwrap();
        assert_eq!(kv.position(0), Some(17));
        for l in 0..c.n_layers {
            assert_eq!(kv.cache().written(l, 0), 17);
        }
        // the append protocol resumes exactly at the rollback point
        let d = c.n_heads * c.head_dim;
        let row = vec![0.5f32; d];
        for l in 0..c.n_layers {
            kv.append_token(l, 0, 17, &row, &row).unwrap();
        }
        assert_eq!(kv.advance(0).unwrap(), 18);
        kv.release(0);
        assert_eq!(kv.cache().in_use_blocks(), 0);
    }

    #[test]
    fn exhaustion_boundary() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(0, 1, c.seq_len - 2, &kc, &vc).unwrap();
        assert!(!kv.exhausted(0));
        kv.advance(0).unwrap();
        assert!(kv.exhausted(0));
    }

    #[test]
    fn bad_prompt_len_rejected() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        assert!(kv.install_prefill(0, 1, 0, &kc, &vc).is_err());
        assert!(kv.install_prefill(0, 1, c.seq_len + 1, &kc, &vc).is_err());
    }

    /// Chunked-scheduler contract: a slot claimed by `admit_prefix` stays
    /// `Active` at its cursor between chunks, `set_position` advances it,
    /// appends resume exactly where the previous chunk stopped, and a
    /// mid-prefill `release` returns every partial block to the pool.
    #[test]
    fn mid_prefill_cursor_survives_across_chunks() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let prompt: Vec<i32> = (0..20).collect();
        let m = kv.admit_prefix(0, 42, &prompt, 20).unwrap();
        assert_eq!(m.tokens, 0, "no index: slot claimed cold");
        assert_eq!(kv.position(0), Some(0));
        let d = c.n_heads * c.head_dim;
        let row = vec![0.5f32; d];
        // chunk 1: rows 0..8
        for l in 0..c.n_layers {
            for p in 0..8 {
                kv.append_token(l, 0, p, &row, &row).unwrap();
            }
        }
        kv.set_position(0, 8).unwrap();
        assert_eq!(kv.position(0), Some(8), "cursor survives between chunks");
        assert_eq!(kv.request_of(0), Some(42), "slot still owned mid-prefill");
        assert_eq!(kv.free_slot(), Some(1), "mid-prefill slot is not free");
        // chunk 2 resumes exactly at the cursor
        for l in 0..c.n_layers {
            for p in 8..20 {
                kv.append_token(l, 0, p, &row, &row).unwrap();
            }
        }
        kv.set_position(0, 20).unwrap();
        for l in 0..c.n_layers {
            assert_eq!(kv.cache().written(l, 0), 20);
        }
        // a second slot released mid-prefill reclaims its partial blocks
        kv.admit_prefix(1, 43, &prompt, 20).unwrap();
        for l in 0..c.n_layers {
            for p in 0..5 {
                kv.append_token(l, 1, p, &row, &row).unwrap();
            }
        }
        kv.set_position(1, 5).unwrap();
        let used = kv.cache().in_use_blocks();
        kv.release(1);
        assert!(kv.cache().in_use_blocks() < used, "partial blocks reclaimed");
        kv.release(0);
        assert_eq!(kv.cache().in_use_blocks(), 0, "zero leaked blocks");
    }
}
