//! KV-cache slot manager: the decode artifact is lowered for a fixed slot
//! count B and max context S; this module owns the host-side cache tensors
//! and the slot lifecycle (free -> prefilled -> decoding -> free). Slot
//! state is the coordinator invariant most heavily property-tested (no
//! leaks, no double-assignments, position bounds).

use crate::runtime::artifacts::ModelCfg;
use crate::runtime::HostTensor;

use super::request::RequestId;

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Slot {
    Free,
    Active { request: RequestId, pos: usize },
}

pub struct KvManager {
    pub cfg: ModelCfg,
    /// (L, B, H, S, hd) host caches
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub slots: Vec<Slot>,
    /// elements per (layer, slot) block: H * S * hd
    per_slot: usize,
    per_layer: usize,
}

impl KvManager {
    pub fn new(cfg: ModelCfg) -> Self {
        let per_slot = cfg.n_heads * cfg.seq_len * cfg.head_dim;
        let per_layer = cfg.decode_batch * per_slot;
        let total = cfg.n_layers * per_layer;
        KvManager {
            cfg,
            k: vec![0.0; total],
            v: vec![0.0; total],
            slots: vec![Slot::Free; cfg.decode_batch],
            per_slot,
            per_layer,
        }
    }

    pub fn kv_shape(&self) -> Vec<usize> {
        vec![
            self.cfg.n_layers,
            self.cfg.decode_batch,
            self.cfg.n_heads,
            self.cfg.seq_len,
            self.cfg.head_dim,
        ]
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| *s == Slot::Free)
    }

    pub fn active_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| matches!(s, Slot::Active { .. }))
            .count()
    }

    /// Install a prefilled (L, 1, H, S, hd) cache pair into `slot`.
    pub fn install_prefill(
        &mut self,
        slot: usize,
        request: RequestId,
        prompt_len: usize,
        kc: &HostTensor,
        vc: &HostTensor,
    ) -> Result<(), String> {
        if self.slots[slot] != Slot::Free {
            return Err(format!("slot {slot} not free"));
        }
        if prompt_len == 0 || prompt_len > self.cfg.seq_len {
            return Err(format!("prompt_len {prompt_len} out of range"));
        }
        let (kc, vc) = (
            kc.as_f32().map_err(|e| e.to_string())?,
            vc.as_f32().map_err(|e| e.to_string())?,
        );
        for l in 0..self.cfg.n_layers {
            let src = &kc[l * self.per_slot..(l + 1) * self.per_slot];
            let dst_off = l * self.per_layer + slot * self.per_slot;
            self.k[dst_off..dst_off + self.per_slot].copy_from_slice(src);
            let src = &vc[l * self.per_slot..(l + 1) * self.per_slot];
            self.v[dst_off..dst_off + self.per_slot].copy_from_slice(src);
        }
        self.slots[slot] = Slot::Active { request, pos: prompt_len };
        Ok(())
    }

    /// Replace the whole cache pair from a decode_step output.
    pub fn update_from_step(&mut self, kc: &HostTensor, vc: &HostTensor) -> Result<(), String> {
        let k = kc.as_f32().map_err(|e| e.to_string())?;
        let v = vc.as_f32().map_err(|e| e.to_string())?;
        if k.len() != self.k.len() || v.len() != self.v.len() {
            return Err("kv size mismatch".into());
        }
        self.k.copy_from_slice(k);
        self.v.copy_from_slice(v);
        Ok(())
    }

    pub fn advance(&mut self, slot: usize) -> Result<usize, String> {
        match &mut self.slots[slot] {
            Slot::Active { pos, .. } => {
                *pos += 1;
                Ok(*pos)
            }
            Slot::Free => Err(format!("advance on free slot {slot}")),
        }
    }

    pub fn position(&self, slot: usize) -> Option<usize> {
        match self.slots[slot] {
            Slot::Active { pos, .. } => Some(pos),
            Slot::Free => None,
        }
    }

    pub fn request_of(&self, slot: usize) -> Option<RequestId> {
        match self.slots[slot] {
            Slot::Active { request, .. } => Some(request),
            Slot::Free => None,
        }
    }

    /// Slot is out of context space (pos at the last cache line).
    pub fn exhausted(&self, slot: usize) -> bool {
        self.position(slot)
            .map(|p| p >= self.cfg.seq_len - 1)
            .unwrap_or(false)
    }

    pub fn release(&mut self, slot: usize) {
        self.slots[slot] = Slot::Free;
        // zero the slot's cache region so stale keys can't leak into the
        // next request via nonzero garbage at masked positions
        for l in 0..self.cfg.n_layers {
            let off = l * self.per_layer + slot * self.per_slot;
            self.k[off..off + self.per_slot].fill(0.0);
            self.v[off..off + self.per_slot].fill(0.0);
        }
    }

    pub fn k_tensor(&self) -> HostTensor {
        HostTensor::f32(self.k.clone(), &self.kv_shape())
    }

    pub fn v_tensor(&self) -> HostTensor {
        HostTensor::f32(self.v.clone(), &self.kv_shape())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelCfg {
        ModelCfg {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            seq_len: 32,
            batch: 2,
            decode_batch: 2,
            head_dim: 16,
            d_ff: 256,
            n_linears: 8,
        }
    }

    fn prefill_pair(c: &ModelCfg, fill: f32) -> (HostTensor, HostTensor) {
        let shape = [c.n_layers, 1, c.n_heads, c.seq_len, c.head_dim];
        let n: usize = shape.iter().product();
        (
            HostTensor::f32(vec![fill; n], &shape),
            HostTensor::f32(vec![-fill; n], &shape),
        )
    }

    #[test]
    fn slot_lifecycle() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        assert_eq!(kv.free_slot(), Some(0));
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(0, 77, 5, &kc, &vc).unwrap();
        assert_eq!(kv.position(0), Some(5));
        assert_eq!(kv.request_of(0), Some(77));
        assert_eq!(kv.free_slot(), Some(1));
        assert_eq!(kv.advance(0).unwrap(), 6);
        kv.release(0);
        assert_eq!(kv.free_slot(), Some(0));
        assert!(kv.k.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn install_into_occupied_fails() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(1, 1, 3, &kc, &vc).unwrap();
        assert!(kv.install_prefill(1, 2, 3, &kc, &vc).is_err());
    }

    #[test]
    fn prefill_lands_in_right_slot_region() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 2.5);
        kv.install_prefill(1, 9, 4, &kc, &vc).unwrap();
        let per_slot = c.n_heads * c.seq_len * c.head_dim;
        // slot 0 region still zero, slot 1 region filled
        assert!(kv.k[..per_slot].iter().all(|&x| x == 0.0));
        assert!(kv.k[per_slot..2 * per_slot].iter().all(|&x| x == 2.5));
    }

    #[test]
    fn exhaustion_boundary() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        kv.install_prefill(0, 1, c.seq_len - 2, &kc, &vc).unwrap();
        assert!(!kv.exhausted(0));
        kv.advance(0).unwrap();
        assert!(kv.exhausted(0));
    }

    #[test]
    fn bad_prompt_len_rejected() {
        let c = cfg();
        let mut kv = KvManager::new(c);
        let (kc, vc) = prefill_pair(&c, 1.0);
        assert!(kv.install_prefill(0, 1, 0, &kc, &vc).is_err());
        assert!(kv.install_prefill(0, 1, c.seq_len + 1, &kc, &vc).is_err());
    }
}
