//! 1-D (weighted) K-Means — the paper's learned-codebook quantizer (eq. 1).
//!
//! Centroids are learned by Lloyd iterations over sorted samples with
//! quantile initialization; the weighted variant implements the paper's
//! Fisher-information-weighted activation-centroid learning (§V-A:
//! "weighted-K-Means algorithm ... where the weights are determined by
//! Fisher information matrices of the activations").

use crate::util::rng::Rng;

/// Learn `k` centroids from samples. Returns sorted centroids.
pub fn kmeans_1d(samples: &[f32], k: usize, iters: usize) -> Vec<f32> {
    weighted_kmeans_1d(samples, None, k, iters)
}

/// Weighted 1-D K-Means; `weights` (same length as samples) biases both the
/// assignment objective's update step (weighted mean) — high-Fisher values
/// pull centroids toward themselves, matching SqueezeLLM-style sensitivity.
pub fn weighted_kmeans_1d(
    samples: &[f32],
    weights: Option<&[f32]>,
    k: usize,
    iters: usize,
) -> Vec<f32> {
    assert!(k >= 1, "k must be >= 1");
    assert!(!samples.is_empty(), "empty sample set");
    if let Some(w) = weights {
        assert_eq!(w.len(), samples.len(), "weights length mismatch");
    }

    // Sort samples (carrying weights) — 1-D clusters are contiguous runs,
    // so assignment reduces to boundary binary search.
    let mut idx: Vec<u32> = (0..samples.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        samples[a as usize]
            .partial_cmp(&samples[b as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let xs: Vec<f32> = idx.iter().map(|&i| samples[i as usize]).collect();
    let ws: Vec<f32> = match weights {
        Some(w) => idx.iter().map(|&i| w[i as usize].max(0.0)).collect(),
        None => vec![1.0; xs.len()],
    };

    let mut centroids = quantile_init(&xs, k);
    // Degenerate data (all values equal) — centroids collapse, still valid.
    for _ in 0..iters {
        let moved = lloyd_step(&xs, &ws, &mut centroids);
        if moved < 1e-7 {
            break;
        }
    }
    dedup_monotone(&mut centroids);
    centroids
}

/// Initialize at weighted-rank quantiles (robust and deterministic; the
/// kmeans++ randomized alternative below is used by property tests to
/// confirm insensitivity to initialization).
fn quantile_init(sorted_xs: &[f32], k: usize) -> Vec<f32> {
    let n = sorted_xs.len();
    (0..k)
        .map(|i| {
            let q = (i as f64 + 0.5) / k as f64;
            sorted_xs[((q * n as f64) as usize).min(n - 1)]
        })
        .collect()
}

/// One Lloyd iteration over sorted data; returns total centroid movement.
fn lloyd_step(xs: &[f32], ws: &[f32], centroids: &mut [f32]) -> f32 {
    let k = centroids.len();
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // cluster c owns xs in [bound[c-1], bound[c])
    let mut sums = vec![0.0f64; k];
    let mut wsum = vec![0.0f64; k];
    let mut c = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        while c + 1 < k && x > 0.5 * (centroids[c] + centroids[c + 1]) {
            c += 1;
        }
        sums[c] += (x as f64) * (ws[i] as f64);
        wsum[c] += ws[i] as f64;
    }
    let mut moved = 0.0f32;
    for j in 0..k {
        if wsum[j] > 0.0 {
            let nc = (sums[j] / wsum[j]) as f32;
            moved += (nc - centroids[j]).abs();
            centroids[j] = nc;
        }
        // empty clusters keep their position (will re-acquire points as
        // neighbors move)
    }
    moved
}

/// Ensure strictly non-decreasing centroids (numerical safety for the
/// boundary-based Clustering Unit).
fn dedup_monotone(centroids: &mut [f32]) {
    centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for i in 1..centroids.len() {
        if centroids[i] < centroids[i - 1] {
            centroids[i] = centroids[i - 1];
        }
    }
}

/// kmeans++-style randomized init + Lloyd, for property tests.
pub fn kmeans_1d_pp(samples: &[f32], k: usize, iters: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(!samples.is_empty() && k >= 1);
    let mut centroids = Vec::with_capacity(k);
    centroids.push(*rng.choice(samples));
    while centroids.len() < k {
        // sample proportional to squared distance to the nearest centroid
        let d2: Vec<f64> = samples
            .iter()
            .map(|&x| {
                centroids
                    .iter()
                    .map(|&c| ((x - c) as f64).powi(2))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            centroids.push(samples[0]);
            continue;
        }
        let mut u = rng.f64() * total;
        let mut pick = samples.len() - 1;
        for (i, &d) in d2.iter().enumerate() {
            u -= d;
            if u <= 0.0 {
                pick = i;
                break;
            }
        }
        centroids.push(samples[pick]);
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ws = vec![1.0; xs.len()];
    for _ in 0..iters {
        if lloyd_step(&xs, &ws, &mut centroids) < 1e-7 {
            break;
        }
    }
    dedup_monotone(&mut centroids);
    centroids
}

/// Weighted quantization MSE of a centroid set over samples.
pub fn quant_mse(samples: &[f32], weights: Option<&[f32]>, centroids: &[f32]) -> f64 {
    let mut err = 0.0f64;
    let mut wtot = 0.0f64;
    for (i, &x) in samples.iter().enumerate() {
        let w = weights.map(|w| w[i] as f64).unwrap_or(1.0);
        let d = centroids
            .iter()
            .map(|&c| ((x - c) as f64).powi(2))
            .fold(f64::INFINITY, f64::min);
        err += w * d;
        wtot += w;
    }
    err / wtot.max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_well_separated_clusters() {
        let mut rng = Rng::new(1);
        let mut xs = Vec::new();
        for &mu in &[-10.0f32, 0.0, 10.0, 20.0] {
            for _ in 0..500 {
                xs.push(mu + 0.1 * rng.normal_f32());
            }
        }
        let c = kmeans_1d(&xs, 4, 50);
        for (got, want) in c.iter().zip(&[-10.0f32, 0.0, 10.0, 20.0]) {
            assert!((got - want).abs() < 0.1, "{c:?}");
        }
    }

    #[test]
    fn output_is_sorted_and_right_size() {
        let mut rng = Rng::new(2);
        let xs = rng.normal_vec(4096, 1.0);
        let c = kmeans_1d(&xs, 16, 30);
        assert_eq!(c.len(), 16);
        assert!(c.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn beats_uniform_grid_on_gaussian() {
        // Non-uniform codebooks should beat a uniform grid on N(0,1) —
        // the paper's core motivation for NU quantization.
        let mut rng = Rng::new(3);
        let xs = rng.normal_vec(20_000, 1.0);
        let km = kmeans_1d(&xs, 16, 50);
        let (lo, hi) = crate::util::stats::min_max(&xs);
        let uniform: Vec<f32> = (0..16)
            .map(|i| lo + (hi - lo) * (i as f32 + 0.5) / 16.0)
            .collect();
        assert!(quant_mse(&xs, None, &km) < 0.5 * quant_mse(&xs, None, &uniform));
    }

    #[test]
    fn weights_pull_centroids() {
        // Two clumps; weighting one clump heavily should allocate it more
        // centroids (lower weighted MSE) than unweighted.
        let mut rng = Rng::new(4);
        let mut xs = Vec::new();
        for _ in 0..1000 {
            xs.push(rng.normal_f32() * 0.5);
        }
        for _ in 0..1000 {
            xs.push(8.0 + rng.normal_f32() * 0.5);
        }
        let w: Vec<f32> = (0..2000).map(|i| if i < 1000 { 100.0 } else { 0.01 }).collect();
        let cw = weighted_kmeans_1d(&xs, Some(&w), 8, 50);
        let cu = kmeans_1d(&xs, 8, 50);
        let mse_w = quant_mse(&xs, Some(&w), &cw);
        let mse_u = quant_mse(&xs, Some(&w), &cu);
        assert!(mse_w <= mse_u + 1e-9, "weighted {mse_w} vs unweighted {mse_u}");
    }

    #[test]
    fn kmeanspp_comparable_to_quantile_init() {
        let mut rng = Rng::new(5);
        let xs = rng.heavy_tailed_vec(8000, 0.02, 10.0);
        let a = kmeans_1d(&xs, 16, 40);
        let b = kmeans_1d_pp(&xs, 16, 40, &mut rng);
        let ma = quant_mse(&xs, None, &a);
        let mb = quant_mse(&xs, None, &b);
        assert!(ma < 2.0 * mb + 1e-6 && mb < 2.0 * ma + 1e-6, "{ma} vs {mb}");
    }

    #[test]
    fn degenerate_constant_data() {
        let xs = vec![3.5f32; 100];
        let c = kmeans_1d(&xs, 4, 10);
        assert_eq!(c.len(), 4);
        assert!(c.iter().all(|&v| (v - 3.5).abs() < 1e-6));
    }

    #[test]
    fn k1_is_weighted_mean() {
        let xs = vec![1.0f32, 2.0, 3.0, 4.0];
        let w = vec![1.0f32, 1.0, 1.0, 5.0];
        let c = weighted_kmeans_1d(&xs, Some(&w), 1, 5);
        let want = (1.0 + 2.0 + 3.0 + 20.0) / 8.0;
        assert!((c[0] - want).abs() < 1e-5, "{c:?} vs {want}");
    }
}
