//! Nibble-packed index storage (two 4-bit K-Means indices per byte).
//!
//! The WAQ datapath is memory-bandwidth-bound at decode, yet the plain
//! `QuantWeights`/`QuantToken` forms spend a full byte per <=4-bit index —
//! twice the traffic the quantization scheme was chosen to avoid. This
//! module provides the packed forms the fast GEMM backend
//! (`gemm::packed`) streams:
//!
//! * [`PackedIdx`] — a flat nibble stream for any index sequence
//!   (activation tokens, weight tails). Element `2i` lives in the HIGH
//!   nibble of byte `i`, element `2i+1` in the LOW nibble, so a byte reads
//!   left-to-right like the index stream it encodes.
//! * [`PackedWeights`] — the K x N weight index matrix packed along the
//!   *reduction* dimension: byte `pairs[p * n_cols + j]` holds
//!   `idx[2p][j] << 4 | idx[2p+1][j]`. Pairing along K is what lets the
//!   GEMM kernel fuse two LUT rows into one 256-entry table and do one
//!   lookup per two MACs (see `gemm::packed` for the kernel-side story).
//!   An odd final row is kept as a nibble-packed tail.
//!
//! Packing is lossless for any codebook of <= 16 centroids (<= 4 bits),
//! which covers every WAQ configuration in the paper (3- and 4-bit).

use super::codebook::Codebook;
use super::weights::QuantWeights;

/// A flat sequence of 4-bit indices, two per byte (high nibble first).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedIdx {
    /// `len.div_ceil(2)` bytes; an odd tail element occupies the high
    /// nibble of the last byte with the low nibble zeroed.
    pub bytes: Vec<u8>,
    /// logical number of indices
    pub len: usize,
}

impl PackedIdx {
    /// Pack a byte-per-index stream. Every index must fit in 4 bits —
    /// enforced with a hard assert even in release, because a wide index
    /// would bleed into its neighbor's nibble and corrupt both values
    /// (packing is a cold path; the check is one branch per pair).
    pub fn pack(idx: &[u8]) -> PackedIdx {
        let mut bytes = Vec::with_capacity(idx.len().div_ceil(2));
        let mut chunks = idx.chunks_exact(2);
        for pair in &mut chunks {
            assert!(pair[0] < 16 && pair[1] < 16, "index does not fit in a nibble");
            bytes.push((pair[0] << 4) | pair[1]);
        }
        if let &[tail] = chunks.remainder() {
            assert!(tail < 16, "index does not fit in a nibble");
            bytes.push(tail << 4);
        }
        PackedIdx { bytes, len: idx.len() }
    }

    /// Inverse of [`PackedIdx::pack`].
    pub fn unpack(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Read one logical index.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        Self::get_in(&self.bytes, i)
    }

    /// Read one logical index from any nibble-packed byte slice (the
    /// layout contract for external pools, e.g. the KV-cache store).
    #[inline]
    pub fn get_in(bytes: &[u8], i: usize) -> u8 {
        let b = bytes[i / 2];
        if i % 2 == 0 {
            b >> 4
        } else {
            b & 0x0F
        }
    }

    /// Write one logical index into a nibble-packed byte slice in place.
    #[inline]
    pub fn set_in(bytes: &mut [u8], i: usize, v: u8) {
        // hard assert even in release, for the same reason as `pack`: a
        // wide index would bleed into the neighboring nibble
        assert!(v < 16, "index does not fit in a nibble");
        let b = &mut bytes[i / 2];
        if i % 2 == 0 {
            *b = (*b & 0x0F) | (v << 4);
        } else {
            *b = (*b & 0xF0) | v;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of index storage (exactly half the unpacked stream, rounded
    /// up).
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }
}

/// A flat sequence of 2-bit indices ("crumbs"), four per byte, high-first:
/// element `4i` lives in bits 7..6 of byte `i`, element `4i+3` in bits
/// 1..0 — a byte reads left-to-right like the index stream it encodes
/// (the crumb analogue of [`PackedIdx`]). Used by the 2-bit KV-cache
/// store, where even nibble packing would waste half the stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedCrumbs {
    /// `len.div_ceil(4)` bytes; tail elements occupy the high crumbs of
    /// the last byte with unused crumbs zeroed.
    pub bytes: Vec<u8>,
    /// logical number of indices
    pub len: usize,
}

impl PackedCrumbs {
    /// Pack a byte-per-index stream. Every index must fit in 2 bits —
    /// hard assert even in release (a wide index would corrupt up to
    /// three neighbors; packing is a cold path).
    pub fn pack(idx: &[u8]) -> PackedCrumbs {
        let mut bytes = Vec::with_capacity(idx.len().div_ceil(4));
        for quad in idx.chunks(4) {
            let mut b = 0u8;
            for (i, &v) in quad.iter().enumerate() {
                assert!(v < 4, "index does not fit in a crumb");
                b |= v << (6 - 2 * i);
            }
            bytes.push(b);
        }
        PackedCrumbs { bytes, len: idx.len() }
    }

    /// Inverse of [`PackedCrumbs::pack`].
    pub fn unpack(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Read one logical index.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        Self::get_in(&self.bytes, i)
    }

    /// Read one logical index from any crumb-packed byte slice (the
    /// layout contract for external pools, e.g. the KV-cache store).
    #[inline]
    pub fn get_in(bytes: &[u8], i: usize) -> u8 {
        (bytes[i / 4] >> (6 - 2 * (i % 4))) & 0x03
    }

    /// Write one logical index into a crumb-packed byte slice in place.
    #[inline]
    pub fn set_in(bytes: &mut [u8], i: usize, v: u8) {
        // hard assert even in release, for the same reason as `pack`: a
        // wide index would corrupt up to three neighboring crumbs
        assert!(v < 4, "index does not fit in a crumb");
        let shift = 6 - 2 * (i % 4);
        let b = &mut bytes[i / 4];
        *b = (*b & !(0x03 << shift)) | (v << shift);
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of index storage (a quarter of the unpacked stream, rounded
    /// up).
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }
}

/// K-Means-quantized weights with the index matrix nibble-packed along the
/// reduction dimension — the storage format the packed/tiled GEMM backend
/// streams. Produced by [`QuantWeights::pack`]; numerically identical to
/// the unpacked form (same codebook, scales, and index values).
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub n_rows: usize, // K (reduction dim)
    pub n_cols: usize, // N (output channels)
    /// `(n_rows / 2) * n_cols` bytes, row-pair-major:
    /// `pairs[p * n_cols + j] = idx[2p][j] << 4 | idx[2p+1][j]`.
    pub pairs: Vec<u8>,
    /// The unpaired final row when `n_rows` is odd, nibble-packed along
    /// columns.
    pub tail: Option<PackedIdx>,
    pub codebook: Codebook,
    pub col_scales: Vec<f32>,
}

impl PackedWeights {
    /// Number of packed row pairs (`n_rows / 2`).
    #[inline]
    pub fn n_pairs(&self) -> usize {
        self.n_rows / 2
    }

    /// Recover the byte-per-index matrix (row-major K x N), for tests and
    /// for interop with the unpacked execution paths.
    pub fn unpack_idx(&self) -> Vec<u8> {
        let n = self.n_cols;
        let mut idx = vec![0u8; self.n_rows * n];
        for p in 0..self.n_pairs() {
            for j in 0..n {
                let b = self.pairs[p * n + j];
                idx[2 * p * n + j] = b >> 4;
                idx[(2 * p + 1) * n + j] = b & 0x0F;
            }
        }
        if let Some(tail) = &self.tail {
            let r = self.n_rows - 1;
            for j in 0..n {
                idx[r * n + j] = tail.get(j);
            }
        }
        idx
    }

    /// Dequantize one input-channel (reduction) row straight from the
    /// packed form — the per-outlier fetch of the error-compensation
    /// branch (paper §III-C2), bit-identical to
    /// `QuantWeights::dequant_row` on the unpacked form.
    pub fn dequant_row(&self, k: usize, out: &mut Vec<f32>) {
        debug_assert!(k < self.n_rows, "row {k} out of range ({})", self.n_rows);
        out.clear();
        if k == self.n_rows - 1 {
            if let Some(tail) = &self.tail {
                out.extend((0..self.n_cols).map(|j| {
                    self.codebook.value(tail.get(j)) * self.col_scales[j]
                }));
                return;
            }
        }
        let row = &self.pairs[(k / 2) * self.n_cols..(k / 2 + 1) * self.n_cols];
        let nibble = move |b: u8| if k % 2 == 0 { b >> 4 } else { b & 0x0F };
        out.extend(
            row.iter()
                .zip(&self.col_scales)
                .map(|(&b, &s)| self.codebook.value(nibble(b)) * s),
        );
    }

    /// Index-storage bytes: half of the byte-per-index form (plus a
    /// rounded-up tail row when K is odd).
    pub fn index_bytes(&self) -> usize {
        self.pairs.len() + self.tail.as_ref().map_or(0, |t| t.storage_bytes())
    }

    /// Slice out output columns `[j0, j1)` as a standalone packed matrix —
    /// the load-time column partitioner of the tensor-parallel sharded
    /// backend (`gemm::sharded`). Row-pair packing is preserved (pair rows
    /// are copied byte-for-byte), the tail row is re-packed from logical
    /// values so shard boundaries need not be nibble-aligned, and the
    /// codebook + per-column scales are partitioned with the slice, so
    /// every per-column value (GEMM accumulation, `dequant_row`) is
    /// bit-identical to the same column of the full matrix.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> PackedWeights {
        assert!(j0 < j1 && j1 <= self.n_cols, "bad column range {j0}..{j1}");
        let width = j1 - j0;
        let mut pairs = Vec::with_capacity(self.n_pairs() * width);
        for p in 0..self.n_pairs() {
            pairs.extend_from_slice(&self.pairs[p * self.n_cols + j0..p * self.n_cols + j1]);
        }
        let tail = self.tail.as_ref().map(|t| {
            let vals: Vec<u8> = (j0..j1).map(|j| t.get(j)).collect();
            PackedIdx::pack(&vals)
        });
        PackedWeights {
            n_rows: self.n_rows,
            n_cols: width,
            pairs,
            tail,
            codebook: self.codebook.clone(),
            col_scales: self.col_scales[j0..j1].to_vec(),
        }
    }

    /// Total storage: packed indices + FP16 codebook + FP16 scales. Note
    /// the index term is one *nibble* per element regardless of codebook
    /// bits — it equals `QuantWeights::storage_bytes` (which counts
    /// bit-level packing) only for 4-bit codebooks; a 3-bit codebook costs
    /// 4/3x the bit-minimal figure in exchange for byte-aligned streaming.
    pub fn storage_bytes(&self) -> usize {
        self.index_bytes() + self.codebook.len() * 2 + self.col_scales.len() * 2
    }
}

/// K-Means-quantized weights with a <= 2-bit codebook, the index matrix
/// crumb-packed FOUR reduction rows per byte — the storage format the
/// crumb GEMM kernel (`gemm::packed::execute_batch_tiled_crumbs`) streams
/// for the 2-bit speculative draft model. Index traffic is half of the
/// nibble-packed [`PackedWeights`] form and a quarter of the
/// byte-per-index form; numerics are identical (same codebook, scales,
/// and index values).
#[derive(Clone, Debug)]
pub struct CrumbWeights {
    pub n_rows: usize, // K (reduction dim)
    pub n_cols: usize, // N (output channels)
    /// `(n_rows / 4) * n_cols` bytes, row-quad-major:
    /// `quads[q * n_cols + j] = idx[4q][j] << 6 | idx[4q+1][j] << 4 |
    /// idx[4q+2][j] << 2 | idx[4q+3][j]` (row `4q` in the top crumb).
    pub quads: Vec<u8>,
    /// The `n_rows % 4` unquaddable final rows, each crumb-packed along
    /// columns.
    pub tail: Vec<PackedCrumbs>,
    pub codebook: Codebook,
    pub col_scales: Vec<f32>,
}

impl CrumbWeights {
    /// Number of packed row quads (`n_rows / 4`).
    #[inline]
    pub fn n_quads(&self) -> usize {
        self.n_rows / 4
    }

    /// Recover the byte-per-index matrix (row-major K x N), for tests and
    /// for interop with the unpacked execution paths.
    pub fn unpack_idx(&self) -> Vec<u8> {
        let n = self.n_cols;
        let mut idx = vec![0u8; self.n_rows * n];
        for q in 0..self.n_quads() {
            for j in 0..n {
                let b = self.quads[q * n + j];
                for r in 0..4 {
                    idx[(4 * q + r) * n + j] = (b >> (6 - 2 * r)) & 0x03;
                }
            }
        }
        for (t, row) in self.tail.iter().enumerate() {
            let r = 4 * self.n_quads() + t;
            for j in 0..n {
                idx[r * n + j] = row.get(j);
            }
        }
        idx
    }

    /// Dequantize one input-channel (reduction) row straight from the
    /// packed form — the per-outlier fetch of the error-compensation
    /// branch, bit-identical to `QuantWeights::dequant_row` on the
    /// unpacked form.
    pub fn dequant_row(&self, k: usize, out: &mut Vec<f32>) {
        debug_assert!(k < self.n_rows, "row {k} out of range ({})", self.n_rows);
        out.clear();
        let nq = self.n_quads();
        if k >= 4 * nq {
            let row = &self.tail[k - 4 * nq];
            out.extend(
                (0..self.n_cols).map(|j| self.codebook.value(row.get(j)) * self.col_scales[j]),
            );
            return;
        }
        let row = &self.quads[(k / 4) * self.n_cols..(k / 4 + 1) * self.n_cols];
        let shift = 6 - 2 * (k % 4);
        out.extend(
            row.iter()
                .zip(&self.col_scales)
                .map(|(&b, &s)| self.codebook.value((b >> shift) & 0x03) * s),
        );
    }

    /// Index-storage bytes: a quarter of the byte-per-index form (plus
    /// rounded-up tail rows when K is not a multiple of 4).
    pub fn index_bytes(&self) -> usize {
        self.quads.len() + self.tail.iter().map(|t| t.storage_bytes()).sum::<usize>()
    }

    /// Total storage: packed indices + FP16 codebook + FP16 scales (the
    /// same accounting convention as [`PackedWeights::storage_bytes`]).
    pub fn storage_bytes(&self) -> usize {
        self.index_bytes() + self.codebook.len() * 2 + self.col_scales.len() * 2
    }

    /// Slice out output columns `[j0, j1)` as a standalone crumb-packed
    /// matrix — the load-time column partitioner for the tensor-parallel
    /// sharded backend, mirroring [`PackedWeights::slice_cols`]. Quad rows
    /// are copied byte-for-byte (crumb packing runs along K inside a
    /// byte, so columns stay independent bytes); tail rows are re-packed
    /// from logical values.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> CrumbWeights {
        assert!(j0 < j1 && j1 <= self.n_cols, "bad column range {j0}..{j1}");
        let width = j1 - j0;
        let mut quads = Vec::with_capacity(self.n_quads() * width);
        for q in 0..self.n_quads() {
            quads.extend_from_slice(&self.quads[q * self.n_cols + j0..q * self.n_cols + j1]);
        }
        let tail = self
            .tail
            .iter()
            .map(|t| {
                let vals: Vec<u8> = (j0..j1).map(|j| t.get(j)).collect();
                PackedCrumbs::pack(&vals)
            })
            .collect();
        CrumbWeights {
            n_rows: self.n_rows,
            n_cols: width,
            quads,
            tail,
            codebook: self.codebook.clone(),
            col_scales: self.col_scales[j0..j1].to_vec(),
        }
    }
}

impl QuantWeights {
    /// Convert to the crumb-packed storage format consumed by the crumb
    /// GEMM kernel. Requires a <= 2-bit codebook (the speculative draft
    /// regime).
    pub fn pack_crumbs(&self) -> CrumbWeights {
        assert!(
            self.codebook.len() <= 4,
            "cannot crumb-pack a {}-entry codebook",
            self.codebook.len()
        );
        let (k, n) = (self.n_rows, self.n_cols);
        let mut quads = Vec::with_capacity((k / 4) * n);
        for q in 0..k / 4 {
            for j in 0..n {
                let mut b = 0u8;
                for r in 0..4 {
                    let v = self.idx[(4 * q + r) * n + j];
                    assert!(v < 4, "weight index does not fit in a crumb");
                    b |= v << (6 - 2 * r);
                }
                quads.push(b);
            }
        }
        let tail = (4 * (k / 4)..k)
            .map(|r| PackedCrumbs::pack(&self.idx[r * n..(r + 1) * n]))
            .collect();
        CrumbWeights {
            n_rows: k,
            n_cols: n,
            quads,
            tail,
            codebook: self.codebook.clone(),
            col_scales: self.col_scales.clone(),
        }
    }

    /// Convert to the nibble-packed storage format consumed by
    /// `gemm::packed`. Requires a <= 4-bit codebook (all WAQ configs).
    pub fn pack(&self) -> PackedWeights {
        assert!(
            self.codebook.len() <= 16,
            "cannot nibble-pack a {}-entry codebook",
            self.codebook.len()
        );
        let (k, n) = (self.n_rows, self.n_cols);
        let mut pairs = Vec::with_capacity((k / 2) * n);
        for p in 0..k / 2 {
            let hi = &self.idx[2 * p * n..(2 * p + 1) * n];
            let lo = &self.idx[(2 * p + 1) * n..(2 * p + 2) * n];
            for (&h, &l) in hi.iter().zip(lo) {
                assert!(h < 16 && l < 16, "weight index does not fit in a nibble");
                pairs.push((h << 4) | l);
            }
        }
        let tail = if k % 2 == 1 {
            Some(PackedIdx::pack(&self.idx[(k - 1) * n..k * n]))
        } else {
            None
        };
        PackedWeights {
            n_rows: k,
            n_cols: n,
            pairs,
            tail,
            codebook: self.codebook.clone(),
            col_scales: self.col_scales.clone(),
        }
    }
}

impl super::activation::QuantToken {
    /// Nibble-pack the activation index stream (halves the activation-side
    /// index traffic; outliers and scale are untouched).
    pub fn pack_idx(&self) -> PackedIdx {
        PackedIdx::pack(&self.idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip_even_and_odd() {
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 2, 7, 8, 31, 64, 1001] {
            let idx: Vec<u8> = (0..len).map(|_| rng.below(16) as u8).collect();
            let p = PackedIdx::pack(&idx);
            assert_eq!(p.len, len);
            assert_eq!(p.storage_bytes(), len.div_ceil(2));
            assert_eq!(p.unpack(), idx, "len {len}");
            for (i, &v) in idx.iter().enumerate() {
                assert_eq!(p.get(i), v, "len {len} elem {i}");
            }
        }
    }

    #[test]
    fn nibble_layout_is_high_first() {
        let p = PackedIdx::pack(&[0xA, 0x3, 0xF]);
        assert_eq!(p.bytes, vec![0xA3, 0xF0]);
    }

    #[test]
    fn crumb_pack_unpack_roundtrip_all_tail_lengths() {
        let mut rng = Rng::new(11);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 33, 1001] {
            let idx: Vec<u8> = (0..len).map(|_| rng.below(4) as u8).collect();
            let p = PackedCrumbs::pack(&idx);
            assert_eq!(p.len, len);
            assert_eq!(p.storage_bytes(), len.div_ceil(4));
            assert_eq!(p.unpack(), idx, "len {len}");
            for (i, &v) in idx.iter().enumerate() {
                assert_eq!(p.get(i), v, "len {len} elem {i}");
            }
        }
        assert!(PackedCrumbs::pack(&[]).is_empty());
    }

    #[test]
    fn crumb_layout_is_high_first() {
        // 0b11_10_01_00, then 0b01_00_00_00
        let p = PackedCrumbs::pack(&[3, 2, 1, 0, 1]);
        assert_eq!(p.bytes, vec![0xE4, 0x40]);
    }

    #[test]
    #[should_panic(expected = "crumb")]
    fn crumb_pack_rejects_wide_index() {
        PackedCrumbs::pack(&[4]);
    }

    #[test]
    fn crumb_boundaries_and_storage_match_allocation() {
        // boundary lengths: empty, single, odd tails, and a large
        // non-multiple-of-4 stream
        let mut rng = Rng::new(12);
        for len in [0usize, 1, 3, 5, 4095] {
            let idx: Vec<u8> = (0..len).map(|_| rng.below(4) as u8).collect();
            let p = PackedCrumbs::pack(&idx);
            assert_eq!(p.unpack(), idx, "len {len}");
            // regression: storage accounting must report the actual byte
            // allocation, not a formula that can drift from it
            assert_eq!(p.storage_bytes(), p.bytes.len(), "len {len}");
            assert_eq!(p.bytes.len(), len.div_ceil(4), "len {len}");
        }
        // same accounting contract for the nibble stream
        for len in [0usize, 1, 3, 4095] {
            let idx: Vec<u8> = (0..len).map(|_| rng.below(16) as u8).collect();
            let p = PackedIdx::pack(&idx);
            assert_eq!(p.unpack(), idx, "len {len}");
            assert_eq!(p.storage_bytes(), p.bytes.len(), "len {len}");
            assert_eq!(p.bytes.len(), len.div_ceil(2), "len {len}");
        }
    }

    #[test]
    fn slice_cols_matches_full_matrix_columns() {
        let mut rng = Rng::new(13);
        // even and odd K (odd exercises tail re-packing across unaligned
        // shard boundaries)
        for &(k, n) in &[(8usize, 11usize), (9, 11), (1, 7), (33, 16)] {
            let w = Matrix::random_normal(k, n, 1.0, &mut rng);
            let qw = quant::quantize_weights(&w, 4);
            let pw = qw.pack();
            let full_idx = pw.unpack_idx();
            for &(j0, j1) in &[(0usize, n), (0, 1), (n - 1, n), (1, n - 1), (n / 2, n)] {
                if j0 >= j1 {
                    continue;
                }
                let s = pw.slice_cols(j0, j1);
                assert_eq!(s.n_rows, k);
                assert_eq!(s.n_cols, j1 - j0);
                assert_eq!(s.col_scales, pw.col_scales[j0..j1].to_vec());
                assert_eq!(s.codebook, pw.codebook);
                // index identity per (row, column)
                let sliced_idx = s.unpack_idx();
                for r in 0..k {
                    for j in j0..j1 {
                        assert_eq!(
                            sliced_idx[r * (j1 - j0) + (j - j0)],
                            full_idx[r * n + j],
                            "({k},{n}) row {r} col {j} slice {j0}..{j1}"
                        );
                    }
                }
                // dequant_row (the outlier-compensation fetch) agrees too
                let (mut a, mut b) = (Vec::new(), Vec::new());
                for r in 0..k {
                    pw.dequant_row(r, &mut a);
                    s.dequant_row(r, &mut b);
                    assert_eq!(&a[j0..j1], &b[..], "({k},{n}) row {r}");
                }
            }
        }
    }

    #[test]
    fn set_in_matches_pack_for_nibbles_and_crumbs() {
        let mut rng = Rng::new(21);
        for len in [1usize, 2, 3, 4, 5, 9, 33] {
            let idx4: Vec<u8> = (0..len).map(|_| rng.below(16) as u8).collect();
            let mut buf = vec![0u8; len.div_ceil(2)];
            for (i, &v) in idx4.iter().enumerate() {
                PackedIdx::set_in(&mut buf, i, v);
            }
            assert_eq!(buf, PackedIdx::pack(&idx4).bytes, "nibble len {len}");
            for (i, &v) in idx4.iter().enumerate() {
                assert_eq!(PackedIdx::get_in(&buf, i), v);
            }
            let idx2: Vec<u8> = (0..len).map(|_| rng.below(4) as u8).collect();
            let mut buf = vec![0u8; len.div_ceil(4)];
            for (i, &v) in idx2.iter().enumerate() {
                PackedCrumbs::set_in(&mut buf, i, v);
            }
            assert_eq!(buf, PackedCrumbs::pack(&idx2).bytes, "crumb len {len}");
            for (i, &v) in idx2.iter().enumerate() {
                assert_eq!(PackedCrumbs::get_in(&buf, i), v);
            }
        }
        // set_in overwrites in place (read-modify-write, not or-in)
        let mut buf = vec![0xFFu8; 1];
        PackedIdx::set_in(&mut buf, 0, 0x2);
        assert_eq!(buf[0], 0x2F);
        PackedCrumbs::set_in(&mut buf, 1, 0x1); // bits 5..4: 0b10 -> 0b01
        assert_eq!(buf[0], 0x1F);
    }

    #[test]
    fn weights_pack_roundtrip() {
        let mut rng = Rng::new(2);
        for &(k, n) in &[(8usize, 6usize), (9, 5), (1, 4), (33, 16)] {
            let w = Matrix::random_normal(k, n, 1.0, &mut rng);
            let qw = quant::quantize_weights(&w, 4);
            let pw = qw.pack();
            assert_eq!(pw.n_rows, k);
            assert_eq!(pw.n_cols, n);
            assert_eq!(pw.n_pairs(), k / 2);
            assert_eq!(pw.tail.is_some(), k % 2 == 1);
            assert_eq!(pw.unpack_idx(), qw.idx, "({k},{n})");
            assert_eq!(pw.col_scales, qw.col_scales);
            assert_eq!(pw.codebook, qw.codebook);
        }
    }

    #[test]
    fn dequant_row_matches_unpacked_even_and_odd_k() {
        let mut rng = Rng::new(7);
        for &(k, n) in &[(8usize, 6usize), (9, 5), (1, 4)] {
            let w = Matrix::random_normal(k, n, 1.0, &mut rng);
            let qw = quant::quantize_weights(&w, 4);
            let pw = qw.pack();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for r in 0..k {
                qw.dequant_row(r, &mut a);
                pw.dequant_row(r, &mut b);
                assert_eq!(a, b, "({k},{n}) row {r}");
            }
        }
    }

    #[test]
    fn packing_halves_index_traffic() {
        let mut rng = Rng::new(3);
        let w = Matrix::random_normal(128, 64, 1.0, &mut rng);
        let qw = quant::quantize_weights(&w, 4);
        let pw = qw.pack();
        assert_eq!(pw.index_bytes(), qw.idx.len() / 2);
        // storage accounting stays consistent with the unpacked form
        assert_eq!(pw.storage_bytes(), qw.storage_bytes());
    }

    #[test]
    fn crumb_weights_pack_roundtrip_all_tail_lengths() {
        let mut rng = Rng::new(31);
        // K % 4 in {0, 1, 2, 3}, including a K < 4 tail-only edge
        for &(k, n) in &[(8usize, 6usize), (9, 5), (10, 7), (11, 4), (3, 4), (33, 16)] {
            let w = Matrix::random_normal(k, n, 1.0, &mut rng);
            let qw = quant::quantize_weights(&w, 2);
            let cw = qw.pack_crumbs();
            assert_eq!(cw.n_rows, k);
            assert_eq!(cw.n_cols, n);
            assert_eq!(cw.n_quads(), k / 4);
            assert_eq!(cw.tail.len(), k % 4);
            assert_eq!(cw.unpack_idx(), qw.idx, "({k},{n})");
            assert_eq!(cw.col_scales, qw.col_scales);
            assert_eq!(cw.codebook, qw.codebook);
            // dequant_row (the outlier-compensation fetch) is bit-identical
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for r in 0..k {
                qw.dequant_row(r, &mut a);
                cw.dequant_row(r, &mut b);
                assert_eq!(a, b, "({k},{n}) row {r}");
            }
        }
    }

    #[test]
    fn crumb_weights_quarter_index_traffic() {
        let mut rng = Rng::new(32);
        let w = Matrix::random_normal(128, 64, 1.0, &mut rng);
        let qw = quant::quantize_weights(&w, 2);
        let cw = qw.pack_crumbs();
        assert_eq!(cw.index_bytes(), qw.idx.len() / 4);
        // half the nibble-packed form's stream
        assert_eq!(cw.index_bytes() * 2, qw.pack().index_bytes());
    }

    #[test]
    fn crumb_slice_cols_matches_full_matrix_columns() {
        let mut rng = Rng::new(33);
        for &(k, n) in &[(8usize, 11usize), (9, 11), (2, 7), (33, 16)] {
            let w = Matrix::random_normal(k, n, 1.0, &mut rng);
            let qw = quant::quantize_weights(&w, 2);
            let cw = qw.pack_crumbs();
            let full_idx = cw.unpack_idx();
            for &(j0, j1) in &[(0usize, n), (0, 1), (n - 1, n), (1, n - 1), (n / 2, n)] {
                if j0 >= j1 {
                    continue;
                }
                let s = cw.slice_cols(j0, j1);
                assert_eq!(s.n_rows, k);
                assert_eq!(s.n_cols, j1 - j0);
                assert_eq!(s.col_scales, cw.col_scales[j0..j1].to_vec());
                assert_eq!(s.codebook, cw.codebook);
                let sliced_idx = s.unpack_idx();
                for r in 0..k {
                    for j in j0..j1 {
                        assert_eq!(
                            sliced_idx[r * (j1 - j0) + (j - j0)],
                            full_idx[r * n + j],
                            "({k},{n}) row {r} col {j} slice {j0}..{j1}"
                        );
                    }
                }
                let (mut a, mut b) = (Vec::new(), Vec::new());
                for r in 0..k {
                    cw.dequant_row(r, &mut a);
                    s.dequant_row(r, &mut b);
                    assert_eq!(&a[j0..j1], &b[..], "({k},{n}) row {r}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "crumb-pack")]
    fn crumb_pack_rejects_wide_codebooks() {
        let mut rng = Rng::new(34);
        let w = Matrix::random_normal(8, 4, 1.0, &mut rng);
        quant::quantize_weights(&w, 4).pack_crumbs();
    }

    #[test]
    fn three_bit_codebooks_pack_too() {
        let mut rng = Rng::new(4);
        let w = Matrix::random_normal(17, 9, 1.0, &mut rng);
        let qw = quant::quantize_weights(&w, 3);
        let pw = qw.pack();
        assert_eq!(pw.unpack_idx(), qw.idx);
    }

    #[test]
    fn token_pack_idx() {
        let mut rng = Rng::new(5);
        let toks: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(33, 1.0)).collect();
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let cfg = quant::OutlierCfg::default();
        let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.normal_vec(33, 1.0);
        let t = quant::quantize_token(&x, &cb, cfg);
        assert_eq!(t.pack_idx().unpack(), t.idx);
    }
}
