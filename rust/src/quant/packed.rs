//! Any-bit packed index storage (runtime bit-width 2/3/4).
//!
//! The WAQ datapath is memory-bandwidth-bound at decode, yet the plain
//! `QuantWeights`/`QuantToken` forms spend a full byte per <=4-bit index —
//! several times the traffic the quantization scheme was chosen to avoid.
//! This module provides the packed forms the fast GEMM backend
//! (`gemm::packed`) streams, unified across every codebook width the repo
//! serves:
//!
//! * [`PackedStream`] — a flat index sequence at a runtime bit-width.
//!   2-bit streams pack four "crumbs" per byte; 3- and 4-bit streams pack
//!   two nibbles per byte (a 3-bit index rides in a nibble: byte-aligned
//!   streaming beats the 4/3x density of true bit-packing on this path).
//!   Both layouts are high-first — element 0 lives in the top lanes of
//!   byte 0, so a byte reads left-to-right like the stream it encodes.
//! * [`PackedWeights`] — the K x N weight index matrix packed along the
//!   *reduction* dimension, `rows_per_byte` rows per byte (2 for nibble
//!   widths, 4 for crumbs). Packing along K is what lets the GEMM kernel
//!   fuse LUT rows and do one lookup per several MACs (see `gemm::packed`
//!   for the kernel-side story). The `n_rows % rows_per_byte` final rows
//!   are kept as column-packed [`PackedStream`] tails. Carries the
//!   optional FineQuant per-group scale grid alongside the per-column
//!   scales (see `quant::weights::quantize_weights_grouped`).
//!
//! Packing is lossless for any codebook of <= 16 centroids (<= 4 bits),
//! which covers every WAQ configuration in the paper plus the 2-bit
//! speculative-draft regime.

use super::codebook::Codebook;
use super::weights::QuantWeights;

/// Logical indices stored per byte at a given stream width: four for
/// 2-bit crumbs, two for 3-/4-bit nibbles.
#[inline]
pub fn idx_per_byte(bits: u32) -> usize {
    if bits <= 2 {
        4
    } else {
        2
    }
}

/// A flat sequence of b-bit indices (b in 2..=4), packed high-first.
///
/// 2-bit: element `4i` lives in bits 7..6 of byte `i`, element `4i+3` in
/// bits 1..0. 3-/4-bit: element `2i` lives in the HIGH nibble of byte
/// `i`, element `2i+1` in the LOW nibble. Unused tail lanes are zeroed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedStream {
    /// `len.div_ceil(idx_per_byte(bits))` bytes.
    pub bytes: Vec<u8>,
    /// logical number of indices
    pub len: usize,
    bits: u32,
}

impl PackedStream {
    /// Pack a byte-per-index stream at width `bits`. Every index must fit
    /// in `bits` bits — enforced with a hard assert even in release,
    /// because a wide index would bleed into its neighbor's lane and
    /// corrupt both values (packing is a cold path; the check is one
    /// branch per element).
    pub fn pack(idx: &[u8], bits: u32) -> PackedStream {
        assert!((2..=4).contains(&bits), "unsupported stream width: {bits} bits");
        let per = idx_per_byte(bits);
        let lane = 8 / per; // bits per storage lane (2 or 4)
        let mut bytes = Vec::with_capacity(idx.len().div_ceil(per));
        for chunk in idx.chunks(per) {
            let mut b = 0u8;
            for (i, &v) in chunk.iter().enumerate() {
                assert!((v as u32) < (1 << bits), "index {v} does not fit in {bits} bits");
                b |= v << (8 - lane * (i + 1));
            }
            bytes.push(b);
        }
        PackedStream { bytes, len: idx.len(), bits }
    }

    /// Inverse of [`PackedStream::pack`].
    pub fn unpack(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// The stream's logical bit-width (2, 3, or 4).
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Read one logical index.
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        debug_assert!(i < self.len);
        Self::get_in(&self.bytes, self.bits, i)
    }

    /// Read one logical index from any packed byte slice at width `bits`
    /// (the layout contract for external pools, e.g. the KV-cache store).
    #[inline]
    pub fn get_in(bytes: &[u8], bits: u32, i: usize) -> u8 {
        if bits <= 2 {
            (bytes[i / 4] >> (6 - 2 * (i % 4))) & 0x03
        } else {
            let b = bytes[i / 2];
            if i % 2 == 0 {
                b >> 4
            } else {
                b & 0x0F
            }
        }
    }

    /// Write one logical index into a packed byte slice in place.
    #[inline]
    pub fn set_in(bytes: &mut [u8], bits: u32, i: usize, v: u8) {
        // hard assert even in release, for the same reason as `pack`: a
        // wide index would corrupt neighboring lanes
        assert!((v as u32) < (1 << bits), "index {v} does not fit in {bits} bits");
        if bits <= 2 {
            let shift = 6 - 2 * (i % 4);
            let b = &mut bytes[i / 4];
            *b = (*b & !(0x03 << shift)) | (v << shift);
        } else {
            let b = &mut bytes[i / 2];
            if i % 2 == 0 {
                *b = (*b & 0x0F) | (v << 4);
            } else {
                *b = (*b & 0xF0) | v;
            }
        }
    }

    /// Slice logical elements `[j0, j1)` and re-pack as a standalone
    /// stream. This is the ONE column-slicing definition — weight-tail
    /// rows and shard splits both route through it, so slice boundaries
    /// need not be byte-aligned anywhere.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> PackedStream {
        assert!(j0 <= j1 && j1 <= self.len, "bad column range {j0}..{j1}");
        let vals: Vec<u8> = (j0..j1).map(|j| self.get(j)).collect();
        PackedStream::pack(&vals, self.bits)
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bytes of index storage (the actual allocation).
    pub fn storage_bytes(&self) -> usize {
        self.bytes.len()
    }
}

/// K-Means-quantized weights with the index matrix packed along the
/// reduction dimension at the codebook's bit-width — the storage format
/// the packed/tiled GEMM backend streams for every width in {2,3,4}.
/// Produced by [`QuantWeights::pack`]; numerically identical to the
/// unpacked form (same codebook, scales, and index values).
#[derive(Clone, Debug)]
pub struct PackedWeights {
    pub n_rows: usize, // K (reduction dim)
    pub n_cols: usize, // N (output channels)
    /// `(n_rows / rows_per_byte) * n_cols` bytes, row-chunk-major: byte
    /// `body[c * n_cols + j]` holds rows `c*per .. (c+1)*per` of column
    /// `j`, high-first (nibble widths: `idx[2c][j] << 4 | idx[2c+1][j]`;
    /// crumbs: row `4c` in bits 7..6).
    pub body: Vec<u8>,
    /// The `n_rows % rows_per_byte` final rows, each packed along columns.
    pub tail: Vec<PackedStream>,
    pub codebook: Codebook,
    pub col_scales: Vec<f32>,
    /// Reduction rows per scale group; 0 = whole-column scaling only.
    pub group_size: usize,
    /// FineQuant per-group scale grid, `n_groups * n_cols` row-major by
    /// group; empty when `group_size == 0`.
    pub group_scales: Vec<f32>,
    bits: u32,
}

impl PackedWeights {
    /// The codebook's logical bit-width (2, 3, or 4).
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Reduction rows packed into each body byte (2 or 4).
    #[inline]
    pub fn rows_per_byte(&self) -> usize {
        idx_per_byte(self.bits)
    }

    /// Number of packed body chunks (`n_rows / rows_per_byte`).
    #[inline]
    pub fn n_chunks(&self) -> usize {
        self.n_rows / self.rows_per_byte()
    }

    /// Rows covered by the body (the rest live in `tail`).
    #[inline]
    pub fn body_rows(&self) -> usize {
        self.n_chunks() * self.rows_per_byte()
    }

    /// Number of reduction-dim scale groups (1 when ungrouped).
    #[inline]
    pub fn n_groups(&self) -> usize {
        if self.group_size == 0 {
            1
        } else {
            self.n_rows.div_ceil(self.group_size)
        }
    }

    /// Reduction-row range `[k0, k1)` covered by scale group `g`.
    #[inline]
    pub fn group_bounds(&self, g: usize) -> (usize, usize) {
        if self.group_size == 0 {
            (0, self.n_rows)
        } else {
            (g * self.group_size, ((g + 1) * self.group_size).min(self.n_rows))
        }
    }

    /// Read one logical index (reduction row `k`, column `j`).
    #[inline]
    pub fn get_idx(&self, k: usize, j: usize) -> u8 {
        let body_rows = self.body_rows();
        if k >= body_rows {
            return self.tail[k - body_rows].get(j);
        }
        let per = self.rows_per_byte();
        let b = self.body[(k / per) * self.n_cols + j];
        if per == 2 {
            if k % 2 == 0 {
                b >> 4
            } else {
                b & 0x0F
            }
        } else {
            (b >> (6 - 2 * (k % 4))) & 0x03
        }
    }

    /// Recover the byte-per-index matrix (row-major K x N), for tests and
    /// for interop with the unpacked execution paths.
    pub fn unpack_idx(&self) -> Vec<u8> {
        let n = self.n_cols;
        let mut idx = vec![0u8; self.n_rows * n];
        for k in 0..self.n_rows {
            for j in 0..n {
                idx[k * n + j] = self.get_idx(k, j);
            }
        }
        idx
    }

    /// Dequantize one input-channel (reduction) row straight from the
    /// packed form — the per-outlier fetch of the error-compensation
    /// branch (paper §III-C2), bit-identical to
    /// `QuantWeights::dequant_row` on the unpacked form, including the
    /// per-group scale factor when present.
    pub fn dequant_row(&self, k: usize, out: &mut Vec<f32>) {
        debug_assert!(k < self.n_rows, "row {k} out of range ({})", self.n_rows);
        out.clear();
        let gs = if self.group_scales.is_empty() {
            None
        } else {
            let g = k / self.group_size;
            Some(&self.group_scales[g * self.n_cols..(g + 1) * self.n_cols])
        };
        out.extend((0..self.n_cols).map(|j| {
            let v = self.codebook.value(self.get_idx(k, j)) * self.col_scales[j];
            match gs {
                Some(gs) => v * gs[j],
                None => v,
            }
        }));
    }

    /// Index-storage bytes: `1/rows_per_byte` of the byte-per-index form
    /// (plus rounded-up tail rows).
    pub fn index_bytes(&self) -> usize {
        self.body.len() + self.tail.iter().map(|t| t.storage_bytes()).sum::<usize>()
    }

    /// Slice out output columns `[j0, j1)` as a standalone packed matrix —
    /// the load-time column partitioner of the tensor-parallel sharded
    /// backend (`gemm::sharded`), width-generic. Body chunks are copied
    /// byte-for-byte (row packing runs along K inside a byte, so columns
    /// stay independent bytes); tail rows route through
    /// [`PackedStream::slice_cols`] so shard boundaries need not be
    /// byte-aligned; the codebook, per-column scales, and per-group scale
    /// grid are partitioned with the slice, so every per-column value
    /// (GEMM accumulation, `dequant_row`) is bit-identical to the same
    /// column of the full matrix.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> PackedWeights {
        assert!(j0 < j1 && j1 <= self.n_cols, "bad column range {j0}..{j1}");
        let width = j1 - j0;
        let mut body = Vec::with_capacity(self.n_chunks() * width);
        for c in 0..self.n_chunks() {
            body.extend_from_slice(&self.body[c * self.n_cols + j0..c * self.n_cols + j1]);
        }
        let tail = self.tail.iter().map(|t| t.slice_cols(j0, j1)).collect();
        let group_scales = if self.group_scales.is_empty() {
            Vec::new()
        } else {
            (0..self.n_groups())
                .flat_map(|g| &self.group_scales[g * self.n_cols + j0..g * self.n_cols + j1])
                .copied()
                .collect()
        };
        PackedWeights {
            n_rows: self.n_rows,
            n_cols: width,
            body,
            tail,
            codebook: self.codebook.clone(),
            col_scales: self.col_scales[j0..j1].to_vec(),
            group_size: self.group_size,
            group_scales,
            bits: self.bits,
        }
    }

    /// Total storage: packed indices + FP16 codebook + FP16 scales (per
    /// column, plus the per-group grid when present). The index term is
    /// lane-aligned — it equals `QuantWeights::storage_bytes` (which
    /// counts bit-level packing) at 2 and 4 bits; a 3-bit codebook costs
    /// 4/3x the bit-minimal figure in exchange for byte-aligned streaming.
    pub fn storage_bytes(&self) -> usize {
        self.index_bytes()
            + self.codebook.len() * 2
            + self.col_scales.len() * 2
            + self.group_scales.len() * 2
    }
}

impl QuantWeights {
    /// Convert to the packed storage format consumed by `gemm::packed`,
    /// selecting the stream density from the codebook width (<= 4
    /// centroids pack four rows per byte, <= 16 pack two). Lossless for
    /// every WAQ config in the repo.
    pub fn pack(&self) -> PackedWeights {
        assert!(
            self.codebook.len() <= 16,
            "cannot pack a {}-entry codebook",
            self.codebook.len()
        );
        let bits = match self.codebook.len() {
            0..=4 => 2,
            5..=8 => 3,
            _ => 4,
        };
        let (k, n) = (self.n_rows, self.n_cols);
        let per = idx_per_byte(bits);
        let lane = 8 / per;
        let mut body = Vec::with_capacity((k / per) * n);
        for c in 0..k / per {
            for j in 0..n {
                let mut b = 0u8;
                for r in 0..per {
                    let v = self.idx[(per * c + r) * n + j];
                    assert!((v as u32) < (1 << bits), "weight index does not fit in {bits} bits");
                    b |= v << (8 - lane * (r + 1));
                }
                body.push(b);
            }
        }
        let tail = (per * (k / per)..k)
            .map(|r| PackedStream::pack(&self.idx[r * n..(r + 1) * n], bits))
            .collect();
        PackedWeights {
            n_rows: k,
            n_cols: n,
            body,
            tail,
            codebook: self.codebook.clone(),
            col_scales: self.col_scales.clone(),
            group_size: self.group_size,
            group_scales: self.group_scales.clone(),
            bits,
        }
    }
}

impl super::activation::QuantToken {
    /// Nibble-pack the activation index stream (halves the activation-side
    /// index traffic; outliers and scale are untouched).
    pub fn pack_idx(&self) -> PackedStream {
        PackedStream::pack(&self.idx, 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn stream_roundtrip_all_widths_and_tail_lengths() {
        let mut rng = Rng::new(1);
        for bits in [2u32, 3, 4] {
            let per = idx_per_byte(bits);
            for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 31, 33, 64, 1001] {
                let idx: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
                let p = PackedStream::pack(&idx, bits);
                assert_eq!(p.len, len);
                assert_eq!(p.bits(), bits);
                assert_eq!(p.storage_bytes(), len.div_ceil(per));
                assert_eq!(p.unpack(), idx, "bits {bits} len {len}");
                for (i, &v) in idx.iter().enumerate() {
                    assert_eq!(p.get(i), v, "bits {bits} len {len} elem {i}");
                }
            }
            assert!(PackedStream::pack(&[], bits).is_empty());
        }
    }

    #[test]
    fn nibble_layout_is_high_first() {
        let p = PackedStream::pack(&[0xA, 0x3, 0xF], 4);
        assert_eq!(p.bytes, vec![0xA3, 0xF0]);
        // 3-bit streams share the nibble layout (byte-aligned lanes)
        let p = PackedStream::pack(&[0x5, 0x3, 0x7], 3);
        assert_eq!(p.bytes, vec![0x53, 0x70]);
    }

    #[test]
    fn crumb_layout_is_high_first() {
        // 0b11_10_01_00, then 0b01_00_00_00
        let p = PackedStream::pack(&[3, 2, 1, 0, 1], 2);
        assert_eq!(p.bytes, vec![0xE4, 0x40]);
    }

    #[test]
    #[should_panic(expected = "does not fit in 2 bits")]
    fn crumb_stream_rejects_wide_index() {
        PackedStream::pack(&[4], 2);
    }

    #[test]
    #[should_panic(expected = "does not fit in 3 bits")]
    fn three_bit_stream_rejects_codeword_past_the_edge() {
        // 8 is the first index past the 8-codeword edge of a 3-bit book
        PackedStream::pack(&[8], 3);
    }

    #[test]
    fn three_bit_boundary_roundtrips_at_the_codeword_edge() {
        // boundary lengths: empty, single, odd tails, and a large odd
        // stream; values pinned at the 8-codeword edge (0 and 7) at both
        // ends so edge codewords survive packing, slicing, and tails
        let mut rng = Rng::new(12);
        for len in [0usize, 1, 3, 5, 4095] {
            let mut idx: Vec<u8> = (0..len).map(|_| rng.below(8) as u8).collect();
            if len > 0 {
                idx[0] = 7;
                idx[len - 1] = 7;
                idx[len / 2] = 0;
            }
            let p = PackedStream::pack(&idx, 3);
            assert_eq!(p.unpack(), idx, "len {len}");
            // regression: storage accounting must report the actual byte
            // allocation, not a formula that can drift from it
            assert_eq!(p.storage_bytes(), p.bytes.len(), "len {len}");
            assert_eq!(p.bytes.len(), len.div_ceil(2), "len {len}");
            if len > 1 {
                // unaligned slice keeps edge values intact
                let s = p.slice_cols(1, len);
                assert_eq!(s.unpack(), idx[1..], "len {len}");
            }
        }
    }

    #[test]
    fn boundaries_and_storage_match_allocation_all_widths() {
        let mut rng = Rng::new(13);
        for bits in [2u32, 3, 4] {
            let per = idx_per_byte(bits);
            for len in [0usize, 1, 3, 5, 4095] {
                let idx: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
                let p = PackedStream::pack(&idx, bits);
                assert_eq!(p.unpack(), idx, "bits {bits} len {len}");
                assert_eq!(p.storage_bytes(), p.bytes.len(), "bits {bits} len {len}");
                assert_eq!(p.bytes.len(), len.div_ceil(per), "bits {bits} len {len}");
            }
        }
    }

    #[test]
    fn stream_slice_cols_matches_full_stream() {
        let mut rng = Rng::new(14);
        for bits in [2u32, 3, 4] {
            let idx: Vec<u8> = (0..33).map(|_| rng.below(1 << bits) as u8).collect();
            let p = PackedStream::pack(&idx, bits);
            for &(j0, j1) in &[(0usize, 33usize), (0, 1), (32, 33), (1, 32), (5, 20), (7, 7)] {
                let s = p.slice_cols(j0, j1);
                assert_eq!(s.len, j1 - j0);
                assert_eq!(s.unpack(), idx[j0..j1], "bits {bits} slice {j0}..{j1}");
            }
        }
    }

    #[test]
    fn set_in_matches_pack_for_nibbles_and_crumbs() {
        let mut rng = Rng::new(21);
        for bits in [2u32, 3, 4] {
            let per = idx_per_byte(bits);
            for len in [1usize, 2, 3, 4, 5, 9, 33] {
                let idx: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
                let mut buf = vec![0u8; len.div_ceil(per)];
                for (i, &v) in idx.iter().enumerate() {
                    PackedStream::set_in(&mut buf, bits, i, v);
                }
                assert_eq!(buf, PackedStream::pack(&idx, bits).bytes, "bits {bits} len {len}");
                for (i, &v) in idx.iter().enumerate() {
                    assert_eq!(PackedStream::get_in(&buf, bits, i), v);
                }
            }
        }
        // set_in overwrites in place (read-modify-write, not or-in)
        let mut buf = vec![0xFFu8; 1];
        PackedStream::set_in(&mut buf, 4, 0, 0x2);
        assert_eq!(buf[0], 0x2F);
        PackedStream::set_in(&mut buf, 2, 1, 0x1); // bits 5..4: 0b10 -> 0b01
        assert_eq!(buf[0], 0x1F);
    }

    #[test]
    fn weights_pack_roundtrip_all_widths_and_tails() {
        let mut rng = Rng::new(2);
        // K covers every tail length for both densities, incl. K < per
        for &(k, n) in &[(8usize, 6usize), (9, 5), (10, 7), (11, 4), (1, 4), (3, 4), (33, 16)] {
            for bits in [2u32, 3, 4] {
                let w = Matrix::random_normal(k, n, 1.0, &mut rng);
                let qw = quant::quantize_weights(&w, bits);
                let pw = qw.pack();
                assert_eq!(pw.bits(), bits);
                assert_eq!(pw.n_rows, k);
                assert_eq!(pw.n_cols, n);
                assert_eq!(pw.n_chunks(), k / pw.rows_per_byte());
                assert_eq!(pw.tail.len(), k % pw.rows_per_byte());
                assert_eq!(pw.unpack_idx(), qw.idx, "({k},{n}) bits {bits}");
                assert_eq!(pw.col_scales, qw.col_scales);
                assert_eq!(pw.codebook, qw.codebook);
                assert!(pw.group_scales.is_empty());
            }
        }
    }

    #[test]
    fn slice_cols_matches_full_matrix_columns_at_every_width() {
        let mut rng = Rng::new(3);
        // odd K exercises tail re-packing across unaligned shard
        // boundaries; group sizes cover ungrouped and a multi-group grid
        for &(k, n) in &[(8usize, 11usize), (9, 11), (1, 7), (33, 16)] {
            for bits in [2u32, 3, 4] {
                for group in [0usize, 4, 8] {
                    let w = Matrix::random_normal(k, n, 1.0, &mut rng);
                    let qw = quant::quantize_weights_grouped(&w, None, bits, group);
                    let pw = qw.pack();
                    let full_idx = pw.unpack_idx();
                    for &(j0, j1) in &[(0usize, n), (0, 1), (n - 1, n), (1, n - 1), (n / 2, n)] {
                        if j0 >= j1 {
                            continue;
                        }
                        let s = pw.slice_cols(j0, j1);
                        assert_eq!(s.n_rows, k);
                        assert_eq!(s.n_cols, j1 - j0);
                        assert_eq!(s.bits(), pw.bits());
                        assert_eq!(s.col_scales, pw.col_scales[j0..j1].to_vec());
                        assert_eq!(s.codebook, pw.codebook);
                        assert_eq!(s.group_size, pw.group_size);
                        assert_eq!(s.n_groups(), pw.n_groups());
                        // index identity per (row, column)
                        let sliced_idx = s.unpack_idx();
                        for r in 0..k {
                            for j in j0..j1 {
                                assert_eq!(
                                    sliced_idx[r * (j1 - j0) + (j - j0)],
                                    full_idx[r * n + j],
                                    "({k},{n}) b{bits} g{group} row {r} col {j} slice {j0}..{j1}"
                                );
                            }
                        }
                        // dequant_row (the outlier-compensation fetch)
                        // agrees too — this pins the group-scale slicing
                        let (mut a, mut b) = (Vec::new(), Vec::new());
                        for r in 0..k {
                            pw.dequant_row(r, &mut a);
                            s.dequant_row(r, &mut b);
                            assert_eq!(&a[j0..j1], &b[..], "({k},{n}) b{bits} g{group} row {r}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dequant_row_matches_unpacked_every_width() {
        let mut rng = Rng::new(7);
        for &(k, n) in &[(8usize, 6usize), (9, 5), (11, 4), (1, 4)] {
            for bits in [2u32, 3, 4] {
                for group in [0usize, 4] {
                    let w = Matrix::random_normal(k, n, 1.0, &mut rng);
                    let qw = quant::quantize_weights_grouped(&w, None, bits, group);
                    let pw = qw.pack();
                    let (mut a, mut b) = (Vec::new(), Vec::new());
                    for r in 0..k {
                        qw.dequant_row(r, &mut a);
                        pw.dequant_row(r, &mut b);
                        assert_eq!(a, b, "({k},{n}) bits {bits} group {group} row {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn packing_shrinks_index_traffic_per_width() {
        let mut rng = Rng::new(8);
        let w = Matrix::random_normal(128, 64, 1.0, &mut rng);
        // 4-bit: two indices per byte, accounting-identical to the
        // bit-packed figure of the unpacked form
        let qw = quant::quantize_weights(&w, 4);
        let pw = qw.pack();
        assert_eq!(pw.index_bytes(), qw.idx.len() / 2);
        assert_eq!(pw.storage_bytes(), qw.storage_bytes());
        // 2-bit: four indices per byte — half the nibble stream
        let qw2 = quant::quantize_weights(&w, 2);
        let cw = qw2.pack();
        assert_eq!(cw.index_bytes(), qw2.idx.len() / 4);
        assert_eq!(cw.storage_bytes(), qw2.storage_bytes());
        // 3-bit rides in nibbles: byte-aligned, 4/3x the bit-minimal size
        let qw3 = quant::quantize_weights(&w, 3);
        assert_eq!(qw3.pack().index_bytes(), qw3.idx.len() / 2);
    }

    #[test]
    fn grouped_pack_carries_the_scale_grid() {
        let mut rng = Rng::new(9);
        let w = Matrix::random_normal(40, 6, 1.0, &mut rng);
        let qw = quant::quantize_weights_grouped(&w, None, 4, 16);
        let pw = qw.pack();
        assert_eq!(pw.group_size, 16);
        assert_eq!(pw.n_groups(), 3); // 40 rows / 16 per group, rounded up
        assert_eq!(pw.group_scales, qw.group_scales);
        assert_eq!(pw.group_bounds(0), (0, 16));
        assert_eq!(pw.group_bounds(2), (32, 40));
        // the grid is FP16-accounted alongside the per-column scales
        assert_eq!(
            pw.storage_bytes(),
            pw.index_bytes() + pw.codebook.len() * 2 + (6 + 3 * 6) * 2
        );
    }

    #[test]
    #[should_panic(expected = "cannot pack")]
    fn pack_rejects_codebooks_wider_than_four_bits() {
        let mut rng = Rng::new(34);
        let w = Matrix::random_normal(8, 4, 1.0, &mut rng);
        quant::quantize_weights(&w, 5).pack();
    }

    #[test]
    fn token_pack_idx() {
        let mut rng = Rng::new(5);
        let toks: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(33, 1.0)).collect();
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let cfg = quant::OutlierCfg::default();
        let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.normal_vec(33, 1.0);
        let t = quant::quantize_token(&x, &cb, cfg);
        assert_eq!(t.pack_idx().unpack(), t.idx);
    }
}
