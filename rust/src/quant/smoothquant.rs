//! SmoothQuant baseline: migrate activation quantization difficulty into
//! the weights via per-channel scales s_j = max|X_j|^a / max|W_j|^(1-a),
//! then RTN-quantize both sides. The smoothing vector is also an input of
//! the `eval_smooth_*` L2 artifacts (activations are divided by it online).

use super::rtn;
use crate::tensor::Matrix;

pub struct Smoothed {
    /// fake-quantized W' = diag(s) W
    pub weights: Matrix,
    /// per-input-channel smoothing vector s (activations divide by this)
    pub smooth: Vec<f32>,
}

/// `calib_absmax`: per-input-channel max-|activation| from calibration.
pub fn smooth_quantize(w: &Matrix, calib_absmax: &[f32], alpha: f64, bits: u32) -> Smoothed {
    assert_eq!(calib_absmax.len(), w.rows, "absmax per input channel");
    // per-input-channel weight absmax
    let mut w_absmax = vec![1e-12f32; w.rows];
    for r in 0..w.rows {
        w_absmax[r] = w.row(r).iter().fold(1e-12f32, |m, &v| m.max(v.abs()));
    }
    let smooth: Vec<f32> = calib_absmax
        .iter()
        .zip(&w_absmax)
        .map(|(&a, &ww)| {
            let s = (a.max(1e-6) as f64).powf(alpha) / (ww as f64).powf(1.0 - alpha);
            (s.max(1e-6)) as f32
        })
        .collect();
    let mut scaled = w.clone();
    scaled.scale_rows(&smooth); // W' = diag(s) W
    Smoothed { weights: rtn::fake_quant_weights(&scaled, bits), smooth }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn smoothing_preserves_product() {
        // (x / s) @ (diag(s) W) == x @ W exactly (pre-quantization).
        let mut rng = Rng::new(1);
        let w = Matrix::random_normal(32, 16, 1.0, &mut rng);
        let absmax: Vec<f32> = (0..32).map(|i| 1.0 + (i % 7) as f32).collect();
        let mut scaled = w.clone();
        let sm = {
            let s = smooth_quantize(&w, &absmax, 0.5, 16); // bits=16 ~ no quant error focus
            s.smooth
        };
        scaled.scale_rows(&sm);
        let x = Matrix::random_normal(4, 32, 1.0, &mut rng);
        let mut xs = x.clone();
        for r in 0..xs.rows {
            for (c, v) in xs.row_mut(r).iter_mut().enumerate() {
                *v /= sm[c];
            }
        }
        let direct = x.matmul(&w);
        let smoothed = xs.matmul(&scaled);
        assert!(smoothed.rel_err(&direct) < 1e-4);
    }

    #[test]
    fn smoothing_tames_activation_outlier_channels() {
        let mut rng = Rng::new(2);
        let w = Matrix::random_normal(64, 32, 1.0, &mut rng);
        let mut absmax = vec![1.0f32; 64];
        absmax[5] = 100.0; // a notorious outlier channel
        let s = smooth_quantize(&w, &absmax, 0.5, 4);
        // the outlier channel's smoothing factor must be much larger
        let med = {
            let mut v = s.smooth.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[32]
        };
        assert!(s.smooth[5] > 3.0 * med, "{} vs {}", s.smooth[5], med);
    }
}
