//! Codebook + the Clustering Unit's boundary-based nearest-centroid
//! assignment (paper §IV-C): boundaries b_i = (c_i + c_{i+1})/2, and an
//! input in [b_{i-1}, b_i) belongs to cluster i. Assignment uses binary
//! search over boundaries — the software twin of the ASIC's log2(C)-depth
//! comparator tree (and of the L1 Pallas `clustering` kernel).

#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    /// sorted centroids, len = 2^bits
    pub centroids: Vec<f32>,
    /// midpoint boundaries, len = centroids.len() - 1
    pub boundaries: Vec<f32>,
}

impl Codebook {
    pub fn new(mut centroids: Vec<f32>) -> Self {
        assert!(!centroids.is_empty());
        centroids.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let boundaries = centroids
            .windows(2)
            .map(|w| 0.5 * (w[0] + w[1]))
            .collect();
        Codebook { centroids, boundaries }
    }

    pub fn bits(&self) -> u32 {
        debug_assert!(self.centroids.len().is_power_of_two());
        self.centroids.len().trailing_zeros()
    }

    pub fn len(&self) -> usize {
        self.centroids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.centroids.is_empty()
    }

    /// Nearest-centroid index via boundary binary search; ties at an exact
    /// boundary go to the upper cell (matches the `x > b` comparator chain
    /// in hardware and the Pallas kernel).
    #[inline]
    pub fn assign(&self, x: f32) -> u8 {
        // partition_point = number of boundaries < x ... we want x > b
        let idx = self.boundaries.partition_point(|&b| x > b);
        idx as u8
    }

    pub fn assign_slice(&self, xs: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.extend(xs.iter().map(|&x| self.assign(x)));
    }

    #[inline]
    pub fn value(&self, idx: u8) -> f32 {
        self.centroids[idx as usize]
    }

    pub fn dequant_slice(&self, idx: &[u8], scale: f32, out: &mut Vec<f32>) {
        out.clear();
        out.extend(idx.iter().map(|&i| self.value(i) * scale));
    }

    /// Quantize-dequantize one value (fake quant).
    #[inline]
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.value(self.assign(x))
    }

    /// Normalize centroids into [-1, 1] by max-abs (token-wise scaling uses
    /// normalized codebooks; see quant::activation).
    pub fn normalized(&self) -> (Codebook, f32) {
        let scale = self
            .centroids
            .iter()
            .fold(0.0f32, |m, &c| m.max(c.abs()))
            .max(1e-12);
        (
            Codebook::new(self.centroids.iter().map(|&c| c / scale).collect()),
            scale,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn assign_matches_argmin() {
        let mut rng = Rng::new(1);
        let cb = Codebook::new(rng.normal_vec(16, 1.0));
        for _ in 0..2000 {
            let x = rng.normal_f32() * 2.0;
            let got = cb.assign(x) as usize;
            let want = cb
                .centroids
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    (x - **a).abs().partial_cmp(&(x - **b).abs()).unwrap()
                })
                .unwrap()
                .0;
            // ties can differ by one cell; distances must match
            let dg = (x - cb.centroids[got]).abs();
            let dw = (x - cb.centroids[want]).abs();
            assert!((dg - dw).abs() < 1e-6, "x={x} got={got} want={want}");
        }
    }

    #[test]
    fn centroids_assign_to_themselves() {
        let cb = Codebook::new(vec![-2.0, -0.5, 0.1, 3.0]);
        for (i, &c) in cb.centroids.iter().enumerate() {
            assert_eq!(cb.assign(c) as usize, i);
        }
    }

    #[test]
    fn extremes_clamp() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0, 2.0]);
        assert_eq!(cb.assign(-100.0), 0);
        assert_eq!(cb.assign(100.0), 3);
    }

    #[test]
    fn normalized_range() {
        let cb = Codebook::new(vec![-4.0, -1.0, 2.0, 8.0]);
        let (n, s) = cb.normalized();
        assert_eq!(s, 8.0);
        assert!(n.centroids.iter().all(|c| c.abs() <= 1.0));
        assert_eq!(n.value(0), -0.5);
    }

    #[test]
    fn fake_quant_idempotent() {
        let mut rng = Rng::new(2);
        let cb = Codebook::new(rng.normal_vec(8, 1.0));
        for _ in 0..100 {
            let x = rng.normal_f32();
            let q = cb.fake_quant(x);
            assert_eq!(cb.fake_quant(q), q);
        }
    }
}
