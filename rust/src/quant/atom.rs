//! Atom baseline: channel reordering + group-wise quantization with an
//! INT8 outlier-channel block. Group size and outlier block are d/32 — the
//! paper's ratio (group 128 and 128 outlier channels at d = 4096). The
//! permutation (outlier channels last) is learned from calibration
//! activation absmax and shared with the `eval_atom_*` artifacts.

use super::rtn;
use crate::tensor::Matrix;

pub struct AtomQuant {
    /// fake-quantized, ROW-PERMUTED weights (use with permuted activations)
    pub weights: Matrix,
    /// channel permutation: inlier channels first, outliers last
    pub perm: Vec<u32>,
}

/// Choose the permutation placing the n_out highest-absmax activation
/// channels last.
pub fn outlier_permutation(calib_absmax: &[f32]) -> Vec<u32> {
    let d = calib_absmax.len();
    let n_out = (d / 32).max(1);
    let mut order: Vec<u32> = (0..d as u32).collect();
    order.sort_by(|&a, &b| {
        calib_absmax[a as usize]
            .partial_cmp(&calib_absmax[b as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    // ascending absmax: first d-n_out are inliers, last n_out outliers —
    // already the layout we want.
    let _ = n_out;
    order
}

/// Atom weight quantization: permute rows, group-wise RTN along the input
/// dim per output channel (inlier groups at `bits`, the trailing outlier
/// block at 8 bits).
pub fn atom_quantize(w: &Matrix, calib_absmax: &[f32], bits: u32) -> AtomQuant {
    assert_eq!(calib_absmax.len(), w.rows);
    let perm = outlier_permutation(calib_absmax);
    let d = w.rows;
    let g = (d / 32).max(1);
    let n_out = g;

    // permuted weight rows
    let mut wp = Matrix::zeros(d, w.cols);
    for (new_r, &old_r) in perm.iter().enumerate() {
        wp.row_mut(new_r).copy_from_slice(w.row(old_r as usize));
    }

    // group-wise quantization along the input dim, per output channel
    for c in 0..wp.cols {
        let mut col: Vec<f32> = (0..d).map(|r| wp.at(r, c)).collect();
        let mut r0 = 0;
        while r0 < d {
            let r1 = (r0 + g).min(d);
            let b = if r0 >= d - n_out { 8 } else { bits };
            let seg = &mut col[r0..r1];
            let m = seg.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let qmax = ((1i32 << (b - 1)) - 1) as f32;
            rtn::fake_quant_slice(seg, m / qmax, b);
            r0 = r1;
        }
        for r in 0..d {
            *wp.at_mut(r, c) = col[r];
        }
    }
    AtomQuant { weights: wp, perm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn permutation_puts_outlier_channels_last() {
        let mut absmax = vec![1.0f32; 64];
        absmax[3] = 50.0;
        absmax[41] = 80.0;
        let p = outlier_permutation(&absmax);
        assert_eq!(p[63], 41);
        assert_eq!(p[62], 3);
    }

    #[test]
    fn permuted_gemm_matches_with_permuted_activations() {
        let mut rng = Rng::new(1);
        let w = Matrix::random_normal(64, 16, 1.0, &mut rng);
        let absmax: Vec<f32> = (0..64).map(|i| 1.0 + (i % 5) as f32).collect();
        let a = atom_quantize(&w, &absmax, 16); // high bits: permutation test
        let x = Matrix::random_normal(4, 64, 1.0, &mut rng);
        let mut xp = Matrix::zeros(4, 64);
        for r in 0..4 {
            for (nc, &oc) in a.perm.iter().enumerate() {
                *xp.at_mut(r, nc) = x.at(r, oc as usize);
            }
        }
        assert!(xp.matmul(&a.weights).rel_err(&x.matmul(&w)) < 0.02);
    }

    #[test]
    fn group_quant_beats_per_channel_on_blocky_weights() {
        let mut rng = Rng::new(2);
        // weights whose magnitude varies along the input dim -> group scales win
        let mut w = Matrix::random_normal(128, 16, 1.0, &mut rng);
        for r in 0..128 {
            let boost = if r < 8 { 20.0 } else { 1.0 };
            for v in w.row_mut(r) {
                *v *= boost;
            }
        }
        let absmax = vec![1.0f32; 128];
        let atom = atom_quantize(&w, &absmax, 4);
        // undo permutation for comparison
        let mut deq = Matrix::zeros(128, 16);
        for (new_r, &old_r) in atom.perm.iter().enumerate() {
            deq.row_mut(old_r as usize)
                .copy_from_slice(atom.weights.row(new_r));
        }
        let plain = rtn::fake_quant_weights(&w, 4);
        assert!(deq.rel_err(&w) < plain.rel_err(&w));
    }
}
