//! Quantization algorithm library: the paper's K-Means NU-WAQ (weights +
//! activations, Fisher-weighted centroids, outlier protection) and every
//! Table III/IV baseline (RTN, SmoothQuant, QuaRot, Atom).

pub mod activation;
pub mod atom;
pub mod codebook;
pub mod kmeans;
pub mod outlier;
pub mod packed;
pub mod quarot;
pub mod rtn;
pub mod smoothquant;
pub mod weights;

pub use activation::{
    learn_act_codebook, quantize_token, quantize_token_static,
    quantize_token_with_outliers, QuantToken,
};
pub use codebook::Codebook;
pub use outlier::OutlierCfg;
pub use packed::{PackedStream, PackedWeights};
pub use weights::{
    plan_bits, quantize_weights, quantize_weights_grouped, quantize_weights_weighted,
    QuantWeights,
};
