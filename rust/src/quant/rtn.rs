//! Round-to-nearest (RTN) integer quantization — the simplest INT-WAQ
//! baseline in Table III. Symmetric, per-output-channel for weights and
//! per-token for activations (matching the paper's baseline setup).

use crate::tensor::Matrix;

/// Symmetric RTN of a slice with a given scale: round(x/s) clamped to the
/// signed n-bit grid, then dequantized.
pub fn fake_quant_slice(xs: &mut [f32], scale: f32, bits: u32) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let qmin = -qmax - 1.0;
    let s = scale.max(1e-12);
    for v in xs.iter_mut() {
        *v = (*v / s).round().clamp(qmin, qmax) * s;
    }
}

/// Per-output-channel (column) weight RTN, returns fake-quantized weights.
pub fn fake_quant_weights(w: &Matrix, bits: u32) -> Matrix {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut scales = vec![0.0f32; w.cols];
    for r in 0..w.rows {
        for (c, &v) in w.row(r).iter().enumerate() {
            scales[c] = scales[c].max(v.abs());
        }
    }
    let mut out = w.clone();
    for r in 0..out.rows {
        let row = &mut out.data[r * w.cols..(r + 1) * w.cols];
        for (c, v) in row.iter_mut().enumerate() {
            let s = (scales[c] / qmax).max(1e-12);
            *v = (*v / s).round().clamp(-qmax - 1.0, qmax) * s;
        }
    }
    out
}

/// Per-token activation RTN (max-abs scale over the token).
pub fn fake_quant_token(tok: &mut [f32], bits: u32) {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let m = tok.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    fake_quant_slice(tok, m / qmax, bits);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn int4_grid() {
        let mut x = vec![0.05f32, -0.9, 0.51, 1.0];
        fake_quant_token(&mut x, 4);
        // grid step = 1/7; every value must be a multiple of it
        for v in &x {
            let q = v / (1.0 / 7.0);
            assert!((q - q.round()).abs() < 1e-5, "{v}");
        }
    }

    #[test]
    fn weights_error_reasonable_without_outliers() {
        let mut rng = Rng::new(1);
        let w = Matrix::random_normal(64, 32, 1.0, &mut rng);
        let q = fake_quant_weights(&w, 4);
        assert!(q.rel_err(&w) < 0.12);
    }

    #[test]
    fn outliers_wreck_rtn() {
        // The Table III failure mode: one huge value blows up the scale and
        // the inliers lose all resolution.
        let mut rng = Rng::new(2);
        let mut tok = rng.normal_vec(256, 1.0);
        let clean_err = {
            let mut t = tok.clone();
            fake_quant_token(&mut t, 4);
            rel_err(&tok, &t)
        };
        tok[0] = 200.0;
        let mut t = tok.clone();
        fake_quant_token(&mut t, 4);
        let dirty_err = rel_err(&tok[1..], &t[1..]);
        assert!(dirty_err > 5.0 * clean_err, "{dirty_err} vs {clean_err}");
    }

    fn rel_err(a: &[f32], b: &[f32]) -> f64 {
        let num: f64 = a
            .iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        num / a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }
}
