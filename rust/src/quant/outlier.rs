//! Activation-outlier identification (paper §III-A, §II-C).
//!
//! Dynamic mode (OASIS): top p/2 % largest and bottom p/2 % smallest values
//! of each token are outliers — in hardware this is Orizuru's job; here a
//! select_nth-based reference implements the same semantics for the
//! algorithm library (the orizuru module provides the hardware-faithful
//! engine and is cross-checked against this).
//!
//! Static mode (OASIS-S): per-layer (lo, hi) thresholds learned on a
//! calibration corpus; online values beyond the thresholds are outliers.

/// Outlier selection config: total outlier fraction (e.g. 0.01 = paper's
/// "top 0.5% + bottom 0.5%").
#[derive(Clone, Copy, Debug)]
pub struct OutlierCfg {
    pub total_frac: f64,
}

impl Default for OutlierCfg {
    fn default() -> Self {
        OutlierCfg { total_frac: 0.01 }
    }
}

impl OutlierCfg {
    /// Outliers per side for a token of dimension `d` (>= 1, as the paper
    /// always emits exactly k per side).
    pub fn k_per_side(&self, d: usize) -> usize {
        ((self.total_frac * 0.5 * d as f64).round() as usize).max(1)
    }
}

/// Indices of the k largest and k smallest elements (dynamic detection).
/// Deterministic tie-breaking: lower index wins, mirroring Orizuru's
/// left-child-first rule.
pub fn topk_outliers(x: &[f32], k_per_side: usize) -> Vec<u32> {
    let n = x.len();
    let k = k_per_side.min(n / 2);
    if k == 0 {
        return Vec::new();
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    // full argsort is O(n log n) but simple; the hardware path (orizuru)
    // is the optimized one. Stable comparator: value, then index.
    order.sort_by(|&a, &b| {
        x[a as usize]
            .partial_cmp(&x[b as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut out: Vec<u32> = Vec::with_capacity(2 * k);
    out.extend_from_slice(&order[..k]); // k smallest
    out.extend_from_slice(&order[n - k..]); // k largest
    out.sort_unstable();
    out
}

/// Static thresholds from calibration tokens: the value of the k-th
/// largest / k-th smallest element, averaged across calibration tokens
/// (this is exactly the "upper/lower outlier threshold" of Fig 3).
pub fn calibrate_thresholds(tokens: &[&[f32]], cfg: OutlierCfg) -> (f32, f32) {
    assert!(!tokens.is_empty());
    let mut lo_sum = 0.0f64;
    let mut hi_sum = 0.0f64;
    for &t in tokens {
        let k = cfg.k_per_side(t.len());
        let mut v = t.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        lo_sum += v[k - 1] as f64; // k-th smallest
        hi_sum += v[v.len() - k] as f64; // k-th largest
    }
    (
        (lo_sum / tokens.len() as f64) as f32,
        (hi_sum / tokens.len() as f64) as f32,
    )
}

/// Upper outlier threshold of a single token (value of the k-th largest),
/// used by the Fig 3 experiment.
pub fn upper_threshold(token: &[f32], cfg: OutlierCfg) -> f32 {
    let k = cfg.k_per_side(token.len());
    let mut v = token.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() - k]
}

/// Static-mode outlier indices: beyond calibrated thresholds.
pub fn static_outliers(x: &[f32], lo: f32, hi: f32) -> Vec<u32> {
    x.iter()
        .enumerate()
        .filter(|(_, &v)| v < lo || v > hi)
        .map(|(i, _)| i as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn finds_planted_outliers() {
        let mut rng = Rng::new(1);
        let mut x = rng.normal_vec(1024, 1.0);
        x[17] = 50.0;
        x[900] = -60.0;
        let out = topk_outliers(&x, 1);
        assert_eq!(out, vec![17, 900]);
    }

    #[test]
    fn exact_count_even_with_ties() {
        let x = vec![1.0f32; 64]; // all tied
        let out = topk_outliers(&x, 3);
        assert_eq!(out.len(), 6);
        // deterministic: lowest indices on the small side, ... and the
        // largest side picks the highest sorted-stable indices
        assert_eq!(&out[..3], &[0, 1, 2]);
    }

    #[test]
    fn k_per_side_matches_paper_ratio() {
        let cfg = OutlierCfg { total_frac: 0.01 };
        assert_eq!(cfg.k_per_side(4096), 20); // 0.5% of 4096 = 20.48 -> 20
        assert_eq!(cfg.k_per_side(64), 1); // floor of >= 1
    }

    #[test]
    fn static_thresholds_catch_tail() {
        let mut rng = Rng::new(2);
        let calib: Vec<Vec<f32>> = (0..16).map(|_| rng.normal_vec(512, 1.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let (lo, hi) = calibrate_thresholds(&refs, OutlierCfg { total_frac: 0.02 });
        assert!(lo < 0.0 && hi > 0.0 && hi > lo);
        let x = rng.normal_vec(512, 1.0);
        let outs = static_outliers(&x, lo, hi);
        // roughly 2% of 512 = ~10, very loose tolerance
        assert!(!outs.is_empty() && outs.len() < 60, "{}", outs.len());
    }

    #[test]
    fn dynamic_equals_static_on_calibration_distribution_roughly() {
        // sanity: on the same distribution the two modes select similar
        // counts (the paper's Fig 3 point is that they differ across
        // distribution shift, tested in eval::experiments).
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(2048, 1.0);
        let cfg = OutlierCfg { total_frac: 0.01 };
        let dynamic = topk_outliers(&x, cfg.k_per_side(2048));
        let calib: Vec<Vec<f32>> = (0..32).map(|_| rng.normal_vec(2048, 1.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let (lo, hi) = calibrate_thresholds(&refs, cfg);
        let stat = static_outliers(&x, lo, hi);
        let ratio = stat.len() as f64 / dynamic.len() as f64;
        assert!(ratio > 0.2 && ratio < 5.0, "ratio {ratio}");
    }
}
