//! Weight-side K-Means quantization (paper §III-A): one shared codebook for
//! the whole matrix, per-output-channel scaling factors, no outlier
//! protection. Produces both the index/codebook form consumed by the WAQ
//! LUT-GEMM datapath and the fake-quant (dequantized) form fed to the L2
//! artifacts for accuracy experiments.

use super::codebook::Codebook;
use super::kmeans::weighted_kmeans_1d;
use crate::tensor::Matrix;

/// K-Means-quantized weight matrix W (K x N), y = x @ W.
/// Output channel n has scale `col_scales[n]`; `idx[k * n_cols + n]` selects
/// from the shared normalized `codebook`.
#[derive(Clone, Debug)]
pub struct QuantWeights {
    pub n_rows: usize, // K (input channels / reduction dim)
    pub n_cols: usize, // N (output channels)
    pub idx: Vec<u8>,
    pub codebook: Codebook,
    pub col_scales: Vec<f32>,
}

/// Max samples fed to the codebook learner (uniform stride subsample keeps
/// calibration O(1) regardless of layer size).
const MAX_KMEANS_SAMPLES: usize = 65_536;

pub fn quantize_weights(w: &Matrix, bits: u32) -> QuantWeights {
    quantize_weights_weighted(w, None, bits)
}

/// `fisher`: optional per-element sensitivity (same layout as w.data).
pub fn quantize_weights_weighted(
    w: &Matrix,
    fisher: Option<&Matrix>,
    bits: u32,
) -> QuantWeights {
    let (k, n) = (w.rows, w.cols);
    // per-output-channel max-abs scale
    let mut col_scales = vec![0.0f32; n];
    for r in 0..k {
        for (c, &v) in w.row(r).iter().enumerate() {
            col_scales[c] = col_scales[c].max(v.abs());
        }
    }
    for s in col_scales.iter_mut() {
        *s = s.max(1e-12);
    }

    // normalized samples for the shared codebook
    let total = k * n;
    let stride = (total / MAX_KMEANS_SAMPLES).max(1);
    let mut samples = Vec::with_capacity(total / stride + 1);
    let mut weights = fisher.map(|_| Vec::with_capacity(total / stride + 1));
    let mut i = 0;
    while i < total {
        let (r, c) = (i / n, i % n);
        samples.push(w.data[i] / col_scales[c]);
        if let (Some(ws), Some(f)) = (weights.as_mut(), fisher) {
            ws.push(f.data[i]);
        }
        i += stride;
        let _ = r;
    }
    let centroids = weighted_kmeans_1d(&samples, weights.as_deref(), 1 << bits, 40);
    let codebook = Codebook::new(centroids);

    let mut idx = Vec::with_capacity(total);
    for r in 0..k {
        for (c, &v) in w.row(r).iter().enumerate() {
            idx.push(codebook.assign(v / col_scales[c]));
        }
    }
    QuantWeights { n_rows: k, n_cols: n, idx, codebook, col_scales }
}

impl QuantWeights {
    /// Dequantize to a dense matrix (the fake-quant form for L2 artifacts,
    /// and the Dequantization-Unit model for the outlier branch).
    pub fn dequantize(&self) -> Matrix {
        let mut data = Vec::with_capacity(self.idx.len());
        for (i, &q) in self.idx.iter().enumerate() {
            let c = i % self.n_cols;
            data.push(self.codebook.value(q) * self.col_scales[c]);
        }
        Matrix::from_vec(self.n_rows, self.n_cols, data)
    }

    /// Dequantize one input-channel row (what the error-compensation branch
    /// fetches per outlier channel, paper §III-C2).
    pub fn dequant_row(&self, k: usize, out: &mut Vec<f32>) {
        out.clear();
        let row = &self.idx[k * self.n_cols..(k + 1) * self.n_cols];
        out.extend(
            row.iter()
                .enumerate()
                .map(|(c, &q)| self.codebook.value(q) * self.col_scales[c]),
        );
    }

    pub fn bits(&self) -> u32 {
        self.codebook.bits()
    }

    /// Bytes to store idx at `bits` packing + codebook + scales (memory
    /// footprint accounting for the simulator).
    pub fn storage_bytes(&self) -> usize {
        let idx_bits = self.idx.len() * self.bits() as usize;
        idx_bits.div_ceil(8) + self.codebook.len() * 2 + self.col_scales.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_small_at_4bit() {
        let mut rng = Rng::new(1);
        let w = Matrix::random_normal(64, 32, 0.05, &mut rng);
        let q = quantize_weights(&w, 4);
        let deq = q.dequantize();
        let err = deq.rel_err(&w);
        assert!(err < 0.10, "4-bit kmeans rel err {err}");
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::random_normal(48, 48, 1.0, &mut rng);
        let e3 = quantize_weights(&w, 3).dequantize().rel_err(&w);
        let e4 = quantize_weights(&w, 4).dequantize().rel_err(&w);
        assert!(e4 < e3, "e4={e4} e3={e3}");
    }

    #[test]
    fn per_channel_scaling_handles_mixed_magnitudes() {
        let mut rng = Rng::new(3);
        let mut w = Matrix::random_normal(32, 8, 1.0, &mut rng);
        w.scale_cols(&[1.0, 10.0, 100.0, 0.1, 1.0, 5.0, 0.01, 1.0]);
        let q = quantize_weights(&w, 4);
        let err = q.dequantize().rel_err(&w);
        assert!(err < 0.1, "channel-scaled rel err {err}");
    }

    #[test]
    fn dequant_row_matches_full() {
        let mut rng = Rng::new(4);
        let w = Matrix::random_normal(16, 12, 1.0, &mut rng);
        let q = quantize_weights(&w, 4);
        let full = q.dequantize();
        let mut row = Vec::new();
        q.dequant_row(5, &mut row);
        assert_eq!(row.as_slice(), full.row(5));
    }

    #[test]
    fn fisher_weighting_prioritizes_sensitive_entries() {
        let mut rng = Rng::new(5);
        let w = Matrix::random_normal(64, 16, 1.0, &mut rng);
        // mark a band of entries as highly sensitive
        let mut fisher = Matrix::zeros(64, 16);
        for i in 0..fisher.data.len() {
            fisher.data[i] = if w.data[i].abs() > 1.5 { 100.0 } else { 0.01 };
        }
        let qw = quantize_weights_weighted(&w, Some(&fisher), 3);
        let qu = quantize_weights(&w, 3);
        let err = |q: &QuantWeights| -> f64 {
            let d = q.dequantize();
            let mut e = 0.0f64;
            for i in 0..d.data.len() {
                if fisher.data[i] > 1.0 {
                    e += ((d.data[i] - w.data[i]) as f64).powi(2);
                }
            }
            e
        };
        assert!(err(&qw) <= err(&qu) + 1e-9);
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Rng::new(6);
        let w = Matrix::random_normal(128, 64, 1.0, &mut rng);
        let q = quantize_weights(&w, 4);
        // 128*64 4-bit indices = 4096 B, + 16 fp16 centroids + 64 fp16 scales
        assert_eq!(q.storage_bytes(), 4096 + 32 + 128);
    }
}
