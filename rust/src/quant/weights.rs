//! Weight-side K-Means quantization (paper §III-A): one shared codebook for
//! the whole matrix, per-output-channel scaling factors, no outlier
//! protection. Produces both the index/codebook form consumed by the WAQ
//! LUT-GEMM datapath and the fake-quant (dequantized) form fed to the L2
//! artifacts for accuracy experiments.
//!
//! Two extensions ride on the same representation (PAPERS.md):
//!
//! * FineQuant-style per-group scales ([`quantize_weights_grouped`]) —
//!   each `group_size`-row block of the reduction dimension carries its
//!   own per-column scale factor, so small (2-/3-bit) codebooks only have
//!   to cover one group's dynamic range at a time. The codebook stays
//!   shared across the matrix (the LUT-GEMM kernel needs one table per
//!   matrix); the group factor folds into the kernel's per-group
//!   accumulator instead.
//! * SKIM-style any-bit planning ([`plan_bits`]) — given measured
//!   per-linear sensitivity at 2/3/4 bits, assign a width per linear
//!   against an average-bits budget.

use super::codebook::Codebook;
use super::kmeans::weighted_kmeans_1d;
use crate::tensor::Matrix;

/// K-Means-quantized weight matrix W (K x N), y = x @ W.
/// Output channel n has scale `col_scales[n]`; `idx[k * n_cols + n]` selects
/// from the shared normalized `codebook`. When `group_size > 0`, entry
/// (k, n) additionally carries the factor
/// `group_scales[(k / group_size) * n_cols + n]`.
#[derive(Clone, Debug)]
pub struct QuantWeights {
    pub n_rows: usize, // K (input channels / reduction dim)
    pub n_cols: usize, // N (output channels)
    pub idx: Vec<u8>,
    pub codebook: Codebook,
    pub col_scales: Vec<f32>,
    /// Reduction rows per FineQuant scale group; 0 = whole-column scaling.
    pub group_size: usize,
    /// `n_groups * n_cols` per-group factors (row-major by group); empty
    /// when `group_size == 0`.
    pub group_scales: Vec<f32>,
}

/// Max samples fed to the codebook learner (uniform stride subsample keeps
/// calibration O(1) regardless of layer size).
const MAX_KMEANS_SAMPLES: usize = 65_536;

pub fn quantize_weights(w: &Matrix, bits: u32) -> QuantWeights {
    quantize_weights_weighted(w, None, bits)
}

/// `fisher`: optional per-element sensitivity (same layout as w.data).
pub fn quantize_weights_weighted(
    w: &Matrix,
    fisher: Option<&Matrix>,
    bits: u32,
) -> QuantWeights {
    let (k, n) = (w.rows, w.cols);
    // per-output-channel max-abs scale
    let mut col_scales = vec![0.0f32; n];
    for r in 0..k {
        for (c, &v) in w.row(r).iter().enumerate() {
            col_scales[c] = col_scales[c].max(v.abs());
        }
    }
    for s in col_scales.iter_mut() {
        *s = s.max(1e-12);
    }

    // normalized samples for the shared codebook
    let total = k * n;
    let stride = (total / MAX_KMEANS_SAMPLES).max(1);
    let mut samples = Vec::with_capacity(total / stride + 1);
    let mut weights = fisher.map(|_| Vec::with_capacity(total / stride + 1));
    let mut i = 0;
    while i < total {
        let c = i % n;
        samples.push(w.data[i] / col_scales[c]);
        if let (Some(ws), Some(f)) = (weights.as_mut(), fisher) {
            ws.push(f.data[i]);
        }
        i += stride;
    }
    let centroids = weighted_kmeans_1d(&samples, weights.as_deref(), 1 << bits, 40);
    let codebook = Codebook::new(centroids);

    let mut idx = Vec::with_capacity(total);
    for r in 0..k {
        for (c, &v) in w.row(r).iter().enumerate() {
            idx.push(codebook.assign(v / col_scales[c]));
        }
    }
    QuantWeights {
        n_rows: k,
        n_cols: n,
        idx,
        codebook,
        col_scales,
        group_size: 0,
        group_scales: Vec::new(),
    }
}

/// FineQuant-style fine-grained quantization: on top of the per-column
/// scale, each `group_size`-row reduction block gets its own per-column
/// factor (the block's max-abs relative to the column scale), and the
/// shared codebook is learned over group-normalized values.
/// `group_size == 0` is the ungrouped path, bit-identical to
/// [`quantize_weights_weighted`].
pub fn quantize_weights_grouped(
    w: &Matrix,
    fisher: Option<&Matrix>,
    bits: u32,
    group_size: usize,
) -> QuantWeights {
    if group_size == 0 {
        return quantize_weights_weighted(w, fisher, bits);
    }
    // group boundaries must land on packed body-chunk boundaries (2 rows
    // per byte at nibble widths, 4 at crumb width) so the packed kernel's
    // per-group accumulation never splits a byte
    assert!(group_size % 4 == 0, "group size must be a multiple of 4, got {group_size}");
    let (k, n) = (w.rows, w.cols);
    let mut col_scales = vec![0.0f32; n];
    for r in 0..k {
        for (c, &v) in w.row(r).iter().enumerate() {
            col_scales[c] = col_scales[c].max(v.abs());
        }
    }
    for s in col_scales.iter_mut() {
        *s = s.max(1e-12);
    }

    // per-group per-column max-abs, relative to the column scale
    let n_groups = k.div_ceil(group_size);
    let mut group_scales = vec![0.0f32; n_groups * n];
    for r in 0..k {
        let g = r / group_size;
        for (c, &v) in w.row(r).iter().enumerate() {
            let gs = &mut group_scales[g * n + c];
            *gs = gs.max(v.abs() / col_scales[c]);
        }
    }
    for s in group_scales.iter_mut() {
        *s = s.max(1e-12);
    }

    let total = k * n;
    let stride = (total / MAX_KMEANS_SAMPLES).max(1);
    let mut samples = Vec::with_capacity(total / stride + 1);
    let mut weights = fisher.map(|_| Vec::with_capacity(total / stride + 1));
    let mut i = 0;
    while i < total {
        let (r, c) = (i / n, i % n);
        samples.push(w.data[i] / (col_scales[c] * group_scales[(r / group_size) * n + c]));
        if let (Some(ws), Some(f)) = (weights.as_mut(), fisher) {
            ws.push(f.data[i]);
        }
        i += stride;
    }
    let centroids = weighted_kmeans_1d(&samples, weights.as_deref(), 1 << bits, 40);
    let codebook = Codebook::new(centroids);

    let mut idx = Vec::with_capacity(total);
    for r in 0..k {
        let g = r / group_size;
        for (c, &v) in w.row(r).iter().enumerate() {
            idx.push(codebook.assign(v / (col_scales[c] * group_scales[g * n + c])));
        }
    }
    QuantWeights { n_rows: k, n_cols: n, idx, codebook, col_scales, group_size, group_scales }
}

/// Solve the per-linear bit assignment against an average-bits budget
/// (SKIM-style greedy). `mse[i][b]` is the measured sensitivity of linear
/// `i` quantized at width `2 + b`; `params[i]` its parameter count; the
/// returned plan's parameter-weighted average width never exceeds
/// `budget`. Starts everything at 2 bits and repeatedly upgrades the
/// linear with the best sensitivity drop per parameter of added storage.
/// The greedy result is then guarded against every feasible *uniform*
/// plan — whichever has the lower total sensitivity wins — so
/// `--wbits auto --wbits-budget B` is never less accurate than
/// `--wbits floor(B)` on the same sensitivity table.
pub fn plan_bits(mse: &[[f64; 3]], params: &[usize], budget: f64) -> Vec<u32> {
    assert_eq!(mse.len(), params.len(), "one sensitivity triple per linear");
    if mse.is_empty() {
        return Vec::new();
    }
    let total: f64 = params.iter().map(|&p| p as f64).sum();
    let score =
        |plan: &[u32]| -> f64 { plan.iter().zip(mse).map(|(&b, m)| m[b as usize - 2]).sum() };

    let mut plan = vec![2u32; mse.len()];
    let mut bit_mass = 2.0 * total;
    loop {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..plan.len() {
            if plan[i] >= 4 {
                continue;
            }
            if (bit_mass + params[i] as f64) / total > budget + 1e-9 {
                continue;
            }
            let step = plan[i] as usize - 2;
            let gain = mse[i][step] - mse[i][step + 1];
            if gain <= 0.0 {
                continue;
            }
            let per_param = gain / params[i] as f64;
            if best.map_or(true, |(_, g)| per_param > g) {
                best = Some((i, per_param));
            }
        }
        let Some((i, _)) = best else { break };
        plan[i] += 1;
        bit_mass += params[i] as f64;
    }

    // greedy can lose to a uniform plan on adversarial sensitivity tables
    // (a cheap upgrade taken early can crowd out a better expensive one)
    let mut best_plan = plan;
    for u in [2u32, 3, 4] {
        if (u as f64) <= budget + 1e-9 {
            let uniform = vec![u; mse.len()];
            if score(&uniform) < score(&best_plan) {
                best_plan = uniform;
            }
        }
    }
    best_plan
}

impl QuantWeights {
    /// Dequantize to a dense matrix (the fake-quant form for L2 artifacts,
    /// and the Dequantization-Unit model for the outlier branch).
    pub fn dequantize(&self) -> Matrix {
        let mut data = Vec::with_capacity(self.idx.len());
        for (i, &q) in self.idx.iter().enumerate() {
            let (r, c) = (i / self.n_cols, i % self.n_cols);
            let mut v = self.codebook.value(q) * self.col_scales[c];
            if !self.group_scales.is_empty() {
                v *= self.group_scales[(r / self.group_size) * self.n_cols + c];
            }
            data.push(v);
        }
        Matrix::from_vec(self.n_rows, self.n_cols, data)
    }

    /// Dequantize one input-channel row (what the error-compensation branch
    /// fetches per outlier channel, paper §III-C2).
    pub fn dequant_row(&self, k: usize, out: &mut Vec<f32>) {
        out.clear();
        let row = &self.idx[k * self.n_cols..(k + 1) * self.n_cols];
        let gs = if self.group_scales.is_empty() {
            None
        } else {
            let g = k / self.group_size;
            Some(&self.group_scales[g * self.n_cols..(g + 1) * self.n_cols])
        };
        out.extend(row.iter().enumerate().map(|(c, &q)| {
            let v = self.codebook.value(q) * self.col_scales[c];
            match gs {
                Some(gs) => v * gs[c],
                None => v,
            }
        }));
    }

    pub fn bits(&self) -> u32 {
        self.codebook.bits()
    }

    /// Number of reduction-dim scale groups (1 when ungrouped).
    pub fn n_groups(&self) -> usize {
        if self.group_size == 0 {
            1
        } else {
            self.n_rows.div_ceil(self.group_size)
        }
    }

    /// Bytes to store idx at `bits` packing + codebook + scales (memory
    /// footprint accounting for the simulator; the per-group grid is
    /// FP16-accounted like the per-column scales).
    pub fn storage_bytes(&self) -> usize {
        let idx_bits = self.idx.len() * self.bits() as usize;
        idx_bits.div_ceil(8)
            + self.codebook.len() * 2
            + self.col_scales.len() * 2
            + self.group_scales.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_small_at_4bit() {
        let mut rng = Rng::new(1);
        let w = Matrix::random_normal(64, 32, 0.05, &mut rng);
        let q = quantize_weights(&w, 4);
        let deq = q.dequantize();
        let err = deq.rel_err(&w);
        assert!(err < 0.10, "4-bit kmeans rel err {err}");
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(2);
        let w = Matrix::random_normal(48, 48, 1.0, &mut rng);
        let e3 = quantize_weights(&w, 3).dequantize().rel_err(&w);
        let e4 = quantize_weights(&w, 4).dequantize().rel_err(&w);
        assert!(e4 < e3, "e4={e4} e3={e3}");
    }

    #[test]
    fn per_channel_scaling_handles_mixed_magnitudes() {
        let mut rng = Rng::new(3);
        let mut w = Matrix::random_normal(32, 8, 1.0, &mut rng);
        w.scale_cols(&[1.0, 10.0, 100.0, 0.1, 1.0, 5.0, 0.01, 1.0]);
        let q = quantize_weights(&w, 4);
        let err = q.dequantize().rel_err(&w);
        assert!(err < 0.1, "channel-scaled rel err {err}");
    }

    #[test]
    fn dequant_row_matches_full() {
        let mut rng = Rng::new(4);
        let w = Matrix::random_normal(16, 12, 1.0, &mut rng);
        let q = quantize_weights(&w, 4);
        let full = q.dequantize();
        let mut row = Vec::new();
        q.dequant_row(5, &mut row);
        assert_eq!(row.as_slice(), full.row(5));
    }

    #[test]
    fn fisher_weighting_prioritizes_sensitive_entries() {
        let mut rng = Rng::new(5);
        let w = Matrix::random_normal(64, 16, 1.0, &mut rng);
        // mark a band of entries as highly sensitive
        let mut fisher = Matrix::zeros(64, 16);
        for i in 0..fisher.data.len() {
            fisher.data[i] = if w.data[i].abs() > 1.5 { 100.0 } else { 0.01 };
        }
        let qw = quantize_weights_weighted(&w, Some(&fisher), 3);
        let qu = quantize_weights(&w, 3);
        let err = |q: &QuantWeights| -> f64 {
            let d = q.dequantize();
            let mut e = 0.0f64;
            for i in 0..d.data.len() {
                if fisher.data[i] > 1.0 {
                    e += ((d.data[i] - w.data[i]) as f64).powi(2);
                }
            }
            e
        };
        assert!(err(&qw) <= err(&qu) + 1e-9);
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Rng::new(6);
        let w = Matrix::random_normal(128, 64, 1.0, &mut rng);
        let q = quantize_weights(&w, 4);
        // 128*64 4-bit indices = 4096 B, + 16 fp16 centroids + 64 fp16 scales
        assert_eq!(q.storage_bytes(), 4096 + 32 + 128);
        // per-group scales are accounted on top: 128/32 groups x 64 cols
        let g = quantize_weights_grouped(&w, None, 4, 32);
        assert_eq!(g.storage_bytes(), 4096 + 32 + 128 + 4 * 64 * 2);
    }

    #[test]
    fn group_size_zero_is_the_ungrouped_path() {
        let mut rng = Rng::new(7);
        let w = Matrix::random_normal(24, 10, 1.0, &mut rng);
        let a = quantize_weights(&w, 3);
        let b = quantize_weights_grouped(&w, None, 3, 0);
        assert_eq!(a.idx, b.idx);
        assert_eq!(a.col_scales, b.col_scales);
        assert_eq!(a.codebook, b.codebook);
        assert_eq!(b.group_size, 0);
        assert!(b.group_scales.is_empty());
        assert_eq!(b.n_groups(), 1);
    }

    #[test]
    fn group_scales_recover_small_magnitude_blocks() {
        // FineQuant's motivating case: one reduction block is 100x
        // smaller than the rest; with one scale per column, a 2-bit
        // codebook spends its codewords on the large block and flattens
        // the small one. Per-group scales renormalize each block.
        let mut rng = Rng::new(8);
        let mut w = Matrix::random_normal(64, 12, 1.0, &mut rng);
        for r in 0..16 {
            for v in w.row_mut(r) {
                *v *= 0.01;
            }
        }
        let e_flat = quantize_weights(&w, 2).dequantize().rel_err(&w);
        let e_grouped = quantize_weights_grouped(&w, None, 2, 16).dequantize().rel_err(&w);
        assert!(
            e_grouped < e_flat,
            "grouped 2-bit {e_grouped} should beat ungrouped {e_flat}"
        );
    }

    #[test]
    fn grouped_dequant_row_matches_full() {
        let mut rng = Rng::new(9);
        let w = Matrix::random_normal(21, 8, 1.0, &mut rng);
        let q = quantize_weights_grouped(&w, None, 3, 8);
        let full = q.dequantize();
        let mut row = Vec::new();
        for r in 0..21 {
            q.dequant_row(r, &mut row);
            assert_eq!(row.as_slice(), full.row(r), "row {r}");
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 4")]
    fn grouped_rejects_unaligned_group_size() {
        let mut rng = Rng::new(10);
        let w = Matrix::random_normal(8, 4, 1.0, &mut rng);
        quantize_weights_grouped(&w, None, 2, 6);
    }

    #[test]
    fn plan_bits_respects_budget_and_spends_on_sensitivity() {
        // linear 0 barely cares about width, linear 1 collapses below 4
        // bits; both same size
        let mse = [[0.010, 0.009, 0.008], [10.0, 4.0, 0.1]];
        let params = [1000, 1000];
        let plan = plan_bits(&mse, &params, 3.0);
        assert_eq!(plan, vec![2, 4], "budget goes to the sensitive linear");
        // parameter-weighted average stays within budget
        let avg: f64 = plan.iter().zip(&params).map(|(&b, &p)| b as f64 * p as f64).sum::<f64>()
            / params.iter().map(|&p| p as f64).sum::<f64>();
        assert!(avg <= 3.0 + 1e-9);
        // tight budget pins everything at the floor; loose budget at the cap
        assert_eq!(plan_bits(&mse, &params, 2.0), vec![2, 2]);
        assert_eq!(plan_bits(&mse, &params, 4.0), vec![4, 4]);
    }

    #[test]
    fn plan_bits_weighs_parameter_cost() {
        // equal sensitivity gain, but linear 1 is 10x cheaper to upgrade —
        // with budget for only one upgrade step of the large linear, the
        // small one must win on gain-per-parameter
        let mse = [[1.0, 0.5, 0.2], [1.0, 0.5, 0.2]];
        let params = [10_000, 1_000];
        let plan = plan_bits(&mse, &params, 2.2);
        assert_eq!(plan, vec![2, 4], "cheap linear upgraded first");
    }

    #[test]
    fn plan_bits_never_loses_to_uniform_at_equal_budget() {
        // adversarial table: greedy's first upgrade (linear 0, huge
        // per-param gain) burns budget the uniform-3 plan spends better
        let mse = [[5.0, 0.1, 0.1], [4.0, 0.5, 0.4], [4.0, 0.5, 0.4], [4.0, 0.5, 0.4]];
        let params = [100, 100, 100, 100];
        let plan = plan_bits(&mse, &params, 3.0);
        let score = |p: &[u32]| -> f64 {
            p.iter().zip(&mse).map(|(&b, m)| m[b as usize - 2]).sum()
        };
        assert!(
            score(&plan) <= score(&vec![3u32; 4]) + 1e-12,
            "auto plan {:?} (score {}) must not lose to uniform 3-bit ({})",
            plan,
            score(&plan),
            score(&vec![3u32; 4])
        );
    }
}
