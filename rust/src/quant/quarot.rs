//! QuaRot baseline: rotate weights/activations with an orthonormal Hadamard
//! so outlier energy spreads across channels, then RTN. Online, the L2
//! `eval_quarot_*` artifacts apply H to activations; here we pre-rotate the
//! weights (H^T W along the input dimension) and fake-quantize.

use super::rtn;
use crate::tensor::Matrix;

/// Rotate W (K x N) along the input dim: returns H^T W = H W (H symmetric).
pub fn rotate_weights(w: &Matrix) -> Matrix {
    // hadamard_rows transforms along rows; transpose twice to hit K.
    let mut wt = w.transpose(); // (N x K)
    wt.hadamard_rows();
    wt.transpose()
}

/// Full QuaRot weight path: rotate then per-channel RTN fake-quant.
pub fn quarot_quantize(w: &Matrix, bits: u32) -> Matrix {
    rtn::fake_quant_weights(&rotate_weights(w), bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rotation_preserves_gemm() {
        // (x H) @ (H^T W) == x @ W
        let mut rng = Rng::new(1);
        let w = Matrix::random_normal(64, 16, 1.0, &mut rng);
        let x = Matrix::random_normal(4, 64, 1.0, &mut rng);
        let wr = rotate_weights(&w);
        let mut xr = x.clone();
        xr.hadamard_rows();
        assert!(xr.matmul(&wr).rel_err(&x.matmul(&w)) < 1e-4);
    }

    #[test]
    fn rotation_plus_rtn_beats_plain_rtn_on_outliers() {
        let mut rng = Rng::new(2);
        // weights with a few outlier rows (input channels)
        let mut w = Matrix::random_normal(128, 32, 1.0, &mut rng);
        for c in 0..32 {
            *w.at_mut(7, c) *= 30.0;
        }
        let plain = rtn::fake_quant_weights(&w, 4);
        let rot = quarot_quantize(&w, 4);
        // compare in the GEMM output domain with rotated activations
        let x = Matrix::random_normal(8, 128, 1.0, &mut rng);
        let mut xr = x.clone();
        xr.hadamard_rows();
        let want = x.matmul(&w);
        let e_plain = x.matmul(&plain).rel_err(&want);
        let e_rot = xr.matmul(&rot).rel_err(&want);
        assert!(e_rot < e_plain, "rot {e_rot} !< plain {e_plain}");
    }
}
