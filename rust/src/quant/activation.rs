//! Token-wise activation quantization (paper §III-A): offline-learned
//! normalized codebook (optionally Fisher-weighted), per-token max-|inlier|
//! scale, and FP-preserved outliers (dynamic or static selection).

use super::codebook::Codebook;
use super::kmeans::weighted_kmeans_1d;
use super::outlier::{static_outliers, topk_outliers, OutlierCfg};

/// A quantized activation token: inlier indices + per-token scale +
/// FP-preserved outliers (channel, original value, quantization residual).
#[derive(Clone, Debug)]
pub struct QuantToken {
    pub idx: Vec<u8>,
    pub scale: f32,
    /// (channel, fp_value, residual = fp_value - dequant(idx[channel]))
    pub outliers: Vec<(u32, f32, f32)>,
}

/// Learn the normalized activation codebook from calibration tokens.
/// `fisher`: per-element sensitivity aligned with the flattened samples
/// (the paper's Fisher-weighted K-Means).
pub fn learn_act_codebook(
    calib_tokens: &[&[f32]],
    fisher: Option<&[f32]>,
    bits: u32,
    cfg: OutlierCfg,
) -> Codebook {
    // Normalize each token by its inlier scale, pool, then k-means.
    let mut samples = Vec::new();
    let mut weights = fisher.map(|_| Vec::new());
    let mut offset = 0usize;
    for &tok in calib_tokens {
        let k = cfg.k_per_side(tok.len());
        let outs = topk_outliers(tok, k);
        let scale = inlier_scale(tok, &outs);
        let mut oi = 0usize;
        for (c, &v) in tok.iter().enumerate() {
            if oi < outs.len() && outs[oi] as usize == c {
                oi += 1;
                continue; // outliers don't shape the codebook
            }
            samples.push(v / scale);
            if let (Some(w), Some(f)) = (weights.as_mut(), fisher) {
                w.push(f[offset + c]);
            }
        }
        offset += tok.len();
    }
    Codebook::new(weighted_kmeans_1d(&samples, weights.as_deref(), 1 << bits, 40))
}

fn inlier_scale(tok: &[f32], outlier_idx: &[u32]) -> f32 {
    let mut oi = 0usize;
    let mut m = 0.0f32;
    for (c, &v) in tok.iter().enumerate() {
        if oi < outlier_idx.len() && outlier_idx[oi] as usize == c {
            oi += 1;
            continue;
        }
        m = m.max(v.abs());
    }
    m.max(1e-12)
}

/// Quantize one token with dynamic (top-k) outlier detection.
pub fn quantize_token(tok: &[f32], cb: &Codebook, cfg: OutlierCfg) -> QuantToken {
    let k = cfg.k_per_side(tok.len());
    let outs = topk_outliers(tok, k);
    quantize_with_outliers(tok, cb, &outs)
}

/// Quantize one token with static thresholds (OASIS-S).
pub fn quantize_token_static(tok: &[f32], cb: &Codebook, lo: f32, hi: f32) -> QuantToken {
    let outs = static_outliers(tok, lo, hi);
    quantize_with_outliers(tok, cb, &outs)
}

/// Quantize one token with an externally supplied (sorted, deduplicated)
/// outlier channel set — the serving datapath, where detection runs in the
/// Orizuru engine (`orizuru::detect_outliers`) rather than the reference
/// top-k selector.
pub fn quantize_token_with_outliers(tok: &[f32], cb: &Codebook, outs: &[u32]) -> QuantToken {
    quantize_with_outliers(tok, cb, outs)
}

fn quantize_with_outliers(tok: &[f32], cb: &Codebook, outs: &[u32]) -> QuantToken {
    let scale = inlier_scale(tok, outs);
    // Look-ahead semantics (paper §III-C1): the WHOLE token is clustered —
    // outliers get (bad) indices too, and the outlier branch compensates
    // with residual = fp - dequant.
    let mut idx = Vec::with_capacity(tok.len());
    for &v in tok {
        idx.push(cb.assign(v / scale));
    }
    let outliers = outs
        .iter()
        .map(|&c| {
            let v = tok[c as usize];
            let deq = cb.value(idx[c as usize]) * scale;
            (c, v, v - deq)
        })
        .collect();
    QuantToken { idx, scale, outliers }
}

impl QuantToken {
    /// Fake-quant reconstruction: inliers from the codebook, outliers FP.
    pub fn dequantize(&self, cb: &Codebook) -> Vec<f32> {
        let mut out: Vec<f32> = self
            .idx
            .iter()
            .map(|&i| cb.value(i) * self.scale)
            .collect();
        for &(c, v, _) in &self.outliers {
            out[c as usize] = v;
        }
        out
    }

    /// The look-ahead (main-branch) view: everything from the codebook,
    /// outlier error NOT yet compensated.
    pub fn dequantize_lookahead(&self, cb: &Codebook) -> Vec<f32> {
        self.idx.iter().map(|&i| cb.value(i) * self.scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn calib(rng: &mut Rng, n_tok: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n_tok)
            .map(|_| rng.heavy_tailed_vec(d, 0.01, 15.0))
            .collect()
    }

    #[test]
    fn roundtrip_error_small_with_outlier_protection() {
        let mut rng = Rng::new(1);
        let toks = calib(&mut rng, 32, 512);
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: 0.02 };
        let cb = learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.heavy_tailed_vec(512, 0.01, 15.0);
        let q = quantize_token(&x, &cb, cfg);
        let deq = q.dequantize(&cb);
        let err: f64 = x
            .iter()
            .zip(&deq)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            / x.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err < 0.15, "rel err {err}");
    }

    #[test]
    fn outlier_protection_beats_no_protection() {
        let mut rng = Rng::new(2);
        let toks = calib(&mut rng, 32, 512);
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: 0.02 };
        let cb = learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.heavy_tailed_vec(512, 0.02, 20.0);
        let q = quantize_token(&x, &cb, cfg);
        let with = q.dequantize(&cb);
        let without = q.dequantize_lookahead(&cb);
        let e = |v: &[f32]| -> f64 {
            x.iter()
                .zip(v)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum()
        };
        assert!(e(&with) < e(&without), "{} !< {}", e(&with), e(&without));
    }

    #[test]
    fn lookahead_plus_residual_equals_fp_outlier() {
        // The error-compensation identity at the token level.
        let mut rng = Rng::new(3);
        let toks = calib(&mut rng, 8, 256);
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb = learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.heavy_tailed_vec(256, 0.02, 10.0);
        let q = quantize_token(&x, &cb, cfg);
        let la = q.dequantize_lookahead(&cb);
        for &(c, v, r) in &q.outliers {
            assert!((la[c as usize] + r - v).abs() < 1e-5);
        }
    }

    #[test]
    fn static_mode_uses_thresholds() {
        let mut rng = Rng::new(4);
        let toks = calib(&mut rng, 8, 256);
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let cb = learn_act_codebook(&refs, None, 4, OutlierCfg::default());
        let x = rng.normal_vec(256, 1.0);
        let q = quantize_token_static(&x, &cb, -2.5, 2.5);
        for &(c, v, _) in &q.outliers {
            assert!(v.abs() > 2.5, "channel {c} value {v} not beyond threshold");
        }
    }

    #[test]
    fn fisher_weighting_improves_weighted_mse() {
        let mut rng = Rng::new(5);
        let toks = calib(&mut rng, 16, 256);
        let refs: Vec<&[f32]> = toks.iter().map(|t| t.as_slice()).collect();
        let total: usize = refs.iter().map(|t| t.len()).sum();
        // sensitivity concentrated on small-magnitude region
        let fisher: Vec<f32> = refs
            .iter()
            .flat_map(|t| t.iter().map(|&v| if v.abs() < 0.3 { 10.0 } else { 0.1 }))
            .collect();
        assert_eq!(fisher.len(), total);
        let cfg = OutlierCfg::default();
        let cbw = learn_act_codebook(&refs, Some(&fisher), 3, cfg);
        let cbu = learn_act_codebook(&refs, None, 3, cfg);
        // weighted codebook should put more centroids near 0
        let near = |cb: &Codebook| cb.centroids.iter().filter(|c| c.abs() < 0.3).count();
        assert!(near(&cbw) >= near(&cbu));
    }
}
