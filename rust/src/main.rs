//! `kllm` — leader entrypoint.
//!
//! Subcommands:
//!   experiment <id|all> [--preset P] [--steps N] [--eval-batches N]
//!       [--calib-samples N] [--md FILE]    regenerate a paper table/figure
//!   train [--preset P] [--steps N] [--lr X] [--corpus C] [--out CKPT]
//!   serve [--preset P] [--config FILE] [--port N] [--ckpt FILE]
//!       [--backend SPEC] [--kv-bits 32|4|3|2] [--prefix-cache on|off]
//!       [--wbits 2|3|4|auto] [--wbits-budget B] [--wbits-group N]
//!       [--sched burst|chunked] [--prefill-chunk N]
//!       [--shards N] [--spec-k N] [--draft-wbits 2|3|4] [--queue-cap N]
//!       [--default-deadline-ms MS] [--max-conns N] [--read-timeout-ms MS]
//!       [--chaos-rate R] [--chaos-seed S] [--chaos-kv-pressure R]
//!       [--drain-ms MS]
//!       `--wbits` picks the native backends' weight bit-width: a fixed
//!       2/3/4 quantizes every linear uniformly, while `auto` runs the
//!       calibration-driven per-layer planner — each linear's output MSE
//!       is measured under 2/3/4-bit codebooks and bits are assigned
//!       greedily against the `--wbits-budget B` average-bits budget
//!       (default 3.0). The served plan rides along in the stats dump
//!       (`wbits_plan`/`wbits_avg`). `--wbits-group N` sets the
//!       FineQuant-style per-group weight-scale granularity in reduction
//!       rows (default 128; 0 = one scale per column).
//!       `--sched chunked` switches the engine to iteration-level
//!       scheduling: every step runs one mixed backend pass of the
//!       active decode slots plus a budgeted chunk of pending prefill
//!       rows, so a long prompt can never stall in-flight decodes for
//!       its whole prefill. `--prefill-chunk N` pins the chunk to N
//!       rows per step; `0` (default) auto-budgets from the measured
//!       datapath (EWMA of the shard critical path vs decode-step
//!       time). Token streams are bit-exact with the default burst
//!       scheduler; requires a paged-prefill (native) backend, warns
//!       and falls back to burst otherwise.
//!       Robustness knobs: `--queue-cap` bounds the admission queue
//!       (overflow answered with a structured rejection carrying a
//!       `retry_after_ms` backpressure hint, never dropped);
//!       `--default-deadline-ms` applies a deadline to requests that
//!       bring none (per-request `deadline_ms` JSON field overrides);
//!       `--max-conns`/`--read-timeout-ms` harden the TCP listener;
//!       `--chaos-rate`/`--chaos-seed` wrap the backend in deterministic
//!       fault injection (testing) and `--chaos-kv-pressure` adds seeded
//!       allocation pressure on the prefix cache (forced LRU evictions);
//!       stdin EOF triggers a graceful drain bounded by `--drain-ms`.
//!       `--prefix-cache on` enables prompt-prefix KV sharing: admission
//!       aliases KV blocks of previously served prompt prefixes
//!       (refcounted, copy-on-write) so only the uncached tail is
//!       prefilled — composes with every `--kv-bits` bit-exactly.
//!       SPEC selects the decode execution engine:
//!       `direct|histogram|packed` run decode through the PJRT artifacts
//!       (the WAQ kernel is a modeled host clock), while
//!       `native-direct|native-histogram|native-packed` serve through the
//!       native K-Means WAQ LUT-GEMM datapath — measured throughput on
//!       the selected kernel, no PJRT required — and `native-sharded`
//!       splits every linear into `--shards N` tensor-parallel column
//!       shards on a persistent worker pool (bit-exact with
//!       `native-packed`). `native-spec` serves speculative decoding: a
//!       low-bit draft (`--draft-wbits {2,3,4}`; 2-bit streams four
//!       reduction rows per byte) proposes up to `--spec-k N` tokens per
//!       round and the packed target verifies them in ONE stacked
//!       LUT-GEMM pass — greedy output is bit-exact with `native-packed`
//!       (`--shards` is ignored by this backend). `--kv-bits` picks the
//!       paged KV-cache storage precision: 32 = FP32 (bit-exact with the
//!       dense cache), 4/3/2 = K-Means index streams (>= 4x lower cache
//!       bytes/token)
//!   quantize [--preset P] [--bits B]        quantize + report one matrix
//!   list                                    list experiments + artifacts

use std::io::Write;

use anyhow::{anyhow, Result};
use kllm::coordinator::{
    serve_tcp_with, BackendSpec, ChaosCfg, Coordinator, EngineConfig, KvBits, SchedPolicy, TcpCfg,
    WbitsSpec,
};
use kllm::eval::{run_experiment, Corpus, ExperimentCtx, ALL_IDS};
use kllm::runtime::{artifacts_dir, Manifest, ParamSet, Runtime};
use kllm::util::cli::Args;
use kllm::util::rng::Rng;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse().map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("list") | None => cmd_list(),
        Some(other) => Err(anyhow!("unknown subcommand '{other}' (try `kllm list`)")),
    }
}

fn ctx_from(args: &Args) -> Result<ExperimentCtx> {
    Ok(ExperimentCtx {
        preset: args.str_or("preset", "test"),
        train_steps: args.usize_or("steps", 250).map_err(|e| anyhow!(e))?,
        eval_batches: args.usize_or("eval-batches", 8).map_err(|e| anyhow!(e))?,
        calib_samples: args.usize_or("calib-samples", 16).map_err(|e| anyhow!(e))?,
    })
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.check_known(&["preset", "steps", "eval-batches", "calib-samples", "md"])
        .map_err(|e| anyhow!(e))?;
    let id = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: kllm experiment <id|all>"))?;
    let ctx = ctx_from(args)?;
    let ids: Vec<&str> = if id == "all" {
        ALL_IDS.to_vec()
    } else {
        vec![id.as_str()]
    };
    let mut md = String::new();
    for id in ids {
        eprintln!("[experiment {id}]");
        let tables = run_experiment(id, &ctx)?;
        for t in &tables {
            t.print();
            md.push_str(&t.render_markdown());
            md.push('\n');
        }
    }
    if let Some(path) = args.opt("md") {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        f.write_all(md.as_bytes())?;
        eprintln!("appended markdown to {path}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&["preset", "steps", "lr", "corpus", "out", "log-every"])
        .map_err(|e| anyhow!(e))?;
    let preset = args.str_or("preset", "test");
    let steps = args.usize_or("steps", 250).map_err(|e| anyhow!(e))?;
    let lr = args.f64_or("lr", 3e-3).map_err(|e| anyhow!(e))? as f32;
    let log_every = args.usize_or("log-every", 10).map_err(|e| anyhow!(e))?;
    let corpus = Corpus::parse(&args.str_or("corpus", "wiki2"))
        .ok_or_else(|| anyhow!("unknown corpus"))?;
    let mut rt = Runtime::new(&artifacts_dir(&preset))?;
    println!(
        "training {} preset on {} for {steps} steps (lr {lr})",
        preset,
        corpus.name()
    );
    let t0 = std::time::Instant::now();
    let (params, losses) = kllm::eval::ppl::train(
        &mut rt,
        corpus,
        steps,
        lr,
        0x7121,
        &mut |s, l| {
            if s % log_every == 0 {
                println!("step {s:>5}  loss {l:.4}");
            }
        },
    )?;
    println!(
        "done in {:.1}s: loss {:.4} -> {:.4}",
        t0.elapsed().as_secs_f64(),
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );
    if let Some(out) = args.opt("out") {
        params.save(std::path::Path::new(out))?;
        println!("checkpoint saved to {out}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&[
        "preset", "config", "port", "ckpt", "requests", "max-new", "backend", "kv-bits",
        "prefix-cache", "sched", "prefill-chunk", "shards", "spec-k", "draft-wbits",
        "wbits", "wbits-budget", "wbits-group", "queue-cap", "default-deadline-ms",
        "max-conns", "read-timeout-ms", "chaos-seed", "chaos-rate", "chaos-kv-pressure",
        "drain-ms",
    ])
    .map_err(|e| anyhow!(e))?;
    let mut preset = args.str_or("preset", "test");
    let mut port = args.usize_or("port", 7070).map_err(|e| anyhow!(e))? as u16;
    if let Some(cfg_path) = args.opt("config") {
        let cfg = kllm::util::config::Config::load(cfg_path).map_err(|e| anyhow!(e))?;
        preset = cfg.str_or("preset", &preset);
        port = cfg.usize_or("server.port", port as usize).map_err(|e| anyhow!(e))? as u16;
    }
    let backend_name = args.str_or("backend", BackendSpec::default().name());
    // accepted values (and the error text) derive from WaqBackend::ALL
    let backend: BackendSpec = backend_name.parse().map_err(|e: String| anyhow!(e))?;
    // KV-cache storage precision: 32 = FP32, 4/3/2 = K-Means index streams
    let kv_bits: KvBits = args
        .str_or("kv-bits", "32")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    // column-shard count for `--backend native-sharded`; 0 is rejected
    // here with a real error (a zero-worker pool is never constructible)
    let shards = args.usize_or("shards", 2).map_err(|e| anyhow!(e))?;
    if shards == 0 {
        return Err(anyhow!(
            "--shards 0 is invalid: the sharded backend needs >= 1 column shard"
        ));
    }
    // speculative decoding knobs for `--backend native-spec` (ignored by
    // every other backend; the backend constructor re-validates both)
    let spec_k = args.usize_or("spec-k", 4).map_err(|e| anyhow!(e))?;
    if spec_k == 0 {
        return Err(anyhow!("--spec-k 0 is invalid: propose at least 1 draft token"));
    }
    let draft_wbits = args.usize_or("draft-wbits", 2).map_err(|e| anyhow!(e))? as u32;
    if !matches!(draft_wbits, 2 | 3 | 4) {
        return Err(anyhow!("--draft-wbits must be 2, 3, or 4, got {draft_wbits}"));
    }
    // native weight width: fixed 2/3/4 or the calibration-driven planner
    // (`auto` + `--wbits-budget`); the backend constructor re-validates
    let wbits = match args.str_or("wbits", "4").as_str() {
        "auto" => {
            let budget = args.f64_or("wbits-budget", 3.0).map_err(|e| anyhow!(e))?;
            if !(2.0..=4.0).contains(&budget) {
                return Err(anyhow!("--wbits-budget must be in [2, 4], got {budget}"));
            }
            WbitsSpec::Auto { budget }
        }
        fixed => match fixed.parse::<u32>() {
            Ok(b) if (2..=4).contains(&b) => WbitsSpec::Uniform(b),
            _ => return Err(anyhow!("--wbits must be 2, 3, 4, or auto, got '{fixed}'")),
        },
    };
    let w_group = args.usize_or("wbits-group", 128).map_err(|e| anyhow!(e))?;
    if w_group % 4 != 0 {
        return Err(anyhow!(
            "--wbits-group must be a multiple of 4 (0 = one scale per column), got {w_group}"
        ));
    }
    // serving-robustness knobs (admission control, deadlines, chaos)
    let queue_cap = args.usize_or("queue-cap", 0).map_err(|e| anyhow!(e))?;
    let default_deadline_ms =
        args.u64_or("default-deadline-ms", 0).map_err(|e| anyhow!(e))?;
    let max_conns = args.usize_or("max-conns", 64).map_err(|e| anyhow!(e))?;
    let read_timeout_ms =
        args.u64_or("read-timeout-ms", 30_000).map_err(|e| anyhow!(e))?;
    let chaos_rate = args.f64_or("chaos-rate", 0.0).map_err(|e| anyhow!(e))?;
    if !(0.0..=1.0).contains(&chaos_rate) {
        return Err(anyhow!("--chaos-rate must be in [0, 1], got {chaos_rate}"));
    }
    let chaos_seed = args.u64_or("chaos-seed", 0xC4A05).map_err(|e| anyhow!(e))?;
    let kv_pressure = args.f64_or("chaos-kv-pressure", 0.0).map_err(|e| anyhow!(e))?;
    if !(0.0..=1.0).contains(&kv_pressure) {
        return Err(anyhow!("--chaos-kv-pressure must be in [0, 1], got {kv_pressure}"));
    }
    let chaos = (chaos_rate > 0.0 || kv_pressure > 0.0).then(|| {
        let mut c = ChaosCfg::uniform(chaos_seed, chaos_rate);
        if kv_pressure > 0.0 {
            // evict up to 4 prefix-cache blocks per fired pressure event
            c = c.with_kv_pressure(kv_pressure, 4);
        }
        c
    });
    // prompt-prefix KV sharing: radix index + refcounted copy-on-write
    // blocks; requires a backend with a paged prefill path (the native
    // backends), silently measured-off otherwise
    let prefix_cache = match args.str_or("prefix-cache", "off").as_str() {
        "on" => true,
        "off" => false,
        other => {
            return Err(anyhow!("--prefix-cache must be 'on' or 'off', got '{other}'"));
        }
    };
    // scheduler shape: burst (phased) or chunked (iteration-level with
    // budgeted prefill chunks); the chunk size is rows per step, 0 = auto
    let sched: SchedPolicy = args
        .str_or("sched", "burst")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let prefill_chunk = args.usize_or("prefill-chunk", 0).map_err(|e| anyhow!(e))?;
    let drain_ms = args.u64_or("drain-ms", 5_000).map_err(|e| anyhow!(e))?;
    let manifest = Manifest::load(&artifacts_dir(&preset)).map_err(|e| anyhow!(e))?;
    let params = match args.opt("ckpt") {
        Some(p) => ParamSet::load(std::path::Path::new(p))?,
        None => ParamSet::init(&manifest, &mut Rng::new(42)),
    };
    // the already-parsed manifest is handed straight to the engine thread
    // (native backends need no further disk access; PJRT loads HLO files
    // from manifest.dir)
    let coord = std::sync::Arc::new(Coordinator::start_with_manifest(
        manifest,
        params,
        EngineConfig {
            backend,
            kv_bits,
            shards,
            spec_k,
            draft_wbits,
            wbits,
            w_group,
            queue_cap,
            default_deadline_ms,
            chaos,
            prefix_cache,
            sched,
            prefill_chunk,
            ..Default::default()
        },
    )?);
    let tcp_cfg = TcpCfg {
        max_conns,
        read_timeout: (read_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(read_timeout_ms)),
    };
    let port = serve_tcp_with(coord.clone(), port, tcp_cfg)?;
    let how = if backend == BackendSpec::NativeSharded {
        format!("measured native WAQ LUT-GEMM datapath, {shards} tensor-parallel column shards")
    } else if backend == BackendSpec::NativeSpec {
        format!(
            "speculative decoding: {draft_wbits}-bit draft proposes up to {spec_k} \
             tokens/round, packed target verifies in one stacked pass"
        )
    } else if backend.is_native() {
        "measured native WAQ LUT-GEMM datapath".to_string()
    } else {
        "PJRT artifacts, modeled WAQ host clock".to_string()
    };
    println!(
        "kllm serving preset '{preset}' on 127.0.0.1:{port} (JSON lines, backend {backend}: \
         {how}, kv cache {kv_bits}-bit, prefix cache {}, sched {sched})",
        if prefix_cache { "on" } else { "off" }
    );
    if let Some(c) = &chaos {
        println!(
            "chaos enabled: rate {chaos_rate} seed {:#x} (deterministic fault injection)",
            c.seed
        );
    }
    println!("example: echo '{{\"prompt\": [1,2,3], \"max_new_tokens\": 8}}' | nc 127.0.0.1 {port}");
    println!("stdin EOF (or a 'drain'/'quit' line) triggers graceful drain ({drain_ms} ms limit)");

    // SIGTERM-equivalent: block on stdin; EOF or an explicit drain/quit
    // line starts the graceful drain (stop admitting, finish in-flight
    // under the limit, abort the rest, dump stats, exit 0)
    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        line.clear();
        match stdin.read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(_) => {
                let cmd = line.trim();
                if cmd == "drain" || cmd == "quit" {
                    break;
                }
                if cmd == "stats" {
                    // same one-line JSON dump as the TCP {"cmd": "stats"}
                    // control path (machine-parseable, prefix counters
                    // included); sim seconds ride along on stderr
                    let (stats, sim) = coord.stats()?;
                    println!("{}", stats.to_json());
                    eprintln!("sim clock: {:.4}s modeled", sim.seconds);
                } else if !cmd.is_empty() {
                    println!("commands: drain | quit | stats (or EOF to drain)");
                }
            }
            Err(_) => break,
        }
    }
    let report = coord.drain(std::time::Duration::from_millis(drain_ms))?;
    println!(
        "drained in {:.3}s: finished {} aborted {} rejected-mid-drain {} \
         (in-use kv blocks after drain: {})",
        report.drain_s,
        report.finished,
        report.aborted,
        report.stats.rejected,
        report.in_use_blocks
    );
    let s = &report.stats;
    println!(
        "final stats: completed {} rejected {} expired {} step_failures {} accept_errors {} \
         conn_rejected {} prefills {} decode_steps {} mean_occupancy {:.2} backend {} \
         kv_bits {} peak_kv_bytes {} prefix_hits {} prefix_blocks_reused {} evictions {}",
        s.completed,
        s.rejected,
        s.expired,
        s.step_failures,
        s.accept_errors,
        s.conn_rejected,
        s.prefills,
        s.decode_steps,
        s.mean_occupancy(),
        s.waq_backend,
        s.kv_bits,
        s.peak_kv_bytes,
        s.prefix_hits,
        s.prefix_blocks_reused,
        s.evictions
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    args.check_known(&["bits", "rows", "cols"]).map_err(|e| anyhow!(e))?;
    let bits = args.usize_or("bits", 4).map_err(|e| anyhow!(e))? as u32;
    let rows = args.usize_or("rows", 512).map_err(|e| anyhow!(e))?;
    let cols = args.usize_or("cols", 512).map_err(|e| anyhow!(e))?;
    let mut rng = Rng::new(1);
    let w = kllm::tensor::Matrix::random_normal(rows, cols, 1.0, &mut rng);
    let t0 = std::time::Instant::now();
    let q = kllm::quant::quantize_weights(&w, bits);
    let err = q.dequantize().rel_err(&w);
    println!(
        "k-means W{bits} quantization of {rows}x{cols}: rel err {err:.4}, {} bytes ({}x compression), {:.2}s",
        q.storage_bytes(),
        rows * cols * 4 / q.storage_bytes(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("experiments: {}", ALL_IDS.join(", "));
    println!("subcommands: experiment, train, serve, quantize, list");
    for preset in ["test", "gpt20m", "gpt100m"] {
        let dir = artifacts_dir(preset);
        let built = dir.join("manifest.json").exists();
        println!(
            "preset {preset:8} artifacts: {}",
            if built { "built" } else { "missing (make artifacts)" }
        );
    }
    Ok(())
}
