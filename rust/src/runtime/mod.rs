//! L3 runtime: PJRT client wrapper (load + compile + execute the AOT
//! artifacts), the artifact manifest, and parameter-set plumbing. Python is
//! never on this path — the HLO text was produced once by `make artifacts`.

pub mod artifacts;
pub mod client;
pub mod model_io;

pub use artifacts::{artifacts_dir, ArtifactSpec, DType, Manifest, TensorSpec};
pub use client::{Executable, HostTensor, Runtime};
pub use model_io::ParamSet;
