//! L3 runtime: PJRT client wrapper (load + compile + execute the AOT
//! artifacts), the artifact manifest, and parameter-set plumbing. Python is
//! never on this path — the HLO text was produced once by `make artifacts`.
//!
//! The PJRT execution backend is gated behind the off-by-default `pjrt`
//! cargo feature; without it an API-identical stub compiles in (see
//! `client`), and `pjrt_available()` lets tests/benches skip artifact
//! paths cleanly.

pub mod artifacts;
pub mod client;
pub mod model_io;

pub use artifacts::{artifacts_dir, ArtifactSpec, DType, Manifest, TensorSpec};
pub use client::{DeviceBuffer, Executable, HostTensor, Runtime};
pub use model_io::ParamSet;

/// Whether this build can actually execute AOT artifacts (the `pjrt`
/// feature, i.e. a real xla binding, was compiled in).
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}
