//! PJRT execution wrapper: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and marshals host tensors in/out. Mirrors
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format because
//! xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos.
//!
//! The PJRT-backed `Runtime`/`Executable` live behind the off-by-default
//! `pjrt` cargo feature (the offline registry has no usable xla binding).
//! Without the feature, an API-identical stub is compiled instead whose
//! constructors fail with a clear message, so every caller — engine, eval,
//! benches, examples — builds and runs unchanged and simply skips the
//! artifact paths. `HostTensor` is pure host code and always available.

use anyhow::{bail, Result};

use super::artifacts::{DType, TensorSpec};

/// A host-side tensor (f32 or i32), shape-carrying.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape mismatch");
        HostTensor::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape mismatch");
        HostTensor::I32 { data, shape: shape.to_vec() }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { data: vec![v], shape: vec![] }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::F32 { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }
}

/// Validate positional inputs against an artifact's manifest spec (shared
/// by the PJRT executable and the featureless stub).
fn validate_inputs(
    name: &str,
    specs: &[TensorSpec],
    inputs: &[HostTensor],
) -> Result<()> {
    if inputs.len() != specs.len() {
        bail!("{}: expected {} inputs, got {}", name, specs.len(), inputs.len());
    }
    for (i, (t, s)) in inputs.iter().zip(specs).enumerate() {
        if !t.matches(s) {
            bail!(
                "{}: input #{i} ('{}') expects {:?}{:?}, got {:?}{:?}",
                name,
                s.name,
                s.dtype,
                s.shape,
                t.dtype(),
                t.shape()
            );
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    //! The real PJRT-backed runtime (feature `pjrt`).

    use std::collections::HashMap;
    use std::path::Path;
    use std::rc::Rc;

    use anyhow::{anyhow, bail, Context, Result};

    use super::super::artifacts::{ArtifactSpec, Manifest};
    use super::{validate_inputs, HostTensor};

    /// Device-resident buffer handle (uploaded once, reused every step).
    pub type DeviceBuffer = xla::PjRtBuffer;

    impl HostTensor {
        pub(super) fn to_literal(&self) -> Result<xla::Literal> {
            let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
            let lit = match self {
                HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
                HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            };
            Ok(lit)
        }

        pub(super) fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            match shape.element_type() {
                xla::ElementType::F32 => Ok(HostTensor::F32 { data: lit.to_vec()?, shape: dims }),
                xla::ElementType::S32 => Ok(HostTensor::I32 { data: lit.to_vec()?, shape: dims }),
                other => bail!("unsupported output element type {other:?}"),
            }
        }
    }

    /// A compiled artifact.
    pub struct Executable {
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        /// Execute with host tensors (validates against the manifest spec).
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            validate_inputs(&self.spec.name, &self.spec.inputs, inputs)?;
            let lits = inputs
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?;
            let out = self.exe.execute::<xla::Literal>(&lits)?;
            self.collect(out)
        }

        /// Execute with pre-uploaded device buffers (the serving hot path:
        /// the big weight buffers are uploaded once and reused every step).
        pub fn run_buffers(&self, inputs: &[&DeviceBuffer]) -> Result<Vec<HostTensor>> {
            if inputs.len() != self.spec.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                );
            }
            let out = self.exe.execute_b::<&DeviceBuffer>(inputs)?;
            self.collect(out)
        }

        fn collect(&self, out: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
            let buf = out
                .first()
                .and_then(|r| r.first())
                .ok_or_else(|| anyhow!("no output buffer"))?;
            let mut lit = buf.to_literal_sync()?;
            // artifacts are lowered with return_tuple=True: single tuple root
            let parts = lit.decompose_tuple()?;
            let tensors = parts
                .iter()
                .map(HostTensor::from_literal)
                .collect::<Result<Vec<_>>>()?;
            if tensors.len() != self.spec.outputs.len() {
                bail!(
                    "{}: manifest says {} outputs, module returned {}",
                    self.spec.name,
                    self.spec.outputs.len(),
                    tensors.len()
                );
            }
            Ok(tensors)
        }
    }

    /// The PJRT runtime: one CPU client + compiled-executable cache.
    /// Not Sync/Send — owned by a single engine thread (the coordinator
    /// talks to it through channels).
    pub struct Runtime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        cache: HashMap<String, Rc<Executable>>,
    }

    impl Runtime {
        pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Runtime { manifest, client, cache: HashMap::new() })
        }

        pub fn for_preset(preset: &str) -> Result<Runtime> {
            Self::new(&super::super::artifacts::artifacts_dir(preset))
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) an artifact.
        pub fn load(&mut self, name: &str) -> Result<Rc<Executable>> {
            if let Some(e) = self.cache.get(name) {
                return Ok(e.clone());
            }
            let spec = self.manifest.artifact(name).map_err(|e| anyhow!(e))?.clone();
            let path = self.manifest.hlo_path(name).map_err(|e| anyhow!(e))?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("loading HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let e = Rc::new(Executable { spec, exe });
            self.cache.insert(name.to_string(), e.clone());
            Ok(e)
        }

        /// One-shot convenience.
        pub fn run(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            self.load(name)?.run(inputs)
        }

        /// Upload a host tensor to the device (for reuse across steps).
        pub fn upload(&self, t: &HostTensor) -> Result<DeviceBuffer> {
            match t {
                HostTensor::F32 { data, shape } => {
                    Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
                }
                HostTensor::I32 { data, shape } => {
                    Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
                }
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    //! API-identical stand-in compiled when the `pjrt` feature is off:
    //! construction fails with a clear message, so artifact-dependent code
    //! paths degrade to runtime skips instead of compile failures.

    use std::path::Path;
    use std::rc::Rc;

    use anyhow::{bail, Result};

    use super::super::artifacts::{ArtifactSpec, Manifest};
    use super::{validate_inputs, HostTensor};

    const NO_PJRT: &str =
        "kllm was built without the `pjrt` feature; rebuild with `--features pjrt` \
         (and a real xla binding) to execute AOT artifacts";

    /// Placeholder device buffer: never constructed without PJRT.
    #[derive(Debug)]
    pub struct DeviceBuffer {
        _private: (),
    }

    /// Spec-carrying placeholder: never constructed without PJRT.
    pub struct Executable {
        pub spec: ArtifactSpec,
    }

    impl Executable {
        pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            validate_inputs(&self.spec.name, &self.spec.inputs, inputs)?;
            bail!(NO_PJRT)
        }

        pub fn run_buffers(&self, _inputs: &[&DeviceBuffer]) -> Result<Vec<HostTensor>> {
            bail!(NO_PJRT)
        }
    }

    /// Featureless runtime: `new` always fails, everything downstream is
    /// therefore unreachable but type-checks against the PJRT API.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn new(_artifacts_dir: &Path) -> Result<Runtime> {
            bail!(NO_PJRT)
        }

        pub fn for_preset(preset: &str) -> Result<Runtime> {
            Self::new(&super::super::artifacts::artifacts_dir(preset))
        }

        pub fn platform(&self) -> String {
            "none (pjrt feature disabled)".to_string()
        }

        pub fn load(&mut self, _name: &str) -> Result<Rc<Executable>> {
            bail!(NO_PJRT)
        }

        pub fn run(&mut self, _name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
            bail!(NO_PJRT)
        }

        pub fn upload(&self, _t: &HostTensor) -> Result<DeviceBuffer> {
            bail!(NO_PJRT)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{DeviceBuffer, Executable, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::{DeviceBuffer, Executable, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::f32(vec![1.0; 6], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), DType::F32);
        assert!(t.as_f32().is_ok() && t.as_i32().is_err());
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![0.0; 5], &[2, 3]);
    }

    #[test]
    fn validate_inputs_checks_arity_and_shape() {
        let specs = vec![TensorSpec {
            name: "x".into(),
            shape: vec![2, 3],
            dtype: DType::F32,
        }];
        let ok = [HostTensor::f32(vec![0.0; 6], &[2, 3])];
        assert!(validate_inputs("t", &specs, &ok).is_ok());
        let bad_shape = [HostTensor::f32(vec![0.0; 6], &[3, 2])];
        assert!(validate_inputs("t", &specs, &bad_shape).is_err());
        assert!(validate_inputs("t", &specs, &[]).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_loudly() {
        let err = Runtime::new(std::path::Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
