//! Parameter-set plumbing between the Rust side and the L2 artifacts:
//! deterministic initialization matching python's ordering, checkpoint
//! save/load (flat binary), and weight <-> Matrix views for the quant
//! library.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;
use super::client::HostTensor;
use crate::tensor::Matrix;
use crate::util::rng::Rng;

/// A full parameter set in manifest order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<HostTensor>,
}

impl ParamSet {
    /// Scaled-normal init mirroring python model.init_params: norm gains
    /// at 1, embeddings at 0.02, linears at 1/sqrt(fan_in).
    pub fn init(manifest: &Manifest, rng: &mut Rng) -> ParamSet {
        let tensors = manifest
            .params
            .iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name.ends_with(".ln1")
                    || name.ends_with(".ln2")
                    || name == "lnf"
                {
                    vec![1.0f32; n]
                } else {
                    let std = if name.contains("emb") {
                        0.02
                    } else {
                        1.0 / (shape[0] as f32).sqrt()
                    };
                    rng.normal_vec(n, std)
                };
                HostTensor::f32(data, shape)
            })
            .collect();
        ParamSet { tensors }
    }

    pub fn zeros_like(manifest: &Manifest) -> ParamSet {
        ParamSet {
            tensors: manifest
                .params
                .iter()
                .map(|(_, shape)| HostTensor::zeros(shape))
                .collect(),
        }
    }

    pub fn index_of(manifest: &Manifest, name: &str) -> Option<usize> {
        manifest.params.iter().position(|(n, _)| n == name)
    }

    /// View a 2-D parameter as a Matrix (copy).
    pub fn matrix(&self, idx: usize) -> Result<Matrix> {
        let t = &self.tensors[idx];
        let sh = t.shape();
        if sh.len() != 2 {
            bail!("param {idx} is not 2-D: {sh:?}");
        }
        Ok(Matrix::from_vec(sh[0], sh[1], t.as_f32()?.to_vec()))
    }

    pub fn set_matrix(&mut self, idx: usize, m: &Matrix) -> Result<()> {
        let sh = self.tensors[idx].shape().to_vec();
        if sh != [m.rows, m.cols] {
            bail!("set_matrix shape mismatch: {sh:?} vs {}x{}", m.rows, m.cols);
        }
        self.tensors[idx] = HostTensor::f32(m.data.clone(), &sh);
        Ok(())
    }

    /// Simple flat-binary checkpoint: magic, count, per-tensor rank/dims/f32.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(b"KLLMCKPT")?;
        f.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for t in &self.tensors {
            let data = t.as_f32()?;
            let sh = t.shape();
            f.write_all(&(sh.len() as u64).to_le_bytes())?;
            for &d in sh {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamSet> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"KLLMCKPT" {
            bail!("bad checkpoint magic");
        }
        let mut u64buf = [0u8; 8];
        f.read_exact(&mut u64buf)?;
        let count = u64::from_le_bytes(u64buf) as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            f.read_exact(&mut u64buf)?;
            let rank = u64::from_le_bytes(u64buf) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                f.read_exact(&mut u64buf)?;
                shape.push(u64::from_le_bytes(u64buf) as usize);
            }
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(HostTensor::f32(data, &shape));
        }
        Ok(ParamSet { tensors })
    }

    /// Names of the quantizable linear weights, in (layer, kind) order
    /// matching the python per-linear index convention.
    pub fn linear_param_names(manifest: &Manifest) -> Vec<String> {
        let mut v = Vec::new();
        for l in 0..manifest.model.n_layers {
            for kind in ["qkv", "attn_out", "mlp_up", "mlp_down"] {
                v.push(format!("l{l}.{kind}"));
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::Manifest;
    use std::path::Path as P;

    fn tiny_manifest() -> Manifest {
        let text = r#"{
          "preset":"t","config":{"vocab":16,"d_model":8,"n_layers":1,
            "n_heads":2,"seq_len":4,"batch":1,"decode_batch":1,"head_dim":4,
            "d_ff":32,"n_linears":4},
          "params":[{"name":"tok_emb","shape":[16,8]},
                    {"name":"l0.ln1","shape":[8]},
                    {"name":"l0.qkv","shape":[8,24]}],
          "artifacts":{}
        }"#;
        Manifest::parse(P::new("/tmp"), text).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_norms_are_ones() {
        let m = tiny_manifest();
        let a = ParamSet::init(&m, &mut Rng::new(7));
        let b = ParamSet::init(&m, &mut Rng::new(7));
        assert_eq!(a.tensors, b.tensors);
        assert!(a.tensors[1].as_f32().unwrap().iter().all(|&v| v == 1.0));
        // embeddings small, linear ~ 1/sqrt(8)
        let emb_std = crate::util::stats::std_dev(a.tensors[0].as_f32().unwrap());
        assert!(emb_std < 0.05, "{emb_std}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = tiny_manifest();
        let p = ParamSet::init(&m, &mut Rng::new(1));
        let path = std::env::temp_dir().join("kllm_ckpt_test.bin");
        p.save(&path).unwrap();
        let q = ParamSet::load(&path).unwrap();
        assert_eq!(p.tensors, q.tensors);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn matrix_view_roundtrip() {
        let m = tiny_manifest();
        let mut p = ParamSet::init(&m, &mut Rng::new(2));
        let idx = ParamSet::index_of(&m, "l0.qkv").unwrap();
        let mut w = p.matrix(idx).unwrap();
        assert_eq!((w.rows, w.cols), (8, 24));
        w.data[0] = 42.0;
        p.set_matrix(idx, &w).unwrap();
        assert_eq!(p.matrix(idx).unwrap().data[0], 42.0);
        assert!(p.matrix(1).is_err()); // 1-D param
    }

    #[test]
    fn linear_names_order() {
        let m = tiny_manifest();
        let names = ParamSet::linear_param_names(&m);
        assert_eq!(names, vec!["l0.qkv", "l0.attn_out", "l0.mlp_up", "l0.mlp_down"]);
    }
}
