//! Artifact manifest: the L2 -> L3 contract. aot.py writes
//! `artifacts/<preset>/manifest.json` describing every HLO module's
//! positional inputs/outputs (name, shape, dtype) plus the model
//! configuration and the canonical parameter order; this module parses it
//! so the Rust runtime can marshal Literals with no Python at runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType, String> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(format!("unknown dtype '{other}'")),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec, String> {
        Ok(TensorSpec {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            shape: j
                .expect("shape")?
                .usize_list()
                .ok_or("bad shape")?,
            dtype: DType::parse(j.expect("dtype")?.as_str().ok_or("bad dtype")?)?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

/// Model configuration blob (mirrors python ModelConfig).
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub decode_batch: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub n_linears: usize,
}

impl ModelCfg {
    /// The `test` preset's model config, mirroring python
    /// `PRESETS["test"]` — the ONE definition the offline benches build
    /// synthetic manifests from (so every BENCH_*.json row measures the
    /// same model and cross-bench comparisons stay like-for-like).
    pub fn test_preset() -> ModelCfg {
        ModelCfg {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            seq_len: 32,
            batch: 2,
            decode_batch: 2,
            head_dim: 16,
            d_ff: 256,
            n_linears: 8,
        }
    }

    /// Deterministic (name, shape) parameter list mirroring python
    /// `model.param_specs` — the canonical order every `ParamSet` follows.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let mut v = vec![
            ("tok_emb".to_string(), vec![self.vocab, self.d_model]),
            ("pos_emb".to_string(), vec![self.seq_len, self.d_model]),
        ];
        for l in 0..self.n_layers {
            v.push((format!("l{l}.ln1"), vec![self.d_model]));
            v.push((format!("l{l}.qkv"), vec![self.d_model, 3 * self.d_model]));
            v.push((format!("l{l}.attn_out"), vec![self.d_model, self.d_model]));
            v.push((format!("l{l}.ln2"), vec![self.d_model]));
            v.push((format!("l{l}.mlp_up"), vec![self.d_model, self.d_ff]));
            v.push((format!("l{l}.mlp_down"), vec![self.d_ff, self.d_model]));
        }
        v.push(("lnf".to_string(), vec![self.d_model]));
        v
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub dir: PathBuf,
    pub model: ModelCfg,
    /// canonical parameter order (name, shape)
    pub params: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Per-linear weight bit plan, layer-major with four entries per
    /// layer (qkv, attn_out, mlp_up, mlp_down), each in {2,3,4}. `None`
    /// until a `--wbits auto` calibration records its choice; a manifest
    /// that carries a plan pins it, so a re-serve skips re-planning and
    /// reproduces the exact same mixed-precision assignment.
    pub wbits_plan: Option<Vec<u32>>,
}

impl Manifest {
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let cfgj = j.expect("config")?;
        let u = |k: &str| -> Result<usize, String> {
            cfgj.expect(k)?.as_usize().ok_or_else(|| format!("bad config.{k}"))
        };
        let model = ModelCfg {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            seq_len: u("seq_len")?,
            batch: u("batch")?,
            decode_batch: u("decode_batch")?,
            head_dim: u("head_dim")?,
            d_ff: u("d_ff")?,
            n_linears: u("n_linears")?,
        };
        let params = j
            .expect("params")?
            .as_arr()
            .ok_or("params not a list")?
            .iter()
            .map(|p| {
                Ok((
                    p.expect("name")?.as_str().ok_or("bad param name")?.to_string(),
                    p.expect("shape")?.usize_list().ok_or("bad param shape")?,
                ))
            })
            .collect::<Result<Vec<_>, String>>()?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.expect("artifacts")?.as_obj().ok_or("artifacts not an object")? {
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>, String> {
                a.expect(key)?
                    .as_arr()
                    .ok_or_else(|| format!("{key} not a list"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.expect("file")?.as_str().ok_or("bad file")?.to_string(),
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                    meta: a.get("meta").cloned().unwrap_or(Json::Null),
                },
            );
        }
        let wbits_plan = match j.get("wbits_plan") {
            None | Some(Json::Null) => None,
            Some(p) => {
                let plan: Vec<u32> = p
                    .usize_list()
                    .ok_or("bad wbits_plan")?
                    .into_iter()
                    .map(|b| b as u32)
                    .collect();
                if plan.len() != 4 * model.n_layers || plan.iter().any(|b| !(2..=4).contains(b)) {
                    return Err(format!(
                        "bad wbits_plan: want {} entries in 2..=4, got {:?}",
                        4 * model.n_layers,
                        plan
                    ));
                }
                Some(plan)
            }
        };
        Ok(Manifest {
            preset: j
                .expect("preset")?
                .as_str()
                .ok_or("bad preset")?
                .to_string(),
            dir: dir.to_path_buf(),
            model,
            params,
            artifacts,
            wbits_plan,
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} (run `make artifacts` first)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("artifact '{name}' not in manifest ({})", self.preset))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf, String> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Total parameter element count.
    pub fn param_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// An artifact-less in-memory manifest for a model configuration:
    /// enough for `ParamSet::init` and the native serving backend (which
    /// needs parameter shapes and order, not HLO files). PJRT execution
    /// still requires a real `make artifacts` manifest on disk.
    pub fn synthetic(preset: &str, model: ModelCfg) -> Manifest {
        Manifest {
            preset: preset.to_string(),
            dir: PathBuf::from("."),
            model,
            params: model.param_specs(),
            artifacts: BTreeMap::new(),
            wbits_plan: None,
        }
    }

    /// Record a `--wbits auto` planner decision (layer-major, four
    /// linears per layer) so later backends built from this manifest pin
    /// the exact assignment instead of re-running calibration planning.
    pub fn with_wbits_plan(mut self, plan: Vec<u32>) -> Manifest {
        self.wbits_plan = Some(plan);
        self
    }
}

/// Locate the artifacts directory for a preset: `$KLLM_ARTIFACTS` or
/// ./artifacts relative to the workspace root.
pub fn artifacts_dir(preset: &str) -> PathBuf {
    let base = std::env::var("KLLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    Path::new(&base).join(preset)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "test",
      "config": {"vocab":256,"d_model":64,"n_layers":2,"n_heads":4,
                 "seq_len":32,"batch":2,"decode_batch":2,"head_dim":16,
                 "d_ff":256,"n_linears":8},
      "params": [{"name":"tok_emb","shape":[256,64]},
                 {"name":"lnf","shape":[64]}],
      "artifacts": {
        "fwd": {"file":"fwd.hlo.txt",
                "inputs":[{"name":"tok_emb","shape":[256,64],"dtype":"f32"},
                          {"name":"tokens","shape":[2,32],"dtype":"i32"}],
                "outputs":[{"name":"","shape":[2,32,256],"dtype":"f32"}],
                "meta":{"method":"none"}}
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.preset, "test");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.param_elems(), 256 * 64 + 64);
        let a = m.artifact("fwd").unwrap();
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.outputs[0].elem_count(), 2 * 32 * 256);
        assert!(m.artifact("nope").is_err());
        assert_eq!(m.hlo_path("fwd").unwrap(), Path::new("/tmp/x/fwd.hlo.txt"));
    }

    #[test]
    fn synthetic_manifest_matches_python_param_order() {
        let cfg = ModelCfg {
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            seq_len: 32,
            batch: 2,
            decode_batch: 2,
            head_dim: 16,
            d_ff: 256,
            n_linears: 8,
        };
        let m = Manifest::synthetic("syn", cfg);
        assert_eq!(m.preset, "syn");
        // tok_emb + pos_emb + 6 per layer + lnf
        assert_eq!(m.params.len(), 2 + 6 * cfg.n_layers + 1);
        assert_eq!(m.params[0].0, "tok_emb");
        assert_eq!(m.params[1].0, "pos_emb");
        assert_eq!(m.params[2].0, "l0.ln1");
        assert_eq!(m.params.last().unwrap().0, "lnf");
        let qkv = m.params.iter().find(|(n, _)| n == "l1.qkv").unwrap();
        assert_eq!(qkv.1, vec![64, 192]);
        let down = m.params.iter().find(|(n, _)| n == "l0.mlp_down").unwrap();
        assert_eq!(down.1, vec![256, 64]);
        assert!(m.artifacts.is_empty());
    }

    #[test]
    fn wbits_plan_is_optional_and_validated() {
        // absent → None (every pre-planner manifest parses unchanged)
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.wbits_plan, None);
        assert_eq!(Manifest::synthetic("syn", m.model).wbits_plan, None);
        // present → 4 entries per layer, each in {2,3,4}
        let good = SAMPLE.replace(
            "\"preset\": \"test\",",
            "\"preset\": \"test\", \"wbits_plan\": [4,3,2,3,4,2,3,4],",
        );
        let m = Manifest::parse(Path::new("/tmp"), &good).unwrap();
        assert_eq!(m.wbits_plan, Some(vec![4, 3, 2, 3, 4, 2, 3, 4]));
        // wrong arity and out-of-range widths are rejected, not ignored
        for plan in ["[4,3]", "[4,3,2,3,4,2,3,5]", "[4,3,2,3,4,2,3,1]"] {
            let bad = SAMPLE.replace(
                "\"preset\": \"test\",",
                &format!("\"preset\": \"test\", \"wbits_plan\": {plan},"),
            );
            assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err(), "{plan}");
        }
        // builder records a plan onto a synthetic manifest
        let m = Manifest::synthetic("syn", m.model).with_wbits_plan(vec![2; 8]);
        assert_eq!(m.wbits_plan.as_deref(), Some(&[2u32; 8][..]));
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"i32\"", "\"f64\"");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration-style: parse the actual artifacts/test manifest when
        // `make artifacts` has run (skips silently otherwise).
        let dir = artifacts_dir("test");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "test");
        for key in ["fwd", "loss_eval", "train_step", "decode_step", "prefill",
                    "collect_acts", "waq_gemm", "waq_gemm_hist", "quantize_act"] {
            assert!(m.artifacts.contains_key(key), "missing {key}");
        }
        // every artifact input arity matches the param prefix where relevant
        let fwd = m.artifact("fwd").unwrap();
        assert_eq!(fwd.inputs.len(), m.params.len() + 1);
    }
}
