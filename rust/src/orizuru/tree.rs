//! Orizuru (paper §IV-D): two complete binary trees (max + min) with
//! *shared leaf nodes*, popping the k largest and k smallest elements of an
//! activation vector with 1.5N + 2k·log2(N) comparisons.
//!
//! Array layout: classic implicit heap — internal nodes 1..N-1, leaves
//! N..2N-1 (leaf i holds x[i - N]). Each internal node stores one bit (the
//! MUX select): 0 = left child holds the subtree winner, 1 = right. Each
//! tree has its own mask (popped leaves), and the min tree's bottom level
//! is initialized by *reversing* the max tree's bottom-level comparisons,
//! which is the 50%-init-savings trick that gives the 1.5N term.
//! Tie-breaking is deterministic: the LEFT child wins ties in both trees
//! (larger in the max tree, smaller in the min tree).

/// One of the two folded trees.
struct HalfTree {
    /// bits[i] for internal node i in 1..n ; bits[0] unused
    bits: Vec<u8>,
    /// popped mask per leaf
    popped: Vec<bool>,
}

pub struct Orizuru {
    /// padded leaf count (power of two)
    n: usize,
    /// original input length
    len: usize,
    values: Vec<f32>,
    max_tree: HalfTree,
    min_tree: HalfTree,
    comparisons: u64,
}

impl Orizuru {
    /// Build both trees over `x`. Counts: N-1 comparisons for the max tree,
    /// N/2-1 for the min tree (bottom level reused) = 1.5N - 2 total.
    pub fn new(x: &[f32]) -> Self {
        assert!(!x.is_empty());
        let len = x.len();
        let n = len.next_power_of_two().max(2);
        let mut values = x.to_vec();
        values.resize(n, 0.0);
        // padding leaves start popped in both trees so they are never
        // selected
        let mut popped = vec![false; n];
        for p in popped.iter_mut().skip(len) {
            *p = true;
        }
        let mut o = Orizuru {
            n,
            len,
            values,
            max_tree: HalfTree { bits: vec![0; n], popped: popped.clone() },
            min_tree: HalfTree { bits: vec![0; n], popped },
            comparisons: 0,
        };
        o.init();
        o
    }

    /// Effective leaf value for a tree: popped leaves read as -inf (max
    /// tree) / +inf (min tree).
    #[inline]
    fn leaf_val(&self, is_max: bool, leaf: usize) -> f32 {
        let t = if is_max { &self.max_tree } else { &self.min_tree };
        if t.popped[leaf] {
            if is_max {
                f32::NEG_INFINITY
            } else {
                f32::INFINITY
            }
        } else {
            self.values[leaf]
        }
    }

    /// Subtree winner value at node `i` (1-based heap index).
    fn node_val(&self, is_max: bool, mut i: usize) -> f32 {
        let t = if is_max { &self.max_tree } else { &self.min_tree };
        while i < self.n {
            i = 2 * i + t.bits[i] as usize;
        }
        self.leaf_val(is_max, i - self.n)
    }

    fn init(&mut self) {
        let n = self.n;
        // bottom level of the max tree: N/2 comparisons
        for i in n / 2..n {
            let l = self.leaf_val(true, 2 * i - n);
            let r = self.leaf_val(true, 2 * i + 1 - n);
            self.comparisons += 1;
            self.max_tree.bits[i] = u8::from(r > l); // left wins ties
            // min tree bottom level: REVERSED comparison result (free)
            // careful with popped padding: for the min tree the padded
            // (popped) side must lose, which the reversed bit already
            // ensures when exactly one side is padded (it read as -inf in
            // the max compare, so the other side won there; reversing makes
            // the padded side "win" the min compare — wrong!). Fix below.
            self.min_tree.bits[i] = u8::from(!(r > l));
        }
        // Repair min-tree bottom bits where padding is involved (no extra
        // FP comparisons — mask logic only, as in hardware).
        for i in n / 2..n {
            let lp = self.min_tree.popped[2 * i - n];
            let rp = self.min_tree.popped[2 * i + 1 - n];
            if lp && !rp {
                self.min_tree.bits[i] = 1;
            } else if rp && !lp {
                self.min_tree.bits[i] = 0;
            }
        }
        // upper levels of both trees
        let mut level_start = n / 4;
        while level_start >= 1 {
            for i in level_start..2 * level_start {
                self.update_node(true, i);
                self.update_node(false, i);
            }
            level_start /= 2;
        }
    }

    /// Recompute one internal node's bit from its children (1 comparison).
    fn update_node(&mut self, is_max: bool, i: usize) {
        let l = self.node_val(is_max, 2 * i);
        let r = self.node_val(is_max, 2 * i + 1);
        self.comparisons += 1;
        let bit = if is_max {
            u8::from(r > l) // left wins ties (larger)
        } else {
            u8::from(r < l) // left wins ties (smaller)
        };
        if is_max {
            self.max_tree.bits[i] = bit;
        } else {
            self.min_tree.bits[i] = bit;
        }
    }

    /// Root-to-leaf traversal following the stored bits: zero comparisons,
    /// one cycle in hardware. Returns the winning leaf index.
    fn winner_leaf(&self, is_max: bool) -> usize {
        let t = if is_max { &self.max_tree } else { &self.min_tree };
        let mut i = 1usize;
        while i < self.n {
            i = 2 * i + t.bits[i] as usize;
        }
        i - self.n
    }

    /// Pop the current maximum: returns (original index, value), then
    /// maintains the tree bottom-up (log2 N comparisons).
    pub fn pop_max(&mut self) -> Option<(usize, f32)> {
        self.pop(true)
    }

    pub fn pop_min(&mut self) -> Option<(usize, f32)> {
        self.pop(false)
    }

    fn pop(&mut self, is_max: bool) -> Option<(usize, f32)> {
        let leaf = self.winner_leaf(is_max);
        {
            let t = if is_max { &self.max_tree } else { &self.min_tree };
            if t.popped[leaf] {
                return None; // tree exhausted
            }
        }
        let val = self.values[leaf];
        if is_max {
            self.max_tree.popped[leaf] = true;
        } else {
            self.min_tree.popped[leaf] = true;
        }
        // maintenance: update ancestors bottom-up, one comparison per level
        let mut i = (leaf + self.n) / 2;
        while i >= 1 {
            self.update_node(is_max, i);
            if i == 1 {
                break;
            }
            i /= 2;
        }
        Some((leaf, val))
    }

    /// Pop the k largest and k smallest (the paper's top-k outlier job).
    /// Emits exactly k per side (ties broken deterministically), matching
    /// the "always output exactly k outliers" rule in §IV-D.
    pub fn top_k(&mut self, k: usize) -> (Vec<(usize, f32)>, Vec<(usize, f32)>) {
        let k = k.min(self.len);
        let mut maxs = Vec::with_capacity(k);
        let mut mins = Vec::with_capacity(k);
        for _ in 0..k {
            if let Some(m) = self.pop_max() {
                maxs.push(m);
            }
            if let Some(m) = self.pop_min() {
                mins.push(m);
            }
        }
        (maxs, mins)
    }

    pub fn comparisons(&self) -> u64 {
        self.comparisons
    }

    /// The paper's cost model: 1.5N + 2k·log2(N) comparisons.
    pub fn paper_cost_model(n: usize, k: usize) -> f64 {
        let np = n.next_power_of_two().max(2) as f64;
        1.5 * np + 2.0 * k as f64 * np.log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sorted_check(x: &[f32], k: usize) {
        let mut o = Orizuru::new(x);
        let (maxs, mins) = o.top_k(k);
        let mut sorted = x.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = x.len();
        for (i, &(_, v)) in maxs.iter().enumerate() {
            assert_eq!(v, sorted[n - 1 - i], "max #{i}");
        }
        for (i, &(_, v)) in mins.iter().enumerate() {
            assert_eq!(v, sorted[i], "min #{i}");
        }
    }

    #[test]
    fn matches_sort_oracle_random() {
        let mut rng = Rng::new(1);
        for &n in &[8usize, 16, 100, 1024, 1000] {
            let x = rng.normal_vec(n, 1.0);
            sorted_check(&x, (n / 8).max(1));
        }
    }

    #[test]
    fn paper_figure_example() {
        // Fig 10: x = [3, 1, 4, 1, 5, 9, 2, 6]; max = 9 at index 5
        let x = [3.0f32, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Orizuru::new(&x);
        assert_eq!(o.pop_max(), Some((5, 9.0)));
        assert_eq!(o.pop_max(), Some((7, 6.0)));
        assert_eq!(o.pop_max(), Some((4, 5.0)));
        assert_eq!(o.pop_min(), Some((1, 1.0))); // tie with idx 3: left wins
        assert_eq!(o.pop_min(), Some((3, 1.0)));
    }

    #[test]
    fn comparison_count_matches_model() {
        let mut rng = Rng::new(2);
        for &(n, k) in &[(1024usize, 10usize), (4096, 20), (256, 4)] {
            let x = rng.normal_vec(n, 1.0);
            let mut o = Orizuru::new(&x);
            let init_cmp = o.comparisons();
            // init = N/2 (max bottom) + (N/2 - 1) (max upper) + (N/2 - 1)
            // (min upper, bottom reused) = 1.5N - 2
            assert_eq!(init_cmp, (3 * n / 2 - 2) as u64, "init at n={n}");
            o.top_k(k);
            let total = o.comparisons();
            let model = Orizuru::paper_cost_model(n, k);
            let actual = total as f64;
            assert!(
                (actual - model).abs() / model < 0.05,
                "n={n} k={k}: actual {actual} vs model {model}"
            );
        }
    }

    #[test]
    fn non_power_of_two_padding() {
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(100, 1.0);
        sorted_check(&x, 10);
    }

    #[test]
    fn exactly_k_with_ties() {
        let x = vec![2.0f32; 64];
        let mut o = Orizuru::new(&x);
        let (maxs, mins) = o.top_k(5);
        assert_eq!(maxs.len(), 5);
        assert_eq!(mins.len(), 5);
        // max and min trees pop independently (shared leaves, separate
        // masks) — a value can be both a max and a min under total ties.
        for &(_, v) in maxs.iter().chain(mins.iter()) {
            assert_eq!(v, 2.0);
        }
    }

    #[test]
    fn exhausting_the_tree() {
        let x = [5.0f32, -1.0, 3.0];
        let mut o = Orizuru::new(&x);
        assert_eq!(o.pop_max(), Some((0, 5.0)));
        assert_eq!(o.pop_max(), Some((2, 3.0)));
        assert_eq!(o.pop_max(), Some((1, -1.0)));
        assert_eq!(o.pop_max(), None);
    }

    #[test]
    fn popping_max_does_not_disturb_min() {
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(512, 1.0);
        let mut o = Orizuru::new(&x);
        for _ in 0..50 {
            o.pop_max();
        }
        let mut sorted = x.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(o.pop_min().unwrap().1, sorted[0]);
    }

    #[test]
    fn negative_infinity_never_reaches_root_while_nonempty() {
        // pop both children of one subtree; winner must still be finite
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut o = Orizuru::new(&x);
        for _ in 0..7 {
            let (_, v) = o.pop_max().unwrap();
            assert!(v.is_finite());
        }
    }
}
