//! Top-k baselines Orizuru is evaluated against: a binary-heap engine, a
//! full sort, and the SpAtten-style ~6N-comparison top-k engine the paper
//! cites ([55]). All paths count comparisons so the bench can reproduce the
//! "1.5N + 2k·log2 N vs 6N" claim.

/// Comparison-counting top-k largest + smallest via two k-bounded heaps.
pub struct HeapTopK {
    pub comparisons: u64,
}

impl HeapTopK {
    pub fn run(x: &[f32], k: usize) -> (Vec<(usize, f32)>, Vec<(usize, f32)>, u64) {
        let mut cmp = 0u64;
        // min-heap of the k largest, max-heap of the k smallest — emulated
        // with sorted insertion over a Vec of size k (k is small; this
        // matches the comparator counts of a binary heap within constants).
        let mut tops: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
        let mut bots: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
        for (i, &v) in x.iter().enumerate() {
            // top side
            cmp += 1;
            if tops.len() < k || v > tops.last().unwrap().1 {
                let pos = tops
                    .binary_search_by(|&(_, tv)| {
                        cmp += 1;
                        v.partial_cmp(&tv).unwrap()
                    })
                    .unwrap_or_else(|e| e);
                tops.insert(pos, (i, v));
                tops.truncate(k);
            }
            // bottom side
            cmp += 1;
            if bots.len() < k || v < bots.last().unwrap().1 {
                let pos = bots
                    .binary_search_by(|&(_, bv)| {
                        cmp += 1;
                        bv.partial_cmp(&v).unwrap()
                    })
                    .unwrap_or_else(|e| e);
                bots.insert(pos, (i, v));
                bots.truncate(k);
            }
        }
        (tops, bots, cmp)
    }
}

/// Full sort baseline (argsort) — comparison count ~ N log2 N.
pub fn sort_topk(x: &[f32], k: usize) -> (Vec<(usize, f32)>, Vec<(usize, f32)>, u64) {
    use std::cell::Cell;
    let cmp = Cell::new(0u64);
    let mut order: Vec<usize> = (0..x.len()).collect();
    order.sort_by(|&a, &b| {
        cmp.set(cmp.get() + 1);
        x[a].partial_cmp(&x[b]).unwrap().then(a.cmp(&b))
    });
    let k = k.min(x.len());
    let mins = order[..k].iter().map(|&i| (i, x[i])).collect();
    let maxs = order[x.len() - k..]
        .iter()
        .rev()
        .map(|&i| (i, x[i]))
        .collect();
    (maxs, mins, cmp.get())
}

/// SpAtten-style engine cost model: the paper states the baseline top-k
/// engine in [55] costs ~6N comparisons for an N-input vector.
pub fn spatten_cost_model(n: usize) -> f64 {
    6.0 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn heap_matches_sort() {
        let mut rng = Rng::new(1);
        let x = rng.normal_vec(500, 1.0);
        let (ht, hb, _) = HeapTopK::run(&x, 7);
        let (st, sb, _) = sort_topk(&x, 7);
        let vals = |v: &[(usize, f32)]| v.iter().map(|&(_, x)| x).collect::<Vec<_>>();
        assert_eq!(vals(&ht), vals(&st));
        assert_eq!(vals(&hb), vals(&sb));
    }

    #[test]
    fn sort_cost_exceeds_orizuru_model() {
        let n = 4096;
        let (_, _, cmp) = sort_topk(
            &crate::util::rng::Rng::new(2).normal_vec(n, 1.0),
            20,
        );
        let oz = crate::orizuru::tree::Orizuru::paper_cost_model(n, 20);
        assert!(cmp as f64 > 2.0 * oz, "sort {cmp} vs orizuru {oz}");
    }

    #[test]
    fn orizuru_beats_spatten_model() {
        // 1.5N + 2k log2 N < 6N for the paper's operating points
        for &(n, k) in &[(4096usize, 20usize), (2048, 10), (11008, 55)] {
            let oz = crate::orizuru::tree::Orizuru::paper_cost_model(n, k);
            assert!(oz < spatten_cost_model(n), "n={n} k={k}");
        }
    }
}
