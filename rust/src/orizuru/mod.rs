//! Orizuru — the paper's dynamic outlier-detection engine (§IV-D) — plus
//! the baselines it is compared against. Cross-checked against
//! `quant::outlier::topk_outliers` (the algorithm-library reference).

pub mod baseline;
pub mod tree;

pub use tree::Orizuru;

/// Convenience API matching quant::outlier::topk_outliers: sorted channel
/// indices of the k largest + k smallest.
pub fn detect_outliers(x: &[f32], k_per_side: usize) -> Vec<u32> {
    let mut o = Orizuru::new(x);
    let (maxs, mins) = o.top_k(k_per_side);
    let mut idx: Vec<u32> = maxs
        .into_iter()
        .chain(mins)
        .map(|(i, _)| i as u32)
        .collect();
    idx.sort_unstable();
    idx.dedup();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::outlier::topk_outliers;
    use crate::util::rng::Rng;

    #[test]
    fn agrees_with_reference_detector_on_distinct_values() {
        let mut rng = Rng::new(1);
        for case in 0..20 {
            let n = 64 + case * 37;
            let x = rng.normal_vec(n, 1.0); // ties have measure zero
            let k = (n / 50).max(1);
            let hw = detect_outliers(&x, k);
            let sw = topk_outliers(&x, k);
            assert_eq!(hw, sw, "case {case} n={n} k={k}");
        }
    }

    #[test]
    fn heavy_tailed_activations() {
        let mut rng = Rng::new(2);
        let x = rng.heavy_tailed_vec(4096, 0.01, 20.0);
        let hw = detect_outliers(&x, 20);
        let sw = topk_outliers(&x, 20);
        assert_eq!(hw, sw);
    }
}
