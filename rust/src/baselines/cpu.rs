//! Software (host CPU) WAQ LUT-GEMM datapath model — the host-side
//! analogue of the accelerator comparators in this module: bytes streamed
//! and scalar table ops per decode step for each [`WaqBackend`]. The
//! serving engine advances this clock alongside the OASIS simulator so
//! every response also reports what the *software* datapath would cost
//! under the configured backend, and so backend choices show up in the
//! e2e bench as modeled (not just measured) deltas.
//!
//! The structural facts captured (mirroring `gemm::packed`'s design):
//!   * `Direct`/`Histogram` stream one byte per weight index per token;
//!     `Packed` streams a nibble per index and, being cache-tiled, streams
//!     the weight matrix once per *batch* rather than once per token;
//!   * `Direct` does ~2 table ops per MAC, `Packed` ~1 per two MACs plus
//!     the 2^(2 nW)-add fused-row builds, `Histogram` pays the
//!     2^(nA+nW)-entry MAC-tree sweep per output channel.

use crate::gemm::WaqBackend;
use crate::models::LlmSpec;

/// Fused-table / Cartesian-LUT entry count at the paper's 4+4-bit config.
const LUT_ENTRIES: f64 = 256.0;

#[derive(Clone, Copy, Debug)]
pub struct CpuWaqModel {
    pub backend: WaqBackend,
    /// sustained single-stream load bandwidth of the host datapath
    pub stream_bytes_per_sec: f64,
    /// scalar gather+add throughput
    pub ops_per_sec: f64,
}

impl CpuWaqModel {
    /// A conservative single-socket host profile.
    pub fn host(backend: WaqBackend) -> CpuWaqModel {
        CpuWaqModel { backend, stream_bytes_per_sec: 12e9, ops_per_sec: 3e9 }
    }

    /// Weight-index bytes streamed for one (1 x K) @ (K x N) GEMM repeated
    /// over `batch` tokens.
    pub fn gemm_index_bytes(&self, k: usize, n: usize, batch: usize) -> f64 {
        let kn = (k * n) as f64;
        match self.backend {
            // byte-per-index, re-streamed for every token
            WaqBackend::Direct | WaqBackend::Histogram => kn * batch as f64,
            // nibble-packed and tile-reused across the whole batch
            WaqBackend::Packed => kn / 2.0,
        }
    }

    /// Scalar table ops (gathers + adds) for the same work.
    pub fn gemm_ops(&self, k: usize, n: usize, batch: usize) -> f64 {
        let b = batch as f64;
        let kn = (k * n) as f64;
        match self.backend {
            WaqBackend::Direct => 2.0 * kn * b,
            WaqBackend::Histogram => (kn + LUT_ENTRIES * n as f64) * 2.0 * b,
            // one lookup+add per packed byte + fused-row builds
            WaqBackend::Packed => (kn / 2.0 + (k as f64 / 2.0) * LUT_ENTRIES) * b,
        }
    }

    /// Roofline seconds for one GEMM over a batch: max of the streaming
    /// and compute times.
    pub fn gemm_seconds(&self, k: usize, n: usize, batch: usize) -> f64 {
        let mem = self.gemm_index_bytes(k, n, batch) / self.stream_bytes_per_sec;
        let comp = self.gemm_ops(k, n, batch) / self.ops_per_sec;
        mem.max(comp)
    }

    /// Modeled host seconds for one batched decode step of `m` (all layer
    /// linears + the LM head).
    pub fn decode_step_seconds(&self, m: &LlmSpec, batch: usize) -> f64 {
        let mut s = 0.0;
        for (k, n) in m.layer_gemms() {
            s += self.gemm_seconds(k, n, batch);
        }
        s *= m.n_layers as f64;
        s + self.gemm_seconds(m.d_model, m.vocab, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn packed_halves_and_reuses_index_traffic() {
        let d = CpuWaqModel::host(WaqBackend::Direct);
        let p = CpuWaqModel::host(WaqBackend::Packed);
        assert_eq!(p.gemm_index_bytes(1024, 1024, 1) * 2.0, d.gemm_index_bytes(1024, 1024, 1));
        // tiling: packed traffic is batch-independent, direct scales with it
        assert_eq!(p.gemm_index_bytes(1024, 1024, 16), p.gemm_index_bytes(1024, 1024, 1));
        assert_eq!(
            d.gemm_index_bytes(1024, 1024, 16),
            16.0 * d.gemm_index_bytes(1024, 1024, 1)
        );
    }

    #[test]
    fn packed_decode_step_is_fastest() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let direct = CpuWaqModel::host(WaqBackend::Direct).decode_step_seconds(m, 4);
        let hist = CpuWaqModel::host(WaqBackend::Histogram).decode_step_seconds(m, 4);
        let packed = CpuWaqModel::host(WaqBackend::Packed).decode_step_seconds(m, 4);
        assert!(packed < direct, "packed {packed} !< direct {direct}");
        assert!(packed < hist, "packed {packed} !< histogram {hist}");
    }

    #[test]
    fn seconds_monotone_in_batch() {
        let m = by_name("OPT-6.7B").unwrap();
        for backend in WaqBackend::ALL {
            let c = CpuWaqModel::host(backend);
            assert!(c.decode_step_seconds(m, 8) >= c.decode_step_seconds(m, 1), "{backend:?}");
        }
    }
}
