//! FIGLUT baseline (Park et al., HPCA'25): the SOTA WOQ LUT-GEMM ASIC the
//! paper compares against (W4A16). Modeled at the same process/bandwidth
//! class as OASIS with its published characteristics: group-wise (mu = 4)
//! inner-product LUTs generated on the fly per token, bit-serial weight
//! processing, FP16 activations and KV cache.
//!
//! The structural consequences captured here (and the sources of OASIS's
//! Fig 11 advantage):
//!   * reduction work per GEMM is K/mu * nW * N FP adds (vs 2^(nA+nW) * N),
//!     so decode is COMPUTE-bound on FIGLUT while OASIS is memory-bound;
//!   * KV cache stays FP16 (4x the traffic of OASIS-A4);
//!   * per-token LUT generation adds (2^mu - 1) * K/mu FP adds per layer.

use crate::models::LlmSpec;
use crate::sim::llm::PhaseCost;

#[derive(Clone, Copy, Debug)]
pub struct FiglutModel {
    pub mu: usize,
    pub n_w_bits: u32,
    /// FP adders available per cycle (16 lines x 32-input trees, matching
    /// an iso-area configuration with OASIS's PE budget)
    pub adders_per_cycle: f64,
    pub clock_hz: f64,
    pub hbm_bytes_per_sec: f64,
    /// chip power: simpler datapath than OASIS (no Orizuru/cluster units);
    /// calibrated against the paper's 1.44x energy-efficiency ratio
    pub power_w: f64,
    /// FP16 activations/KV
    pub act_bytes: f64,
}

pub fn figlut() -> FiglutModel {
    FiglutModel {
        mu: 4,
        n_w_bits: 4,
        adders_per_cycle: 672.0,
        clock_hz: 500e6,
        hbm_bytes_per_sec: 512e9,
        power_w: 4.6,
        act_bytes: 2.0,
    }
}

impl FiglutModel {
    /// Cycles of one 1-K-N GEMM token on FIGLUT.
    pub fn gemm_cycles(&self, batch: usize, k: usize, n: usize) -> f64 {
        let groups = (k as f64 / self.mu as f64).ceil();
        let reduction = groups * self.n_w_bits as f64 * n as f64;
        let lut_gen = groups * ((1u64 << self.mu) - 1) as f64;
        (reduction + lut_gen) * batch as f64 / self.adders_per_cycle
    }

    pub fn decode_step_cost(&self, m: &LlmSpec, batch: usize, ctx: usize) -> PhaseCost {
        let mut cycles = 0.0;
        for (k, n) in m.layer_gemms() {
            cycles += self.gemm_cycles(batch, k, n);
        }
        cycles *= m.n_layers as f64;
        cycles += self.gemm_cycles(batch, m.d_model, m.vocab);
        // memory: 4-bit weights + FP16 KV
        let wgt_bytes = (m.linear_params() + m.vocab * m.d_model) as f64
            * self.n_w_bits as f64
            / 8.0;
        let kv_bytes = m.kv_bytes_per_token(self.act_bytes) * ctx as f64 * batch as f64;
        let bytes = wgt_bytes + kv_bytes;
        let mem_s = bytes / self.hbm_bytes_per_sec;
        let comp_s = cycles / self.clock_hz;
        let seconds = comp_s.max(mem_s);
        // chip power x time + HBM access energy (same accounting as the
        // OASIS model in sim::llm, so the Fig 11 energy ratios compare
        // like for like)
        let energy_j = seconds * self.power_w
            + bytes * crate::sim::energy::HBM_PJ_PER_BYTE * 1e-12;
        PhaseCost { seconds, energy_j, hbm_bytes: bytes }
    }

    pub fn generation_cost(
        &self,
        m: &LlmSpec,
        batch: usize,
        prompt_len: usize,
        out_len: usize,
    ) -> PhaseCost {
        let pre = if prompt_len > 0 {
            self.decode_step_cost(m, prompt_len, prompt_len / 2)
        } else {
            PhaseCost::default()
        };
        let step = self.decode_step_cost(m, batch, prompt_len + out_len / 2);
        PhaseCost {
            seconds: pre.seconds + step.seconds * out_len as f64,
            energy_j: pre.energy_j + step.energy_j * out_len as f64,
            hbm_bytes: pre.hbm_bytes + step.hbm_bytes * out_len as f64,
        }
    }

    pub fn decode_throughput(&self, m: &LlmSpec, batch: usize, out_len: usize) -> f64 {
        let g = self.generation_cost(m, batch, 0, out_len);
        (out_len * batch) as f64 / g.seconds
    }
}

/// Fig 16 comparators: LUT sizes and reduction FLOPs of the WOQ designs on
/// a given q_proj GEMM (K = N = d_model), at W4A16.
pub struct LutDesignCost {
    pub name: &'static str,
    pub lut_entries: usize,
    pub reduction_flops: usize,
}

pub fn fig16_costs(k: usize, n: usize) -> Vec<LutDesignCost> {
    use crate::gemm::woq::woq_cost;
    let fig = woq_cost(k, n, 4, 4);
    let ltc = woq_cost(k, n, 4, 4); // LUT Tensor Core: same mu = 4 class
    let lg = woq_cost(k, n, 4, 8); // LUT-GEMM: larger groups
    let oasis_entries = 1usize << 8; // 2^(4+4)
    let oasis_flops = oasis_entries * n;
    vec![
        LutDesignCost { name: "FIGLUT", lut_entries: fig.lut_entries, reduction_flops: fig.reduction_flops },
        LutDesignCost { name: "LUT Tensor Core", lut_entries: ltc.lut_entries, reduction_flops: ltc.reduction_flops },
        LutDesignCost { name: "LUT-GEMM", lut_entries: lg.lut_entries, reduction_flops: lg.reduction_flops },
        LutDesignCost { name: "OASIS-A4", lut_entries: oasis_entries, reduction_flops: oasis_flops },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;
    use crate::sim::llm::{decode_throughput, OasisMode};
    use crate::sim::config::HwConfig;

    #[test]
    fn oasis_beats_figlut_by_paper_range() {
        // Fig 11: OASIS-A4 ~3.0x over FIGLUT (avg across models).
        let hw = HwConfig::default();
        let mut ratios = Vec::new();
        for name in ["LLaMA-2-7B", "LLaMA-2-13B", "OPT-6.7B"] {
            let m = by_name(name).unwrap();
            let o = decode_throughput(&hw, m, OasisMode::a4(), 1, 64);
            let f = figlut().decode_throughput(m, 1, 64);
            ratios.push(o / f);
        }
        let avg = crate::util::stats::geomean(&ratios);
        assert!(avg > 1.8 && avg < 5.0, "OASIS/FIGLUT avg {avg} ({ratios:?})");
    }

    #[test]
    fn figlut_is_compute_bound_at_decode() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let f = figlut();
        let c = f.decode_step_cost(m, 1, 1024);
        let mem_s = c.hbm_bytes / f.hbm_bytes_per_sec;
        assert!(c.seconds > mem_s * 1.3, "{} vs mem {}", c.seconds, mem_s);
    }

    #[test]
    fn fig16_lut_size_ratios() {
        // q_proj of LLaMA-7B: K = N = 4096 — OASIS reduces LUT entries 64x
        // vs FIGLUT-class designs.
        let costs = fig16_costs(4096, 4096);
        let fig = costs.iter().find(|c| c.name == "FIGLUT").unwrap();
        let oasis = costs.iter().find(|c| c.name == "OASIS-A4").unwrap();
        assert_eq!(fig.lut_entries / oasis.lut_entries, 64);
        assert_eq!(fig.reduction_flops / oasis.reduction_flops, 16);
        // LUT sizes grow with K for WOQ designs but not for OASIS
        let big = fig16_costs(8192, 8192);
        let fig_big = big.iter().find(|c| c.name == "FIGLUT").unwrap();
        let oasis_big = big.iter().find(|c| c.name == "OASIS-A4").unwrap();
        assert!(fig_big.lut_entries > fig.lut_entries);
        assert_eq!(oasis_big.lut_entries, oasis.lut_entries);
    }

    #[test]
    fn larger_models_widen_the_gap() {
        // Fig 13 note: OASIS's edge grows on LLaMA-2-70B (more input
        // channels per layer).
        let hw = HwConfig::default();
        let small = by_name("LLaMA-2-7B").unwrap();
        let big = by_name("LLaMA-2-70B").unwrap();
        let r_small = decode_throughput(&hw, small, OasisMode::a4(), 1, 32)
            / figlut().decode_throughput(small, 1, 32);
        let r_big = decode_throughput(&hw, big, OasisMode::a4(), 1, 32)
            / figlut().decode_throughput(big, 1, 32);
        assert!(r_big > r_small * 0.95, "small {r_small} big {r_big}");
    }
}
