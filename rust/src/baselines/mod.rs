//! Baseline accelerator models the paper evaluates against: A100 FP16,
//! QuaRot W4A4 GPU kernels, and the FIGLUT WOQ-LUT ASIC (plus the Fig 16
//! LUT-design cost comparators), and the host-CPU software-datapath model
//! (`cpu`) parameterized by `gemm::WaqBackend`.

pub mod cpu;
pub mod figlut;
pub mod gpu;

pub use cpu::CpuWaqModel;
pub use figlut::{fig16_costs, figlut, FiglutModel};
pub use gpu::{a100_fp16, quarot_w4a4, GpuModel};
