//! GPU baseline rooflines: A100 FP16 and QuaRot's W4A4 CUDA path.
//!
//! These are bandwidth/compute rooflines with utilization factors, not CUDA
//! measurements (no GPU on this testbed — DESIGN.md §1.3). Decode at low
//! batch is HBM-bound with poor effective utilization on GPUs (the paper's
//! own explanation for Fig 11: "limited by low batch sizes"); the
//! utilization constants are calibrated so the *relative* OASIS speedups
//! land in the paper's reported range, and the batch-scaling behaviour
//! (Fig 12: GPUs gain steadily with batch) emerges from the model.

use crate::models::LlmSpec;
use crate::sim::llm::PhaseCost;

#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    pub name: &'static str,
    pub mem_bw_bytes: f64,
    pub peak_flops: f64,
    /// effective bandwidth utilization at batch 1 decode
    pub util_decode_b1: f64,
    /// utilization approach rate with batch (saturating)
    pub util_batch_gain: f64,
    pub board_power_w: f64,
    /// bytes per weight element
    pub weight_bytes: f64,
    /// bytes per KV-cache element
    pub kv_bytes: f64,
    /// extra per-GEMM overhead seconds (kernel launches, dequant epilogue)
    pub step_overhead_s: f64,
    /// max model bytes before OOM (80 GB board)
    pub mem_capacity_bytes: f64,
}

/// NVIDIA A100-80GB running FP16 inference.
pub fn a100_fp16() -> GpuModel {
    GpuModel {
        name: "A100 (FP16)",
        mem_bw_bytes: 2039e9,
        peak_flops: 312e12,
        util_decode_b1: 0.18,
        util_batch_gain: 0.22,
        board_power_w: 400.0,
        weight_bytes: 2.0,
        kv_bytes: 2.0,
        step_overhead_s: 45e-6,
        mem_capacity_bytes: 80e9,
    }
}

/// QuaRot W4A4 kernels on the A100 (INT4 tensor cores + rotation/dequant
/// epilogues).
pub fn quarot_w4a4() -> GpuModel {
    GpuModel {
        name: "QuaRot (W4A4)",
        mem_bw_bytes: 2039e9,
        peak_flops: 624e12, // INT4 TOPS usable fraction
        util_decode_b1: 0.082,
        util_batch_gain: 0.13,
        board_power_w: 400.0,
        weight_bytes: 0.5,
        kv_bytes: 0.5,
        step_overhead_s: 80e-6, // Hadamard + quant/dequant epilogues
        mem_capacity_bytes: 80e9,
    }
}

impl GpuModel {
    fn eff_bw(&self, batch: usize) -> f64 {
        // saturating utilization: b1 -> ~b1 + gain * (1 - 1/b)
        let u = self.util_decode_b1
            + self.util_batch_gain * (1.0 - 1.0 / batch as f64);
        self.mem_bw_bytes * u.min(0.85)
    }

    pub fn fits(&self, m: &LlmSpec) -> bool {
        let total = m.linear_params() as f64 * self.weight_bytes
            + 2.0 * (m.vocab * m.d_model) as f64 * self.weight_bytes;
        total < self.mem_capacity_bytes
    }

    /// One decode step (batch sequences, context ctx).
    pub fn decode_step_cost(&self, m: &LlmSpec, batch: usize, ctx: usize) -> PhaseCost {
        let weight_traffic = (m.linear_params() + m.vocab * m.d_model) as f64
            * self.weight_bytes;
        let kv_traffic = m.kv_bytes_per_token(self.kv_bytes) * ctx as f64 * batch as f64;
        let bytes = weight_traffic + kv_traffic;
        let mem_s = bytes / self.eff_bw(batch);
        // compute roofline (matters at larger batch)
        let flops = 2.0 * m.linear_params() as f64 * batch as f64;
        let comp_s = flops / (self.peak_flops * 0.5);
        let layers_overhead = self.step_overhead_s;
        let seconds = mem_s.max(comp_s) + layers_overhead;
        PhaseCost { seconds, energy_j: seconds * self.board_power_w, hbm_bytes: bytes }
    }

    pub fn generation_cost(
        &self,
        m: &LlmSpec,
        batch: usize,
        prompt_len: usize,
        out_len: usize,
    ) -> PhaseCost {
        // prefill: compute-bound at high token parallelism
        let pre_s = if prompt_len > 0 {
            let flops = 2.0 * m.linear_params() as f64 * prompt_len as f64;
            flops / (self.peak_flops * 0.45)
                + (m.linear_params() as f64 * self.weight_bytes) / self.mem_bw_bytes
        } else {
            0.0
        };
        let step = self.decode_step_cost(m, batch, prompt_len + out_len / 2);
        PhaseCost {
            seconds: pre_s + step.seconds * out_len as f64,
            energy_j: (pre_s + step.seconds * out_len as f64) * self.board_power_w,
            hbm_bytes: step.hbm_bytes * out_len as f64,
        }
    }

    pub fn decode_throughput(&self, m: &LlmSpec, batch: usize, out_len: usize) -> f64 {
        let g = self.generation_cost(m, batch, 0, out_len);
        (out_len * batch) as f64 / g.seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    #[test]
    fn quarot_faster_than_fp16_gpu() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let a = a100_fp16().decode_throughput(m, 1, 64);
        let q = quarot_w4a4().decode_throughput(m, 1, 64);
        assert!(q > a, "quarot {q} !> a100 {a}");
    }

    #[test]
    fn batch_scaling_is_steady_on_gpu() {
        // Fig 12 observation: GPUs gain with batch size.
        let m = by_name("LLaMA-2-7B").unwrap();
        let g = a100_fp16();
        let t1 = g.decode_throughput(m, 1, 64);
        let t2 = g.decode_throughput(m, 2, 64);
        let t4 = g.decode_throughput(m, 4, 64);
        assert!(t2 > 1.3 * t1 && t4 > 1.2 * t2, "{t1} {t2} {t4}");
    }

    #[test]
    fn oom_detection_on_70b_fp16() {
        // A100-80GB cannot hold LLaMA-2-70B in FP16 (Fig 11's OOM cell).
        let m = by_name("LLaMA-2-70B").unwrap();
        assert!(!a100_fp16().fits(m));
        assert!(quarot_w4a4().fits(m));
    }
}
