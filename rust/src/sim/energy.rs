//! Energy + memory-traffic accounting (the Cacti / DRAMSim3 substitute —
//! see DESIGN.md §1.2). Module energies come from Table II powers times
//! modeled busy cycles; DRAM energy uses a standard pJ/byte constant. The
//! per-component breakdown feeds Fig 18.

use std::collections::BTreeMap;

use super::config::HwConfig;
use super::gemm::GemmCost;

/// HBM access energy (pJ per byte) — DRAMSim3-class constant for HBM2.
pub const HBM_PJ_PER_BYTE: f64 = 60.0;
/// SRAM access energy per byte at 28 nm (Cacti-class, small arrays).
pub const SRAM_PJ_PER_BYTE: f64 = 0.5;

#[derive(Debug, Default, Clone)]
pub struct Breakdown {
    /// component -> value (joules for energy, bytes for traffic)
    pub by_component: BTreeMap<&'static str, f64>,
}

impl Breakdown {
    pub fn add(&mut self, component: &'static str, v: f64) {
        *self.by_component.entry(component).or_insert(0.0) += v;
    }

    pub fn total(&self) -> f64 {
        self.by_component.values().sum()
    }

    pub fn fraction(&self, component: &str) -> f64 {
        self.by_component
            .get(component)
            .copied()
            .unwrap_or(0.0)
            / self.total().max(1e-30)
    }

    pub fn merge(&mut self, other: &Breakdown) {
        for (k, v) in &other.by_component {
            self.add(k, *v);
        }
    }
}

/// On-chip memory traffic of one GEMM (bytes, reads + writes) — Fig 18(a).
pub fn gemm_traffic(hw: &HwConfig, c: &GemmCost, n_a_bits: u32) -> Breakdown {
    let mut t = Breakdown::default();
    let n_w_bits = 4u32;
    // Weight Index Buffer: stream all K*N weight indices through the
    // per-line buffers (write once from HBM, read once by the Concat Units).
    let wgt_bytes = (c.k * c.n) as f64 * n_w_bits as f64 / 8.0;
    t.add("wgt_idx_buffer", 2.0 * wgt_bytes);
    // LUT: each MAC-tree weighted sum reads the live entries; model one
    // full LUT read per output channel + the one-time load.
    let lut_bytes = (1usize << (n_a_bits + n_w_bits)) as f64 * 2.0;
    t.add("lut", lut_bytes * (c.n * c.m) as f64 + hw.lut_bytes as f64);
    // Activation Index Buffer: M*K indices written by clustering, read by
    // every PE line broadcast.
    let act_bytes = (c.m * c.k) as f64 * n_a_bits as f64 / 8.0;
    t.add("act_idx_buffer", 2.0 * act_bytes);
    // Output buffer: activations in (FP), outputs out (FP), outlier reads.
    t.add(
        "output_buffer",
        (c.m * c.k) as f64 * 2.0 + (c.m * c.n) as f64 * 2.0 * 2.0
            + c.outlier_count as f64 * 2.0,
    );
    t
}

/// Energy of one GEMM (joules) — Fig 18(b) categories.
pub fn gemm_energy(hw: &HwConfig, c: &GemmCost, n_a_bits: u32) -> Breakdown {
    let cyc = hw.cycle_s();
    let p = &hw.power_w;
    let mut e = Breakdown::default();
    // dynamic blocks: power * busy-time (powers are per Table II, which
    // reports the whole-chip module powers)
    let lines = hw.pe_lines as f64;
    e.add("clustering", p.clustering_unit * c.main.cluster as f64 * cyc);
    e.add("broadcast", p.act_idx_buffer * c.main.broadcast as f64 * cyc);
    e.add("concat", p.concat_unit * lines * c.main.concat as f64 * cyc);
    e.add("count", p.index_counter * lines * c.main.count as f64 * cyc);
    e.add("reduction", p.mac_tree * lines * c.main.mac_tree as f64 * cyc);
    e.add("orizuru", p.orizuru * (c.outlier.orizuru_init + c.outlier.orizuru_pops) as f64 * cyc);
    e.add(
        "dequant",
        p.dequant_unit * lines * c.outlier.fetch_dequant as f64 * cyc,
    );
    e.add("error_calc", p.error_calc_unit * c.outlier.error_calc as f64 * cyc);
    e.add(
        "merge",
        p.mac * hw.macs_per_line as f64 * lines
            * (c.outlier.mac + c.merge) as f64
            * cyc,
    );
    // on-chip SRAM traffic energy (HBM energy is accounted at the LLM
    // phase level — Fig 18(b) is the ON-CHIP breakdown)
    let traffic = gemm_traffic(hw, c, n_a_bits);
    e.add("sram", traffic.total() * SRAM_PJ_PER_BYTE * 1e-12);
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gemm::gemm_cost;

    #[test]
    fn fig18_weight_buffer_dominates_traffic() {
        // Fig 18(a): Weight Index Buffer ~76% of on-chip traffic, LUT ~19%.
        let hw = HwConfig::default();
        let c = gemm_cost(&hw, 1, 4096, 4096, 4, 0.01);
        let t = gemm_traffic(&hw, &c, 4);
        let f_w = t.fraction("wgt_idx_buffer");
        let f_l = t.fraction("lut");
        assert!(f_w > 0.55 && f_w < 0.9, "wgt fraction {f_w}");
        assert!(f_l > 0.08 && f_l < 0.35, "lut fraction {f_l}");
        assert!(f_w > f_l);
    }

    #[test]
    fn fig18_reduction_is_top_energy_block() {
        // Fig 18(b): reduction 33.1%, merge 22.1% lead the breakdown.
        let hw = HwConfig::default();
        let c = gemm_cost(&hw, 1, 4096, 4096, 4, 0.01);
        let e = gemm_energy(&hw, &c, 4);
        let top = e
            .by_component
            .iter()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert!(
            *top.0 == "reduction" || *top.0 == "merge",
            "top component {top:?}"
        );
    }

    #[test]
    fn energy_scales_with_work() {
        let hw = HwConfig::default();
        let small = gemm_energy(&hw, &gemm_cost(&hw, 1, 1024, 1024, 4, 0.01), 4);
        let big = gemm_energy(&hw, &gemm_cost(&hw, 1, 4096, 4096, 4, 0.01), 4);
        assert!(big.total() > 4.0 * small.total());
    }
}
