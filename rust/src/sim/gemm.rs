//! Cycle model of one M-K-N GEMM on OASIS (paper §IV-A computation flow,
//! §V-D3 pipeline). Both branches are modeled step by step; the pipeline
//! overlaps them (look-ahead design), so GEMM latency = max(main, outlier)
//! + merge. Cost formulas follow directly from the Table II unit counts.

use super::config::HwConfig;

/// Per-step cycle costs of the main (look-ahead) branch.
#[derive(Debug, Clone, Copy, Default)]
pub struct MainBranch {
    pub cluster: u64,
    pub broadcast: u64,
    pub concat: u64,
    pub count: u64,
    pub mac_tree: u64,
}

impl MainBranch {
    /// Pipelined latency: stages overlap across output channels, so the
    /// branch is bottlenecked by its slowest stage plus fill of the others.
    pub fn total(&self) -> u64 {
        let stages = [self.concat, self.count, self.mac_tree];
        let bottleneck = *stages.iter().max().unwrap();
        // cluster + broadcast happen once per token before the PE pipeline
        self.cluster + self.broadcast + bottleneck
            + stages.iter().sum::<u64>().saturating_sub(bottleneck) / 8 // fill
    }
}

/// Per-step cycle costs of the outlier branch.
#[derive(Debug, Clone, Copy, Default)]
pub struct OutlierBranch {
    pub orizuru_init: u64,
    pub orizuru_pops: u64,
    pub fetch_dequant: u64,
    pub error_calc: u64,
    pub mac: u64,
}

impl OutlierBranch {
    pub fn total(&self) -> u64 {
        // init -> (pops || error-calc) -> per-outlier fetch/dequant/mac are
        // pipelined one outlier behind the pop stream; the per-outlier MAC
        // work dominates steady state.
        self.orizuru_init + self.orizuru_pops.max(self.error_calc) + self.fetch_dequant.max(self.mac)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct GemmCost {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub main: MainBranch,
    pub outlier: OutlierBranch,
    pub merge: u64,
    /// weight-index HBM streaming cycles (overlapped with compute; the
    /// scheduler takes max(compute, memory))
    pub mem_stream: u64,
    pub outlier_count: usize,
}

impl GemmCost {
    /// End-to-end GEMM cycles with the look-ahead (parallel-branch) design.
    pub fn total_lookahead(&self) -> u64 {
        let compute = self.main.total().max(self.outlier.total()) + self.merge;
        compute.max(self.mem_stream)
    }

    /// Outlier-detection cycles (Orizuru init + pops) — the work OASIS-C
    /// serializes on the critical path.
    pub fn detect_cycles(&self) -> u64 {
        self.outlier.orizuru_init + self.outlier.orizuru_pops
    }

    /// Conventional critical-path design (paper Fig 4(a), "OASIS-C"):
    /// detection must finish before any GEMM work (or further weight
    /// consumption) starts, so it adds on top of the overlapped total.
    pub fn total_critical_path(&self) -> u64 {
        self.detect_cycles() + self.total_lookahead()
    }

    /// Reduction FP operations in the main branch (for Fig 16).
    pub fn reduction_flops(&self, n_a_bits: u32, n_w_bits: u32) -> usize {
        (1usize << (n_a_bits + n_w_bits)) * self.n * self.m
    }
}

/// Model an M-K-N GEMM at the given activation precision and outlier
/// fraction. Weights at 4 bits (the paper's only weight precision).
pub fn gemm_cost(
    hw: &HwConfig,
    m: usize,
    k: usize,
    n: usize,
    n_a_bits: u32,
    outlier_frac: f64,
) -> GemmCost {
    let n_w_bits = 4u32;
    let lut_entries = 1u64 << (n_a_bits + n_w_bits);

    // ---- main branch --------------------------------------------------
    // Clustering Units: 1 element/cycle each (binary-search tree is
    // pipelined), all M*K activation elements.
    let cluster = ((m * k) as u64).div_ceil(hw.clustering_units as u64);
    // Broadcast clustered indices to the PE lines.
    let idx_bytes = (m * k) as u64 * n_a_bits as u64 / 8;
    let broadcast = idx_bytes.div_ceil(hw.bcast_bytes_per_cycle as u64).max(1);

    // Each PE line owns N / pe_lines output channels; per channel the line
    // concatenates K index pairs (concat_units_per_line per cycle), counts
    // them (index_counters * inputs per cycle), and MAC-trees the
    // LUT-entry weighted sum (mac_tree_inputs per cycle).
    let chans_per_line = n.div_ceil(hw.pe_lines) as u64;
    let per_chan_concat = (k as u64).div_ceil(hw.concat_units_per_line as u64);
    let per_chan_count = (k as u64)
        .div_ceil((hw.index_counters_per_line * hw.index_counter_inputs) as u64);
    let per_chan_mac = lut_entries.div_ceil(hw.mac_tree_inputs as u64);
    let work = chans_per_line * m as u64;
    let main = MainBranch {
        cluster,
        broadcast,
        concat: per_chan_concat * work,
        count: per_chan_count * work,
        mac_tree: per_chan_mac * work,
    };

    // ---- outlier branch -------------------------------------------------
    let k_outliers = (((outlier_frac * k as f64) / 2.0).round() as usize).max(1) * 2;
    let total_outliers = k_outliers * m;
    // Orizuru: 16-input units, 273 of them; init does 1.5K comparisons.
    let cmp_per_cycle = (hw.orizuru_units * hw.orizuru_inputs / 16) as u64; // 1 cmp/unit/cycle
    let orizuru_init = ((1.5 * k as f64) as u64).div_ceil(cmp_per_cycle) * m as u64;
    // each pop requires log2(K) *sequential* maintenance comparisons
    // (paper §IV-D), so pops stream out one per log2(K) cycles
    let log2k = (usize::BITS - (k - 1).leading_zeros()) as u64;
    let orizuru_pops = total_outliers as u64 * log2k;
    // per outlier: fetch the weight-index input channel (N indices across
    // the lines), dequantize (1 dequant unit per line), MAC into outputs
    // (macs_per_line per line per cycle).
    let chans = n.div_ceil(hw.pe_lines) as u64;
    let fetch_per_outlier = chans.div_ceil(16); // 16 idx/cycle from buffer
    let dequant_per_outlier = chans.div_ceil(16); // LUT-read pipelined x16
    let mac_per_outlier = chans.div_ceil(hw.macs_per_line as u64);
    let outlier = OutlierBranch {
        orizuru_init,
        orizuru_pops,
        fetch_dequant: (fetch_per_outlier + dequant_per_outlier) * total_outliers as u64 / 2,
        error_calc: total_outliers as u64, // 1/cycle in the Error Calc Unit
        mac: mac_per_outlier * total_outliers as u64,
    };

    // ---- merge + memory ------------------------------------------------
    let merge = (n as u64 * m as u64).div_ceil((hw.macs_per_line * hw.pe_lines) as u64);
    let wgt_idx_bytes = (k * n) as u64 * n_w_bits as u64 / 8;
    let mem_stream = (wgt_idx_bytes as f64 / hw.hbm_bytes_per_cycle()).ceil() as u64;

    GemmCost {
        m,
        k,
        n,
        main,
        outlier,
        merge,
        mem_stream,
        outlier_count: total_outliers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwConfig {
        HwConfig::default()
    }

    #[test]
    fn paper_pipeline_balance_at_1pct() {
        // §V-D3: at 1% outliers the two branches are comparable, outlier
        // branch ~33% faster (so main dominates).
        let c = gemm_cost(&hw(), 1, 4096, 4096, 4, 0.01);
        let main = c.main.total() as f64;
        let outl = c.outlier.total() as f64;
        assert!(outl < main, "outlier {outl} !< main {main}");
        assert!(outl > 0.3 * main, "branches should be comparable: {outl} vs {main}");
    }

    #[test]
    fn outlier_heavy_flips_bottleneck() {
        // Fig 15: beyond ~1% the outlier branch becomes the bottleneck.
        let lo = gemm_cost(&hw(), 1, 4096, 4096, 4, 0.01);
        let hi = gemm_cost(&hw(), 1, 4096, 4096, 4, 0.10);
        assert!(lo.outlier.total() < lo.main.total());
        assert!(hi.outlier.total() > hi.main.total());
    }

    #[test]
    fn lookahead_beats_critical_path() {
        // §V-D4: OASIS vs OASIS-C ~16-18% at 1% outliers.
        let c = gemm_cost(&hw(), 1, 4096, 4096, 4, 0.01);
        let la = c.total_lookahead() as f64;
        let cp = c.total_critical_path() as f64;
        assert!(cp > la, "critical path {cp} !> lookahead {la}");
        let gain = cp / la - 1.0;
        assert!(gain > 0.02 && gain < 0.6, "gain {gain}");
    }

    #[test]
    fn reduction_independent_of_k() {
        let a = gemm_cost(&hw(), 1, 1024, 4096, 4, 0.01);
        let b = gemm_cost(&hw(), 1, 8192, 4096, 4, 0.01);
        assert_eq!(a.main.mac_tree, b.main.mac_tree);
        assert_eq!(a.reduction_flops(4, 4), b.reduction_flops(4, 4));
    }

    #[test]
    fn memory_streaming_scales_with_weights() {
        let a = gemm_cost(&hw(), 1, 4096, 4096, 4, 0.01);
        let b = gemm_cost(&hw(), 1, 4096, 8192, 4, 0.01);
        assert!((b.mem_stream as f64 / a.mem_stream as f64 - 2.0).abs() < 0.01);
    }

    #[test]
    fn a3_smaller_lut_work_than_a4() {
        let a3 = gemm_cost(&hw(), 1, 4096, 4096, 3, 0.01);
        let a4 = gemm_cost(&hw(), 1, 4096, 4096, 4, 0.01);
        assert!(a3.main.mac_tree < a4.main.mac_tree);
    }
}
