//! Cycle-level OASIS accelerator simulator (the DnnWeaver-derived simulator
//! substitute): Table II configuration, per-GEMM dual-branch cycle model,
//! pipeline schedules (Fig 14), energy/traffic accounting (Fig 18), and the
//! LLM phase model behind Figs 11-13 and 15.

pub mod config;
pub mod energy;
pub mod gemm;
pub mod llm;
pub mod pipeline;

pub use config::HwConfig;
pub use gemm::{gemm_cost, GemmCost};
pub use llm::{decode_step_cost, decode_throughput, generation_cost, OasisMode, PhaseCost};
