//! Pipeline schedule of one GEMM across the two branches (paper Fig 14):
//! per-step start/duration in cycles, with the bottleneck step of each
//! stage flagged. `kllm experiment fig14` renders this as the paper does.

use super::config::HwConfig;
use super::gemm::{gemm_cost, GemmCost};

#[derive(Debug, Clone)]
pub struct Step {
    pub branch: &'static str,
    pub name: &'static str,
    pub start: u64,
    pub cycles: u64,
    pub bottleneck: bool,
}

#[derive(Debug, Clone)]
pub struct Schedule {
    pub steps: Vec<Step>,
    pub main_end: u64,
    pub outlier_end: u64,
    pub total: u64,
}

pub fn schedule(hw: &HwConfig, m: usize, k: usize, n: usize, n_a_bits: u32, outlier_frac: f64) -> Schedule {
    let c: GemmCost = gemm_cost(hw, m, k, n, n_a_bits, outlier_frac);
    let mut steps = Vec::new();

    // ---- main branch: cluster -> broadcast -> {concat, count, mac} ------
    let mut t = 0u64;
    let mb = [
        ("cluster", c.main.cluster),
        ("broadcast", c.main.broadcast),
        ("concat", c.main.concat),
        ("count", c.main.count),
        ("mac_tree", c.main.mac_tree),
    ];
    let main_max = mb.iter().map(|&(_, d)| d).max().unwrap();
    for (name, d) in mb {
        steps.push(Step { branch: "main", name, start: t, cycles: d, bottleneck: d == main_max });
        // concat/count/mac_tree are pipelined: successors start one
        // pipeline beat later, not after full completion
        let pipelined = matches!(name, "concat" | "count");
        t += if pipelined { d.div_ceil(8).max(1) } else { d };
    }
    let main_end = steps
        .iter()
        .filter(|s| s.branch == "main")
        .map(|s| s.start + s.cycles)
        .max()
        .unwrap();

    // ---- outlier branch ---------------------------------------------------
    let mut t = 0u64;
    let ob = [
        ("orizuru_init", c.outlier.orizuru_init),
        ("orizuru_pop", c.outlier.orizuru_pops),
        ("fetch+dequant", c.outlier.fetch_dequant),
        ("error_calc", c.outlier.error_calc),
        ("mac", c.outlier.mac),
    ];
    let out_max = ob.iter().map(|&(_, d)| d).max().unwrap();
    for (name, d) in ob {
        steps.push(Step { branch: "outlier", name, start: t, cycles: d, bottleneck: d == out_max });
        let pipelined = matches!(name, "orizuru_pop" | "fetch+dequant" | "error_calc");
        t += if pipelined { d.div_ceil(8).max(1) } else { d };
    }
    let outlier_end = steps
        .iter()
        .filter(|s| s.branch == "outlier")
        .map(|s| s.start + s.cycles)
        .max()
        .unwrap();

    // ---- merge ------------------------------------------------------------
    let merge_start = main_end.max(outlier_end);
    steps.push(Step {
        branch: "merge",
        name: "merge",
        start: merge_start,
        cycles: c.merge,
        bottleneck: false,
    });

    Schedule { steps, main_end, outlier_end, total: merge_start + c.merge }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_shape_at_1pct() {
        // 1-4096-4096, W4A4, 1% outliers: outlier branch finishes first
        // (§V-D3: "approximately 33% faster").
        let s = schedule(&HwConfig::default(), 1, 4096, 4096, 4, 0.01);
        assert!(s.outlier_end < s.main_end, "{:?}", (s.outlier_end, s.main_end));
        let ratio = s.outlier_end as f64 / s.main_end as f64;
        assert!(ratio < 0.95 && ratio > 0.2, "ratio {ratio}");
        // merge is last
        let merge = s.steps.last().unwrap();
        assert_eq!(merge.name, "merge");
        assert_eq!(merge.start, s.main_end.max(s.outlier_end));
    }

    #[test]
    fn heavy_outliers_flip_finish_order() {
        let s = schedule(&HwConfig::default(), 1, 4096, 4096, 4, 0.10);
        assert!(s.outlier_end > s.main_end);
    }

    #[test]
    fn exactly_one_bottleneck_flag_per_branch_at_least() {
        let s = schedule(&HwConfig::default(), 1, 4096, 4096, 4, 0.01);
        for b in ["main", "outlier"] {
            assert!(s.steps.iter().any(|st| st.branch == b && st.bottleneck), "{b}");
        }
    }

    #[test]
    fn steps_are_causally_ordered() {
        let s = schedule(&HwConfig::default(), 1, 2048, 2048, 4, 0.01);
        for b in ["main", "outlier"] {
            let mut last_start = 0;
            for st in s.steps.iter().filter(|st| st.branch == b) {
                assert!(st.start >= last_start);
                last_start = st.start;
            }
        }
        assert!(s.total >= s.main_end && s.total >= s.outlier_end);
    }
}
