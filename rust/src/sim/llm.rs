//! End-to-end LLM phase model on OASIS: maps a model's decoder layers to
//! GEMM costs, adds attention (KV-cache streaming) and embedding-head
//! costs, and produces per-token latency/energy for prefill and decode —
//! the engine behind Figs 11, 12, 13 and 15(b, c).

use super::config::HwConfig;
use super::energy::{gemm_energy, Breakdown, HBM_PJ_PER_BYTE};
use super::gemm::{gemm_cost, GemmCost};
use crate::models::LlmSpec;

#[derive(Clone, Copy, Debug)]
pub struct OasisMode {
    pub n_a_bits: u32,
    pub outlier_frac: f64,
    /// look-ahead (OASIS) vs critical-path (OASIS-C)
    pub lookahead: bool,
}

impl OasisMode {
    pub fn a4() -> Self {
        OasisMode { n_a_bits: 4, outlier_frac: 0.01, lookahead: true }
    }

    pub fn a3() -> Self {
        OasisMode { n_a_bits: 3, outlier_frac: 0.01, lookahead: true }
    }

    /// KV-cache element bytes: activations quantized to nA bits.
    pub fn kv_bytes_per_elem(&self) -> f64 {
        self.n_a_bits as f64 / 8.0
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseCost {
    pub seconds: f64,
    pub energy_j: f64,
    /// HBM bytes moved
    pub hbm_bytes: f64,
}

/// One decode step for a batch of `batch` sequences at context length
/// `ctx`. Weight streaming is amortized across the batch (read once per
/// step); KV traffic is per sequence.
pub fn decode_step_cost(
    hw: &HwConfig,
    m: &LlmSpec,
    mode: OasisMode,
    batch: usize,
    ctx: usize,
) -> PhaseCost {
    let mut compute_cycles = 0u64;
    let mut detect_extra = 0u64; // OASIS-C: detection on the critical path
    let mut energy = Breakdown::default();
    for (k, n) in m.layer_gemms() {
        let c: GemmCost = gemm_cost(hw, batch, k, n, mode.n_a_bits, mode.outlier_frac);
        // compute only; memory handled globally below
        compute_cycles += c.main.total().max(c.outlier.total()) + c.merge;
        if !mode.lookahead {
            detect_extra += c.detect_cycles();
        }
        energy.merge(&gemm_energy(hw, &c, mode.n_a_bits));
    }
    compute_cycles *= m.n_layers as u64;
    detect_extra *= m.n_layers as u64;
    // scale per-layer energy to all layers
    let mut total_energy: f64 = energy.total() * m.n_layers as f64;

    // attention: stream the KV cache (quantized to nA bits) per sequence,
    // plus FP16 score/weighted-sum MACs on the Functional Unit.
    let kv_bytes =
        m.kv_bytes_per_token(mode.kv_bytes_per_elem()) * ctx as f64 * batch as f64;
    let attn_macs = 2.0 * (m.n_heads * m.head_dim()) as f64 * ctx as f64 * batch as f64
        * m.n_layers as f64;
    let attn_cycles = attn_macs / (hw.macs_per_line * hw.pe_lines) as f64;

    // head/embedding GEMM (kept FP16-weight in OASIS? no — weights 4-bit):
    let head = gemm_cost(hw, batch, m.d_model, m.vocab, mode.n_a_bits, mode.outlier_frac);
    compute_cycles += head.main.total().max(head.outlier.total()) + head.merge;

    // HBM: all 4-bit weight indices once per step + KV + head weights
    let wgt_bytes = m.linear_params() as f64 * 0.5
        + (m.d_model * m.vocab) as f64 * 0.5;
    let hbm_bytes = wgt_bytes + kv_bytes;
    let mem_cycles = hbm_bytes / hw.hbm_bytes_per_cycle();

    let cycles = (compute_cycles as f64 + attn_cycles).max(mem_cycles) + detect_extra as f64;
    let seconds = cycles * hw.cycle_s();
    total_energy += hbm_bytes * HBM_PJ_PER_BYTE * 1e-12;
    // static leakage-ish floor: idle power of the buffers/controller
    total_energy += 0.15 * hw.total_power_w() * seconds;

    PhaseCost { seconds, energy_j: total_energy, hbm_bytes }
}

/// Prefill of `prompt_len` tokens (one pass, weights read once, compute
/// scales with tokens).
pub fn prefill_cost(
    hw: &HwConfig,
    m: &LlmSpec,
    mode: OasisMode,
    prompt_len: usize,
) -> PhaseCost {
    // prefill = decode_step with batch = prompt_len tokens and ctx ~ L/2
    decode_step_cost(hw, m, mode, prompt_len, prompt_len / 2)
}

/// Whole-generation cost: prefill + `out_len` decode steps with growing
/// context (evaluated at the mean context for closed form).
pub fn generation_cost(
    hw: &HwConfig,
    m: &LlmSpec,
    mode: OasisMode,
    batch: usize,
    prompt_len: usize,
    out_len: usize,
) -> PhaseCost {
    let pre = if prompt_len > 0 {
        prefill_cost(hw, m, mode, prompt_len)
    } else {
        PhaseCost::default()
    };
    let mid_ctx = prompt_len + out_len / 2;
    let step = decode_step_cost(hw, m, mode, batch, mid_ctx);
    PhaseCost {
        seconds: pre.seconds + step.seconds * out_len as f64,
        energy_j: pre.energy_j + step.energy_j * out_len as f64,
        hbm_bytes: pre.hbm_bytes + step.hbm_bytes * out_len as f64,
    }
}

/// tokens/sec for single-stream decode at the paper's setting.
pub fn decode_throughput(hw: &HwConfig, m: &LlmSpec, mode: OasisMode, batch: usize, out_len: usize) -> f64 {
    let g = generation_cost(hw, m, mode, batch, 0, out_len);
    (out_len * batch) as f64 / g.seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::by_name;

    fn hw() -> HwConfig {
        HwConfig::default()
    }

    #[test]
    fn decode_is_memory_bound_for_7b() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let c = decode_step_cost(&hw(), m, OasisMode::a4(), 1, 1024);
        // 4-bit weights of ~6.6B linear params ~ 3.3 GB; at 512 GB/s that is
        // ~6.5 ms — latency must be within 2x of the memory bound.
        let mem_s = c.hbm_bytes / hw().hbm_bytes_per_sec;
        assert!(c.seconds >= mem_s * 0.99);
        assert!(c.seconds < mem_s * 2.0, "{} vs {}", c.seconds, mem_s);
    }

    #[test]
    fn batching_amortizes_weights() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let t1 = decode_throughput(&hw(), m, OasisMode::a4(), 1, 64);
        let t4 = decode_throughput(&hw(), m, OasisMode::a4(), 4, 64);
        assert!(t4 > 2.0 * t1, "batch-4 {t4} vs batch-1 {t1}");
    }

    #[test]
    fn a3_faster_than_a4() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let a4 = decode_throughput(&hw(), m, OasisMode::a4(), 1, 64);
        let a3 = decode_throughput(&hw(), m, OasisMode::a3(), 1, 64);
        assert!(a3 >= a4 * 0.99, "a3 {a3} vs a4 {a4}");
    }

    #[test]
    fn bigger_models_slower() {
        let s = decode_throughput(&hw(), by_name("LLaMA-2-7B").unwrap(), OasisMode::a4(), 1, 32);
        let b = decode_throughput(&hw(), by_name("LLaMA-2-70B").unwrap(), OasisMode::a4(), 1, 32);
        assert!(s > 5.0 * b, "7B {s} vs 70B {b}");
    }

    #[test]
    fn lookahead_beats_critical_path_end_to_end() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let la = decode_throughput(&hw(), m, OasisMode::a4(), 1, 32);
        let cp = decode_throughput(
            &hw(),
            m,
            OasisMode { lookahead: false, ..OasisMode::a4() },
            1,
            32,
        );
        assert!(la > cp, "la {la} !> cp {cp}");
    }

    #[test]
    fn energy_positive_and_scales() {
        let m = by_name("LLaMA-2-7B").unwrap();
        let g1 = generation_cost(&hw(), m, OasisMode::a4(), 1, 128, 64);
        let g2 = generation_cost(&hw(), m, OasisMode::a4(), 1, 128, 128);
        assert!(g1.energy_j > 0.0 && g2.energy_j > 1.5 * g1.energy_j);
    }
}
