//! OASIS accelerator configuration — paper Table II (28 nm, 500 MHz).
//! Area (mm^2) and power (W) constants are the paper's published synthesis
//! numbers; the simulator multiplies module power by modeled busy time for
//! the energy breakdowns (Fig 18) and end-to-end energy (Figs 11-13).

/// Hardware configuration of one OASIS chip.
#[derive(Clone, Debug)]
pub struct HwConfig {
    pub clock_hz: f64,
    pub pe_lines: usize,
    pub concat_units_per_line: usize,
    pub index_counters_per_line: usize,
    pub index_counter_inputs: usize,
    pub mac_tree_inputs: usize,
    pub macs_per_line: usize,
    pub clustering_units: usize,
    pub orizuru_units: usize,
    pub orizuru_inputs: usize,
    /// broadcast bus width for activation indices (bytes/cycle)
    pub bcast_bytes_per_cycle: usize,
    /// weight-index buffer per line (bytes)
    pub wgt_idx_buffer_bytes: usize,
    pub output_buffer_bytes: usize,
    pub act_idx_buffer_bytes: usize,
    pub lut_bytes: usize,
    /// off-chip HBM bandwidth (bytes/s)
    pub hbm_bytes_per_sec: f64,
    pub area_mm2: AreaModel,
    pub power_w: PowerModel,
}

#[derive(Clone, Debug)]
pub struct AreaModel {
    pub pe_lines_total: f64,
    pub concat_unit: f64,
    pub wgt_idx_buffer: f64,
    pub index_counter: f64,
    pub dequant_unit: f64,
    pub mac_tree: f64,
    pub mac: f64,
    pub output_buffer: f64,
    pub act_idx_buffer: f64,
    pub lut: f64,
    pub clustering_unit: f64,
    pub orizuru: f64,
    pub error_calc_unit: f64,
    pub func_unit: f64,
    pub memory_controller: f64,
}

#[derive(Clone, Debug)]
pub struct PowerModel {
    pub pe_lines_total: f64,
    pub concat_unit: f64,
    pub wgt_idx_buffer: f64,
    pub index_counter: f64,
    pub dequant_unit: f64,
    pub mac_tree: f64,
    pub mac: f64,
    pub output_buffer: f64,
    pub act_idx_buffer: f64,
    pub lut: f64,
    pub clustering_unit: f64,
    pub orizuru: f64,
    pub error_calc_unit: f64,
    pub func_unit: f64,
    pub memory_controller: f64,
}

impl Default for HwConfig {
    /// Paper Table II verbatim.
    fn default() -> Self {
        HwConfig {
            clock_hz: 500e6,
            pe_lines: 16,
            concat_units_per_line: 4096,
            index_counters_per_line: 32,
            index_counter_inputs: 16,
            mac_tree_inputs: 32,
            macs_per_line: 8,
            clustering_units: 4,
            orizuru_units: 273,
            orizuru_inputs: 16,
            bcast_bytes_per_cycle: 64,
            wgt_idx_buffer_bytes: 2 * 1024,
            output_buffer_bytes: 64 * 1024,
            act_idx_buffer_bytes: 16 * 1024,
            lut_bytes: 2 * 1024,
            // Edge-class HBM (see DESIGN.md §1.3: calibrated so OASIS's
            // memory-bound decode reproduces the paper's FIGLUT ratios).
            hbm_bytes_per_sec: 512e9,
            area_mm2: AreaModel {
                pe_lines_total: 9.08,
                concat_unit: 8.68e-2,
                wgt_idx_buffer: 6.75e-2,
                index_counter: 2.71e-1,
                dequant_unit: 2.83e-3,
                mac_tree: 1.17e-1,
                mac: 2.26e-2,
                output_buffer: 2.17,
                act_idx_buffer: 5.40e-1,
                lut: 6.75e-2,
                clustering_unit: 1.31e-3,
                orizuru: 7.39e-1,
                error_calc_unit: 4.12e-3,
                func_unit: 8.89e-1,
                memory_controller: 1.47,
            },
            power_w: PowerModel {
                pe_lines_total: 7.54,
                concat_unit: 8.36e-2,
                wgt_idx_buffer: 1.69e-2,
                index_counter: 6.14e-2,
                dequant_unit: 6.11e-3,
                mac_tree: 2.54e-1,
                mac: 4.89e-2,
                output_buffer: 2.68e-1,
                act_idx_buffer: 6.71e-2,
                lut: 8.38e-3,
                clustering_unit: 2.90e-4,
                orizuru: 2.73e-1,
                error_calc_unit: 6.40e-3,
                func_unit: 5.63e-1,
                memory_controller: 9.28e-1,
            },
        }
    }
}

impl HwConfig {
    /// Total chip area (Table II bottom row: 15.31 mm^2).
    pub fn total_area_mm2(&self) -> f64 {
        let a = &self.area_mm2;
        a.pe_lines_total
            + a.output_buffer
            + a.act_idx_buffer
            + a.lut
            + a.clustering_unit
            + a.orizuru
            + a.error_calc_unit
            + a.func_unit
            + a.memory_controller
    }

    /// Total chip power (Table II bottom row: 9.66 W).
    pub fn total_power_w(&self) -> f64 {
        let p = &self.power_w;
        p.pe_lines_total
            + p.output_buffer
            + p.act_idx_buffer
            + p.lut
            + p.clustering_unit
            + p.orizuru
            + p.error_calc_unit
            + p.func_unit
            + p.memory_controller
    }

    pub fn cycle_s(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// HBM bytes transferable per clock cycle.
    pub fn hbm_bytes_per_cycle(&self) -> f64 {
        self.hbm_bytes_per_sec / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals() {
        let c = HwConfig::default();
        // paper total is 15.31 mm^2 / 9.66 W; summing the table's major
        // rows reproduces it within rounding of the per-line sub-items
        assert!((c.total_area_mm2() - 15.31).abs() < 0.4, "{}", c.total_area_mm2());
        assert!((c.total_power_w() - 9.66).abs() < 0.4, "{}", c.total_power_w());
    }

    #[test]
    fn derived_rates() {
        let c = HwConfig::default();
        assert!((c.cycle_s() - 2e-9).abs() < 1e-15);
        assert!((c.hbm_bytes_per_cycle() - 1024.0).abs() < 1.0);
    }
}
