//! The Cartesian-Product LUT (paper §III-B): all 2^(nA+nW) products of
//! activation x weight centroids, precomputed offline and resident on-chip.
//! Layout matches the concatenated index `cat = ia << nW | iw` used by the
//! Concat Units and by the L1 Pallas kernels.

use crate::quant::Codebook;

#[derive(Clone, Debug)]
pub struct CartesianLut {
    pub table: Vec<f32>,
    pub n_a_bits: u32,
    pub n_w_bits: u32,
}

impl CartesianLut {
    pub fn build(cb_a: &Codebook, cb_w: &Codebook) -> Self {
        let n_a_bits = cb_a.bits();
        let n_w_bits = cb_w.bits();
        let mut table = Vec::with_capacity(1 << (n_a_bits + n_w_bits));
        for &ca in &cb_a.centroids {
            for &cw in &cb_w.centroids {
                table.push(ca * cw);
            }
        }
        CartesianLut { table, n_a_bits, n_w_bits }
    }

    #[inline]
    pub fn cat(&self, ia: u8, iw: u8) -> usize {
        ((ia as usize) << self.n_w_bits) | iw as usize
    }

    #[inline]
    pub fn lookup(&self, ia: u8, iw: u8) -> f32 {
        self.table[self.cat(ia, iw)]
    }

    pub fn entries(&self) -> usize {
        self.table.len()
    }

    /// On-chip bytes at FP16 storage for the full lookup state the Table II
    /// budget covers: the Cartesian-product table PLUS both centroid
    /// codebooks (the Clustering Unit needs them resident too). At 4+4-bit
    /// that is 256 * 2 + (16 + 16) * 2 = 576 B, well inside the 2 KB LUT
    /// buffer provisioned per PE line.
    pub fn storage_bytes(&self) -> usize {
        let codebooks = (1usize << self.n_a_bits) + (1usize << self.n_w_bits);
        (self.table.len() + codebooks) * 2
    }
}

/// Table I analytics: LUT sizes / group sizes / reduction FLOPs for the
/// paper's scheme-comparison table (entries, not bytes).
pub mod analytics {
    /// Ours: LUT entries = 2^(nA+nW), independent of K.
    pub fn waq_lut_entries(n_a_bits: u32, n_w_bits: u32) -> usize {
        1usize << (n_a_bits + n_w_bits)
    }

    /// WOQ inner-product LUT entries for reduction length K, group size mu:
    /// 2^mu entries per group, K/mu groups (Table I: `2^mu * K/mu`).
    pub fn woq_lut_entries(k: usize, mu: usize) -> usize {
        (1usize << mu) * k.div_ceil(mu)
    }

    /// Ours: FP additions per output tile of N channels = 2^(nA+nW) * N
    /// (one weighted sum per channel), independent of K.
    pub fn waq_reduction_flops(n_a_bits: u32, n_w_bits: u32, n: usize) -> usize {
        waq_lut_entries(n_a_bits, n_w_bits) * n
    }

    /// WOQ: K/mu partial sums per bit-plane, n_w bit-planes, N channels
    /// (Table I: `K/mu * n_w * N`).
    pub fn woq_reduction_flops(k: usize, mu: usize, n_w_bits: u32, n: usize) -> usize {
        k.div_ceil(mu) * n_w_bits as usize * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lut_is_outer_product() {
        let mut rng = Rng::new(1);
        let cb_a = Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = Codebook::new(rng.normal_vec(16, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        assert_eq!(lut.entries(), 256);
        for ia in 0..16u8 {
            for iw in 0..16u8 {
                assert_eq!(
                    lut.lookup(ia, iw),
                    cb_a.value(ia) * cb_w.value(iw),
                    "({ia},{iw})"
                );
            }
        }
    }

    #[test]
    fn paper_table1_numbers() {
        use analytics::*;
        // the paper's running example: K = N = 4096, nA = nW = 4, mu = 4
        let (k, n) = (4096, 4096);
        assert_eq!(waq_lut_entries(4, 4), 256);
        assert_eq!(woq_lut_entries(k, 4), 16 * 1024);
        // 64x LUT-size reduction claimed in §III-B
        assert_eq!(woq_lut_entries(k, 4) / waq_lut_entries(4, 4), 64);
        // 16x FLOP reduction claimed in §III-B
        assert_eq!(
            woq_reduction_flops(k, 4, 4, n) / waq_reduction_flops(4, 4, n),
            16
        );
    }

    #[test]
    fn storage_counts_table_and_codebooks() {
        let mut rng = Rng::new(3);
        // the paper's 4+4-bit running configuration
        let cb_a = Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = Codebook::new(rng.normal_vec(16, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        // 256 fp16 products + 16 fp16 centroids per side
        assert_eq!(lut.storage_bytes(), 256 * 2 + 32 * 2);
        assert!(lut.storage_bytes() <= 2048, "must fit the 2 KB LUT buffer");
        // asymmetric config counts each codebook at its own size
        let cb_a3 = Codebook::new(rng.normal_vec(8, 1.0));
        let lut34 = CartesianLut::build(&cb_a3, &cb_w);
        assert_eq!(lut34.storage_bytes(), 128 * 2 + (8 + 16) * 2);
    }

    #[test]
    fn mixed_bitwidths() {
        let mut rng = Rng::new(2);
        let cb_a = Codebook::new(rng.normal_vec(8, 1.0)); // 3-bit activations
        let cb_w = Codebook::new(rng.normal_vec(16, 1.0)); // 4-bit weights
        let lut = CartesianLut::build(&cb_a, &cb_w);
        assert_eq!(lut.entries(), 128);
        assert_eq!(lut.cat(7, 15), 127);
        assert_eq!(lut.lookup(5, 9), cb_a.value(5) * cb_w.value(9));
    }
}
