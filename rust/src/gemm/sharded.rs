//! Tensor-parallel column sharding of the packed WAQ LUT-GEMM.
//!
//! The index-based LUT-GEMM is embarrassingly parallel across output
//! columns: every output channel owns its own accumulator, its own scale,
//! and its own weight-index column, and the Cartesian LUT is replicated
//! read-only state. This module exploits that the same way tensor-parallel
//! serving does — each [`WaqGemm`](super::WaqGemm)-shaped matrix is split
//! into `S` column shards *at load time* ([`PackedWeights::slice_cols`]:
//! stream width (2/3/4-bit) and packing preserved, codebook / column
//! scales / per-group scale grid / outlier-dequant state partitioned per
//! shard, per-shard LUT replica), and one GEMM call executes all shards
//! concurrently on a persistent worker pool. One constructor serves every
//! bit-width — the shard never inspects the stream density.
//!
//! # No concat copies, all-gather at nonlinearity boundaries
//!
//! Each shard writes directly into its disjoint column slice of the
//! shared per-token output rows (`split_at_mut`, no post-hoc concat). The
//! "all-gather" of tensor-parallel serving is therefore zero-copy shared
//! memory: the only synchronization is the per-GEMM latch, and a full row
//! is first *consumed* at the next nonlinearity (norm / softmax / GELU) —
//! exactly the boundary where a multi-device TP implementation would
//! gather. Attention stays unsharded (it is FP row arithmetic over the
//! paged KV cache, not a LUT-GEMM; see `coordinator::backend::sharded`).
//!
//! # Bit-exactness
//!
//! Per output column the shard kernel performs the identical FP additions
//! in the identical order as the unsharded packed kernel (k pairs
//! ascending, odd tail, `tok.scale * col_scale` scaling, then outlier
//! compensation in detection order), so sharded results are bit-identical
//! to [`super::packed::execute_batch_tiled`] — and hence to
//! `execute_direct` — at every shard count, including uneven splits.
//!
//! # Scaling limit
//!
//! The fused pair-table build (`2^(2*nW)` adds per K pair) is replicated
//! in every shard — it amortizes over the shard's *column width*, not the
//! full N. Narrow shards therefore pay a relatively larger build tax:
//! ideal speedup at S shards is `(B + N) / (B + N/S)` with `B = 256`
//! build adds per pair, which the `shard_scaling` bench's efficiency
//! column makes visible. Widen per-shard columns (fewer shards, bigger
//! N) to approach linear scaling.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::compensation::compensate_packed;
use super::lut::CartesianLut;
use super::packed::{accumulate_tiles, even_ranges};
use crate::quant::{PackedWeights, QuantToken};

/// K-pair tile depth used inside every shard (the same default the
/// unsharded batched kernel uses; per-column accumulation order — and
/// therefore bit-exactness — does not depend on it).
const SHARD_K_PAIR_BLOCK: usize = 128;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Countdown latch joining one round of shard jobs.
struct Latch {
    /// (jobs still running, any job panicked)
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, false)), cv: Condvar::new() }
    }

    fn done(&self, panicked: bool) {
        let mut g = self.state.lock().unwrap();
        g.0 -= 1;
        g.1 |= panicked;
        if g.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job finished; returns whether any panicked.
    fn wait(&self) -> bool {
        let mut g = self.state.lock().unwrap();
        while g.0 > 0 {
            g = self.cv.wait(g).unwrap();
        }
        g.1
    }
}

/// Persistent worker pool for shard execution: `S` long-lived threads fed
/// per-GEMM job rounds over channels, joined by a countdown latch. The
/// pool outlives individual GEMM calls (workers are spawned once per
/// backend, not per MatMul), which is what makes per-step sharding cheap
/// enough for decode-sized GEMMs.
pub struct ShardPool {
    txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `workers` persistent shard threads. Zero workers is a config
    /// error (`--shards 0`), reported as `Err`, never a panic.
    pub fn new(workers: usize) -> Result<ShardPool, String> {
        if workers == 0 {
            return Err("shard pool needs >= 1 worker (got --shards 0)".into());
        }
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("kllm-shard-{i}"))
                .spawn(move || {
                    // run until the pool drops its sender
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .map_err(|e| format!("spawn shard worker {i}: {e}"))?;
            txs.push(tx);
            handles.push(handle);
        }
        Ok(ShardPool { txs, handles })
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Execute one round of jobs on the persistent workers (job `i` runs
    /// on worker `i % workers`; extra jobs queue per worker) and block
    /// until all of them finish. Panics if any job panicked or a worker
    /// died mid-round — in every case only *after* the latch has drained,
    /// so no job is abandoned mid-borrow.
    pub fn run(&self, jobs: Vec<Box<dyn FnOnce() + Send + '_>>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        let mut send_failed = false;
        for (i, job) in jobs.into_iter().enumerate() {
            if send_failed {
                // round aborted: count the undispatched job down so the
                // latch still drains to zero
                latch.done(true);
                continue;
            }
            let l = latch.clone();
            let wrapped: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let panicked = catch_unwind(AssertUnwindSafe(job)).is_err();
                l.done(panicked);
            });
            // SAFETY: lifetime erasure only — `run` never returns (or
            // unwinds) before the latch has drained: every dispatched job
            // counts down after running, a failed send counts its
            // never-run job down right here (the closure comes back
            // inside the SendError and is dropped without executing), and
            // both panic exits below sit after `latch.wait()`. So no
            // borrow captured by a job outlives this call, and a worker
            // never holds a job beyond its invocation.
            let wrapped: Job = unsafe {
                Box::from_raw(Box::into_raw(wrapped) as *mut (dyn FnOnce() + Send + 'static))
            };
            if self.txs[i % self.txs.len()].send(wrapped).is_err() {
                latch.done(true);
                send_failed = true;
            }
        }
        let job_panicked = latch.wait();
        if send_failed {
            panic!("shard worker exited mid-round");
        }
        if job_panicked {
            panic!("shard worker job panicked");
        }
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // closing the channels ends each worker's recv loop
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One column shard: a contiguous output-column slice of the packed
/// weights (at whatever stream width the full matrix carries — the
/// kernel is width-generic) plus its own LUT replica (read-only state is
/// per-shard, as it would be per-rank in multi-device tensor
/// parallelism).
struct Shard {
    w: PackedWeights,
    lut: CartesianLut,
}

impl Shard {
    /// Full dual-branch forward for this shard's columns, written straight
    /// into the callers' per-token output slices (each `w.n_cols` wide):
    /// main-branch accumulation (k-pairs ascending + tail), per-column
    /// scaling, then outlier compensation — the exact per-column op order
    /// of the unsharded `execute_batch` + compensation path.
    fn run(&self, toks: &[QuantToken], mut outs: Vec<&mut [f32]>) {
        for o in outs.iter_mut() {
            o.fill(0.0);
        }
        accumulate_tiles(toks, &self.w, &self.lut, SHARD_K_PAIR_BLOCK, &mut outs);
        for (tok, o) in toks.iter().zip(outs.iter_mut()) {
            for (a, &s) in o.iter_mut().zip(&self.w.col_scales) {
                *a *= tok.scale * s;
            }
        }
        // outlier branch on this shard's columns: the canonical
        // compensation routine over the shard's sliced weights (per-column
        // values are bit-identical to the full matrix's, so this is the
        // same math the unsharded compensation applies)
        for (tok, o) in toks.iter().zip(outs.iter_mut()) {
            compensate_packed(o, tok, &self.w);
        }
    }
}

/// A prepared tensor-parallel WAQ GEMM: `S` column shards of one packed
/// weight matrix, executed concurrently on a shared persistent
/// [`ShardPool`]. Bit-exact with the unsharded packed kernel (plus
/// `compensate_packed`) at every shard count.
pub struct ShardedWaqGemm {
    shards: Vec<Shard>,
    pool: Arc<ShardPool>,
    n_rows: usize,
    n_cols: usize,
}

impl ShardedWaqGemm {
    /// Split `w` into (at most) `shards` contiguous column shards —
    /// uneven splits are fine; when `n_cols < shards` the surplus shards
    /// are simply empty and dropped. Works at every stream width (2/3/4
    /// bits — including the speculative draft's 2-bit regime, which used
    /// to need its own constructor). `shards == 0` is a config error.
    pub fn from_packed(
        w: &PackedWeights,
        lut: &CartesianLut,
        shards: usize,
        pool: Arc<ShardPool>,
    ) -> Result<ShardedWaqGemm, String> {
        if shards == 0 {
            return Err("shard count must be >= 1 (got 0)".into());
        }
        let n = w.n_cols;
        // the same chunking the tiled kernel uses for its thread ranges —
        // one definition, so the two paths can never split differently
        let parts: Vec<Shard> = even_ranges(n, shards)
            .into_iter()
            .map(|(j0, j1)| Shard { w: w.slice_cols(j0, j1), lut: lut.clone() })
            .collect();
        Ok(ShardedWaqGemm {
            shards: parts,
            pool,
            n_rows: w.n_rows,
            n_cols: n,
        })
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Effective shard count (after dropping empty column ranges).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Batched dual-branch forward into caller-allocated output rows
    /// (each `n_cols` long; contents are overwritten). Every shard runs
    /// concurrently on the pool and writes its own column slice of each
    /// row — no gather copies. Returns the slowest shard's wall-clock
    /// nanoseconds (the step's tensor-parallel critical path).
    pub fn execute_batch_into(&self, toks: &[QuantToken], out: &mut [Vec<f32>]) -> u64 {
        assert_eq!(toks.len(), out.len(), "token/output arity mismatch");
        for t in toks {
            assert_eq!(t.idx.len(), self.n_rows, "reduction length mismatch");
        }
        for row in out.iter() {
            assert_eq!(row.len(), self.n_cols, "output row width mismatch");
        }
        if toks.is_empty() {
            return 0;
        }
        // carve each token's row into per-shard disjoint slices
        let mut per_shard: Vec<Vec<&mut [f32]>> = self
            .shards
            .iter()
            .map(|_| Vec::with_capacity(out.len()))
            .collect();
        for row in out.iter_mut() {
            let mut rest: &mut [f32] = row.as_mut_slice();
            for (si, sh) in self.shards.iter().enumerate() {
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(sh.w.n_cols);
                per_shard[si].push(head);
                rest = tail;
            }
            debug_assert!(rest.is_empty());
        }
        let mut times = vec![0u64; self.shards.len()];
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(self.shards.len());
        for ((sh, slices), t) in self.shards.iter().zip(per_shard).zip(times.iter_mut()) {
            jobs.push(Box::new(move || {
                let t0 = Instant::now();
                sh.run(toks, slices);
                *t = t0.elapsed().as_nanos() as u64;
            }));
        }
        self.pool.run(jobs);
        times.into_iter().max().unwrap_or(0)
    }

    /// Allocating convenience over [`Self::execute_batch_into`], which is
    /// the primary entry point: callers that need the critical-path
    /// timing (the serving backend) or want to reuse output buffers
    /// across calls (the scaling bench) pass their own rows to `_into`.
    pub fn execute_batch(&self, toks: &[QuantToken]) -> Vec<Vec<f32>> {
        let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; self.n_cols]).collect();
        self.execute_batch_into(toks, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{compensate_packed, execute_batch_tiled, TileCfg};
    use crate::quant::{self, OutlierCfg, QuantToken, QuantWeights};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn setup(
        seed: u64,
        k: usize,
        n: usize,
        batch: usize,
        outliers: bool,
    ) -> (Vec<QuantToken>, QuantWeights, CartesianLut) {
        let mut rng = Rng::new(seed);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> =
            (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: 0.04 };
        let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
        let toks: Vec<QuantToken> = (0..batch)
            .map(|_| {
                let x = rng.heavy_tailed_vec(k, 0.02, 8.0);
                if outliers {
                    quant::quantize_token(&x, &cb, cfg)
                } else {
                    quant::quantize_token_with_outliers(&x, &cb, &[])
                }
            })
            .collect();
        let lut = CartesianLut::build(&cb, &qw.codebook);
        (toks, qw, lut)
    }

    fn reference(toks: &[QuantToken], qw: &QuantWeights, lut: &CartesianLut) -> Vec<Vec<f32>> {
        let pw = qw.pack();
        let mut want = execute_batch_tiled(toks, &pw, lut, &TileCfg::single_thread());
        for (o, t) in want.iter_mut().zip(toks) {
            compensate_packed(o, t, &pw);
        }
        want
    }

    #[test]
    fn sharded_bit_exact_even_and_uneven_splits() {
        // odd K (tail row), N not divisible by the shard count, N < shards
        for &(k, n, batch) in &[(64usize, 24usize, 3usize), (65, 23, 5), (9, 3, 1), (1, 8, 2)] {
            let (toks, qw, lut) = setup(100 + k as u64, k, n, batch, true);
            let want = reference(&toks, &qw, &lut);
            let pw = qw.pack();
            for shards in [1usize, 2, 3, 4, 7] {
                let pool = Arc::new(ShardPool::new(shards).unwrap());
                let sh = ShardedWaqGemm::from_packed(&pw, &lut, shards, pool).unwrap();
                assert!(sh.shard_count() <= shards && sh.shard_count() >= 1);
                assert_eq!(
                    sh.execute_batch(&toks),
                    want,
                    "({k},{n}) batch {batch} shards {shards}"
                );
            }
        }
    }

    #[test]
    fn sharded_without_outliers_and_empty_batch() {
        let (toks, qw, lut) = setup(7, 48, 10, 4, false);
        assert!(toks.iter().all(|t| t.outliers.is_empty()));
        let want = reference(&toks, &qw, &lut);
        let pool = Arc::new(ShardPool::new(3).unwrap());
        let sh = ShardedWaqGemm::from_packed(&qw.pack(), &lut, 3, pool).unwrap();
        assert_eq!(sh.execute_batch(&toks), want);
        let none: Vec<QuantToken> = Vec::new();
        assert!(sh.execute_batch(&none).is_empty());
    }

    #[test]
    fn output_rows_are_overwritten_not_accumulated() {
        let (toks, qw, lut) = setup(9, 32, 8, 2, true);
        let want = reference(&toks, &qw, &lut);
        let pool = Arc::new(ShardPool::new(2).unwrap());
        let sh = ShardedWaqGemm::from_packed(&qw.pack(), &lut, 2, pool).unwrap();
        // poisoned output buffers must not leak into results
        let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![f32::NAN; 8]).collect();
        sh.execute_batch_into(&toks, &mut out);
        assert_eq!(out, want);
    }

    #[test]
    fn zero_shards_is_an_error_not_a_panic() {
        assert!(ShardPool::new(0).is_err());
        let (_, qw, lut) = setup(11, 16, 8, 1, true);
        let pool = Arc::new(ShardPool::new(1).unwrap());
        assert!(ShardedWaqGemm::from_packed(&qw.pack(), &lut, 0, pool.clone()).is_err());
        let mut rng = Rng::new(11);
        let qw2 = quant::quantize_weights(&Matrix::random_normal(16, 8, 1.0, &mut rng), 2);
        assert!(ShardedWaqGemm::from_packed(&qw2.pack(), &lut, 0, pool).is_err());
    }

    #[test]
    fn sharded_bit_exact_uneven_splits_at_every_width() {
        // the one sharding path serves every stream width: K % 4 in
        // {0,1,2,3} (every tail shape for both densities), uneven N
        // splits, N < shards, outliers on and off, grouped and ungrouped
        // scale grids
        for w_bits in [2u32, 3, 4] {
            for &(k, n, batch, outliers, group) in &[
                (64usize, 24usize, 3usize, true, 0usize),
                (65, 23, 5, true, 32),
                (66, 9, 2, false, 0),
                (67, 3, 1, true, 4),
            ] {
                let mut rng = Rng::new(200 + k as u64 + w_bits as u64 * 1000);
                let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
                let qw = quant::quantize_weights_grouped(&wmat, None, w_bits, group);
                let calib: Vec<Vec<f32>> =
                    (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
                let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
                let cfg = OutlierCfg { total_frac: 0.04 };
                let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
                let toks: Vec<QuantToken> = (0..batch)
                    .map(|_| {
                        let x = rng.heavy_tailed_vec(k, 0.02, 8.0);
                        if outliers {
                            quant::quantize_token(&x, &cb, cfg)
                        } else {
                            quant::quantize_token_with_outliers(&x, &cb, &[])
                        }
                    })
                    .collect();
                let lut = CartesianLut::build(&cb, &qw.codebook);
                let want = reference(&toks, &qw, &lut);
                let pw = qw.pack();
                assert_eq!(pw.bits(), w_bits);
                for shards in [1usize, 2, 3, 7] {
                    let pool = Arc::new(ShardPool::new(shards).unwrap());
                    let sh = ShardedWaqGemm::from_packed(&pw, &lut, shards, pool).unwrap();
                    assert_eq!(
                        sh.execute_batch(&toks),
                        want,
                        "W{w_bits} ({k},{n}) batch {batch} g{group} shards {shards}"
                    );
                }
            }
        }
    }

    #[test]
    fn pool_survives_many_rounds_and_reports_critical_path() {
        let (toks, qw, lut) = setup(13, 40, 12, 3, true);
        let pool = Arc::new(ShardPool::new(4).unwrap());
        assert_eq!(pool.workers(), 4);
        let sh = ShardedWaqGemm::from_packed(&qw.pack(), &lut, 4, pool).unwrap();
        let want = sh.execute_batch(&toks);
        let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; 12]).collect();
        for round in 0..50 {
            let crit = sh.execute_batch_into(&toks, &mut out);
            assert_eq!(out, want, "round {round}");
            assert!(crit > 0, "critical path must be measured");
        }
    }
}
