//! WOQ LUT-GEMM baseline (FIGLUT / LUT Tensor Core / LUT-GEMM style,
//! paper §II-B): group-wise inner-product LUTs over FP16 activations with
//! bit-serial weight processing. Implemented functionally (verified against
//! a direct dot product) and instrumented for the Table I / Fig 16
//! LUT-size and reduction-FLOP comparisons.

use crate::tensor::Matrix;

/// Group size mu used by FIGLUT / LUT Tensor Core (paper: mu = 4).
pub const DEFAULT_MU: usize = 4;

/// One GEMV y = x @ W with int-quantized weights (values in [-2^(b-1),
/// 2^(b-1)-1] as i8) via group-wise inner-product LUTs + bit-serial
/// accumulation. `x` is the FP16(f32) activation of length K; `w_q` is
/// K x N (row-major); returns length-N output (scales are the caller's
/// concern — baselines fold them per output channel).
pub fn woq_lut_gemv(x: &[f32], w_q: &[i8], n: usize, bits: u32, mu: usize) -> Vec<f32> {
    let k = x.len();
    assert_eq!(w_q.len(), k * n);
    let n_groups = k.div_ceil(mu);
    let lut_len = 1usize << mu;

    // Build the on-the-fly inner-product LUT: for each group g, T[g][p] =
    // sum of x[i] over the subset selected by bit pattern p. This is the
    // per-inference LUT-generation cost WOQ schemes pay (2^mu * K/mu
    // entries — exactly the Table I row).
    let mut luts = vec![0.0f32; n_groups * lut_len];
    for g in 0..n_groups {
        let base = g * mu;
        let tbl = &mut luts[g * lut_len..(g + 1) * lut_len];
        for p in 1..lut_len {
            // incremental: p = q | lowest_bit
            let low = p.trailing_zeros() as usize;
            let rest = p & (p - 1);
            let xv = if base + low < k { x[base + low] } else { 0.0 };
            tbl[p] = tbl[rest] + xv;
        }
    }

    // offset-binary weight encoding: w = q' - 2^(b-1), q' in [0, 2^b)
    let offset = 1i32 << (bits - 1);
    let x_total: f32 = x.iter().sum();

    let mut out = vec![0.0f32; n];
    for j in 0..n {
        let mut acc = 0.0f32;
        for g in 0..n_groups {
            let base = g * mu;
            let tbl = &luts[g * lut_len..(g + 1) * lut_len];
            // bit-serial over weight bit-planes
            for b in 0..bits {
                let mut pattern = 0usize;
                for i in 0..mu {
                    let kk = base + i;
                    if kk >= k {
                        break;
                    }
                    let qp = (w_q[kk * n + j] as i32 + offset) as u32;
                    if (qp >> b) & 1 == 1 {
                        pattern |= 1 << i;
                    }
                }
                acc += ((1u32 << b) as f32) * tbl[pattern];
            }
        }
        out[j] = acc - offset as f32 * x_total;
    }
    out
}

/// Cost metrics of one WOQ LUT-GEMM execution (Fig 16 inputs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WoqCost {
    /// LUT entries materialized per token (FP16 each)
    pub lut_entries: usize,
    /// FP additions in the reduction (per token, all N channels)
    pub reduction_flops: usize,
    /// FP additions to *build* the LUTs (on-the-fly generation cost)
    pub lut_gen_flops: usize,
}

pub fn woq_cost(k: usize, n: usize, bits: u32, mu: usize) -> WoqCost {
    let n_groups = k.div_ceil(mu);
    WoqCost {
        lut_entries: n_groups << mu,
        reduction_flops: n_groups * bits as usize * n,
        lut_gen_flops: n_groups * ((1 << mu) - 1),
    }
}

/// LUT-GEMM (Park et al.) uses a larger group size to trade LUT size for
/// fewer reduction FLOPs; the paper's Fig 16 uses mu = 8 for that baseline.
pub const LUT_GEMM_MU: usize = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn direct(x: &[f32], w_q: &[i8], n: usize) -> Vec<f32> {
        let k = x.len();
        let mut out = vec![0.0f32; n];
        for j in 0..n {
            out[j] = (0..k).map(|i| x[i] * w_q[i * n + j] as f32).sum();
        }
        out
    }

    #[test]
    fn matches_direct_dot() {
        let mut rng = Rng::new(1);
        for &(k, n, bits, mu) in &[(16usize, 4usize, 4u32, 4usize), (64, 8, 4, 4), (60, 3, 3, 4), (128, 5, 4, 8)] {
            let x = rng.normal_vec(k, 1.0);
            let w_q: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(1 << bits) as i32 - (1 << (bits - 1))) as i8)
                .collect();
            let got = woq_lut_gemv(&x, &w_q, n, bits, mu);
            let want = direct(&x, &w_q, n);
            crate::util::check::assert_allclose(&got, &want, 1e-4, 1e-3, "woq");
        }
    }

    #[test]
    fn cost_matches_table1() {
        // K = N = 4096, nW = 4, mu = 4 (Table I)
        let c = woq_cost(4096, 4096, 4, 4);
        assert_eq!(c.lut_entries, (1 << 4) * 1024);
        assert_eq!(c.reduction_flops, 1024 * 4 * 4096);
    }

    #[test]
    fn bigger_group_trades_lut_for_flops() {
        let a = woq_cost(4096, 4096, 4, 4);
        let b = woq_cost(4096, 4096, 4, LUT_GEMM_MU);
        assert!(b.lut_entries > a.lut_entries);
        assert!(b.reduction_flops < a.reduction_flops);
    }

    #[test]
    fn ragged_k_handled() {
        let mut rng = Rng::new(2);
        let (k, n) = (13, 3);
        let x = rng.normal_vec(k, 1.0);
        let w_q: Vec<i8> = (0..k * n).map(|_| (rng.below(16) as i32 - 8) as i8).collect();
        let got = woq_lut_gemv(&x, &w_q, n, 4, 4);
        crate::util::check::assert_allclose(&got, &direct(&x, &w_q, n), 1e-4, 1e-3, "ragged");
    }
}

/// Dense-reference path for the baselines that dequantize to FP16 and run a
/// standard GEMM (paper Fig 1(c)).
pub fn dequant_then_gemm(a: &Matrix, w_deq: &Matrix) -> Matrix {
    a.matmul(w_deq)
}
