//! Look-ahead computation + error compensation — the outlier branch
//! (paper §III-C, Fig 7).
//!
//! The main branch computes the WAQ LUT-GEMM on the *fully quantized*
//! activation (outliers included, with their bad indices). For each outlier
//! the detection engine emits (channel c, fp value v); this branch fetches
//! input-channel c of the quantized weights, dequantizes it (Dequantization
//! Unit), multiplies the residual r = v - dequant(a_idx[c]) (Error
//! Calculation Unit), and accumulates into the look-ahead result (the 8 MAC
//! units per PE line). The sum is mathematically identical to conventional
//! dynamic-detection GEMM.

use super::lut::CartesianLut;
use super::waq;
use crate::quant::{PackedWeights, QuantToken, QuantWeights};

/// Apply error compensation in place: out[n] += r * W_deq[c, n] per outlier.
pub fn compensate(out: &mut [f32], tok: &QuantToken, w: &QuantWeights) {
    assert_eq!(out.len(), w.n_cols);
    let mut wrow = Vec::with_capacity(w.n_cols);
    for &(c, _v, r) in &tok.outliers {
        w.dequant_row(c as usize, &mut wrow);
        for (o, &wv) in out.iter_mut().zip(&wrow) {
            *o += r * wv;
        }
    }
}

/// [`compensate`] over the packed weight form at any stream width (what
/// the serving path keeps resident when the packed GEMM backend is
/// selected): same per-outlier dequant-row fetch — group scales included
/// when present — bit-identical FP accumulation.
pub fn compensate_packed(out: &mut [f32], tok: &QuantToken, w: &PackedWeights) {
    assert_eq!(out.len(), w.n_cols);
    let mut wrow = Vec::with_capacity(w.n_cols);
    for &(c, _v, r) in &tok.outliers {
        w.dequant_row(c as usize, &mut wrow);
        for (o, &wv) in out.iter_mut().zip(&wrow) {
            *o += r * wv;
        }
    }
}

/// Full dual-branch GEMM for one token: look-ahead main branch + outlier
/// error compensation.
pub fn execute_dual_branch(
    tok: &QuantToken,
    w: &QuantWeights,
    lut: &CartesianLut,
) -> Vec<f32> {
    let mut out = waq::execute_direct(tok, w, lut); // main branch
    compensate(&mut out, tok, w); // outlier branch
    out
}

/// The conventional critical-path design (paper Fig 4(a), "OASIS-C"): split
/// first, then run inlier LUT-GEMM and FP outlier GEMM. Numerically
/// identical; exists so tests can assert the equivalence the paper claims
/// and so the simulator can model the serialized schedule.
pub fn execute_critical_path(
    tok: &QuantToken,
    w: &QuantWeights,
    lut: &CartesianLut,
) -> Vec<f32> {
    // inlier-only token: outlier channels contribute their dequant value
    // minus itself, i.e. we compute the full look-ahead then *subtract* the
    // outliers' quantized contribution and add their FP contribution —
    // algebraically the same dataflow a masked inlier GEMM would produce.
    let mut out = waq::execute_direct(tok, w, lut);
    let mut wrow = Vec::with_capacity(w.n_cols);
    for &(c, v, _r) in &tok.outliers {
        let deq = lut_act_value(tok, lut, c as usize);
        w.dequant_row(c as usize, &mut wrow);
        for (o, &wv) in out.iter_mut().zip(&wrow) {
            *o += (v - deq) * wv;
        }
    }
    out
}

fn lut_act_value(tok: &QuantToken, lut: &CartesianLut, c: usize) -> f32 {
    // activation centroid value recovered via the residual identity
    // r = v - dequant  =>  dequant = v - r (avoids threading the codebook)
    for &(oc, v, r) in &tok.outliers {
        if oc as usize == c {
            return v - r;
        }
    }
    // non-outlier channels never queried
    let _ = lut;
    unreachable!("lut_act_value called on non-outlier channel {c}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, OutlierCfg};
    use crate::tensor::Matrix;
    use crate::util::check::assert_allclose;
    use crate::util::rng::Rng;

    fn setup(
        seed: u64,
        k: usize,
        n: usize,
        frac: f64,
    ) -> (QuantToken, QuantWeights, CartesianLut, Vec<f32>, Matrix) {
        let mut rng = Rng::new(seed);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> =
            (0..8).map(|_| rng.heavy_tailed_vec(k, 0.02, 12.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: frac };
        let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.heavy_tailed_vec(k, 0.02, 12.0);
        let tok = quant::quantize_token(&x, &cb_a, cfg);
        let lut = CartesianLut::build(&cb_a, &qw.codebook);
        (tok, qw, lut, x, wmat)
    }

    #[test]
    fn dual_branch_equals_critical_path() {
        // The paper's central equivalence claim (§III-C2): look-ahead +
        // compensation == conventional dynamic detection.
        let (tok, qw, lut, _, _) = setup(1, 128, 32, 0.02);
        assert!(!tok.outliers.is_empty());
        let dual = execute_dual_branch(&tok, &qw, &lut);
        let conv = execute_critical_path(&tok, &qw, &lut);
        assert_allclose(&dual, &conv, 1e-4, 1e-4, "dual vs critical-path");
    }

    #[test]
    fn compensation_equals_fp_outlier_gemm() {
        // dual-branch == dequant(tok with FP outliers) @ dequant(W)
        let (tok, qw, lut, _x, _) = setup(2, 96, 16, 0.04);
        let got = execute_dual_branch(&tok, &qw, &lut);
        // rebuild codebook-based reconstruction with FP outliers
        let mut a = tok.dequantize_lookahead(&rebuild_cb(&tok, &lut, &qw));
        for &(c, v, _) in &tok.outliers {
            a[c as usize] = v;
        }
        let want = Matrix::from_vec(1, a.len(), a).matmul(&qw.dequantize());
        assert_allclose(&got, want.row(0), 2e-4, 2e-4, "vs fp-outlier gemm");
    }

    // Reconstruct the activation codebook from the LUT and the weight
    // codebook (lut[ia, iw] = ca[ia] * cw[iw]).
    fn rebuild_cb(
        _tok: &QuantToken,
        lut: &CartesianLut,
        qw: &QuantWeights,
    ) -> crate::quant::Codebook {
        // pick the weight centroid with max magnitude for stable division
        let (j, cw) = qw
            .codebook
            .centroids
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.abs().partial_cmp(&b.abs()).unwrap())
            .map(|(j, &c)| (j, c))
            .unwrap();
        let n_w = 1usize << lut.n_w_bits;
        let ca: Vec<f32> = (0..(lut.table.len() / n_w))
            .map(|ia| lut.table[ia * n_w + j] / cw)
            .collect();
        crate::quant::Codebook::new(ca)
    }

    #[test]
    fn compensation_reduces_error_vs_lookahead_only() {
        let (tok, qw, lut, x, wmat) = setup(3, 160, 24, 0.03);
        let exact = Matrix::from_vec(1, x.len(), x.clone()).matmul(&wmat);
        let lookahead = waq::execute_direct(&tok, &qw, &lut);
        let dual = execute_dual_branch(&tok, &qw, &lut);
        let err = |v: &[f32]| -> f64 {
            v.iter()
                .zip(exact.row(0))
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum()
        };
        assert!(
            err(&dual) < err(&lookahead),
            "comp {} !< lookahead {}",
            err(&dual),
            err(&lookahead)
        );
    }

    #[test]
    fn packed_compensation_is_bit_exact_with_unpacked() {
        // odd K exercises the packed tail row
        for (seed, k) in [(5u64, 96usize), (6, 97)] {
            let (tok, qw, lut, _, _) = setup(seed, k, 24, 0.04);
            assert!(!tok.outliers.is_empty());
            let mut a = waq::execute_direct(&tok, &qw, &lut);
            let mut b = a.clone();
            compensate(&mut a, &tok, &qw);
            compensate_packed(&mut b, &tok, &qw.pack());
            assert_eq!(a, b, "seed {seed} k {k}");
        }
    }

    #[test]
    fn packed_compensation_is_bit_exact_at_every_width_and_group() {
        // K % 4 in {0,1,2,3} exercises every tail shape for both stream
        // densities; group sizes cover ungrouped and a multi-group grid
        for (seed, k) in [(7u64, 96usize), (8, 97), (9, 98), (10, 99)] {
            for w_bits in [2u32, 3, 4] {
                for group in [0usize, 32] {
                    let mut rng = Rng::new(seed + w_bits as u64);
                    let wmat = Matrix::random_normal(k, 24, 1.0, &mut rng);
                    let qw = quant::quantize_weights_grouped(&wmat, None, w_bits, group);
                    let calib: Vec<Vec<f32>> =
                        (0..8).map(|_| rng.heavy_tailed_vec(k, 0.02, 12.0)).collect();
                    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
                    let cfg = OutlierCfg { total_frac: 0.04 };
                    let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
                    let tok =
                        quant::quantize_token(&rng.heavy_tailed_vec(k, 0.02, 12.0), &cb_a, cfg);
                    assert!(!tok.outliers.is_empty());
                    let lut = CartesianLut::build(&cb_a, &qw.codebook);
                    let mut a = waq::execute_direct(&tok, &qw, &lut);
                    let mut b = a.clone();
                    compensate(&mut a, &tok, &qw);
                    compensate_packed(&mut b, &tok, &qw.pack());
                    assert_eq!(a, b, "seed {seed} k {k} W{w_bits} g{group}");
                }
            }
        }
    }

    #[test]
    fn zero_outliers_is_identity() {
        let (mut tok, qw, lut, _, _) = setup(4, 64, 8, 0.02);
        tok.outliers.clear();
        let a = waq::execute_direct(&tok, &qw, &lut);
        let b = execute_dual_branch(&tok, &qw, &lut);
        assert_eq!(a, b);
    }
}
