//! WAQ LUT-GEMM execution — the bit-exact software model of the OASIS main
//! branch (paper Fig 6): concatenate indices (Concat Units), histogram the
//! concatenated indices (Index Counters), and reduce as a weighted sum of
//! Cartesian-Product LUT entries (MAC Tree).
//!
//! Two functionally identical paths are provided and cross-checked:
//! `execute_direct` (per-element LUT lookups, the fast software form) and
//! `execute_histogram` (literal Index-Counter semantics). The cycle-level
//! costs of the hardware pipeline live in `sim::gemm`; this module is the
//! numerics twin.

use super::lut::CartesianLut;
use crate::quant::{QuantToken, QuantWeights};

/// out[n] = a_scale * w_scale[n] * sum_k LUT[cat(a_idx[k], w_idx[k, n])]
/// for one token (M = 1 decode GEMM, the paper's running case).
pub fn execute_direct(tok: &QuantToken, w: &QuantWeights, lut: &CartesianLut) -> Vec<f32> {
    assert_eq!(tok.idx.len(), w.n_rows, "reduction length mismatch");
    let n = w.n_cols;
    let mask = (1usize << lut.n_w_bits) - 1;
    let mut acc = vec![0.0f32; n];
    // Process two reduction rows per pass: two independent LUT gathers per
    // output element break the load-add dependency chain (EXPERIMENTS.md
    // §Perf iterations 1-2: 768us -> 536us -> measured below on 1024^2).
    // Masking iw elides the per-element bounds check on the LUT row slice
    // in release; debug builds assert in-range first — a wrapped index
    // means corrupt data (e.g. a mixed-bitwidth config feeding 4-bit
    // indices to a 3-bit LUT), which must fail loudly, not alias entries.
    let mut k = 0;
    while k + 1 < w.n_rows {
        let base0 = (tok.idx[k] as usize) << lut.n_w_bits;
        let base1 = (tok.idx[k + 1] as usize) << lut.n_w_bits;
        let lr0 = &lut.table[base0..base0 + mask + 1];
        let lr1 = &lut.table[base1..base1 + mask + 1];
        let w0 = &w.idx[k * n..(k + 1) * n];
        let w1 = &w.idx[(k + 1) * n..(k + 2) * n];
        for ((a, &i0), &i1) in acc.iter_mut().zip(w0).zip(w1) {
            debug_assert!(
                (i0 as usize) <= mask && (i1 as usize) <= mask,
                "weight index out of range for {}-bit LUT: {i0}/{i1} at k={k}",
                lut.n_w_bits
            );
            *a += lr0[i0 as usize & mask] + lr1[i1 as usize & mask];
        }
        k += 2;
    }
    if k < w.n_rows {
        let base = (tok.idx[k] as usize) << lut.n_w_bits;
        let lut_row = &lut.table[base..base + mask + 1];
        let wrow = &w.idx[k * n..(k + 1) * n];
        for (a, &iw) in acc.iter_mut().zip(wrow) {
            debug_assert!(
                (iw as usize) <= mask,
                "weight index out of range for {}-bit LUT: {iw} at k={k}",
                lut.n_w_bits
            );
            *a += lut_row[iw as usize & mask];
        }
    }
    for (j, a) in acc.iter_mut().enumerate() {
        *a *= tok.scale * w.col_scales[j];
    }
    acc
}

/// The Index-Counter path: per output channel, build the histogram of
/// concatenated indices over K, then MAC-tree the counts against the LUT.
/// Bit-exact identical index handling to `execute_direct`; float
/// accumulation groups by LUT entry instead of by k.
pub fn execute_histogram(tok: &QuantToken, w: &QuantWeights, lut: &CartesianLut) -> Vec<f32> {
    assert_eq!(tok.idx.len(), w.n_rows);
    let n = w.n_cols;
    let entries = lut.entries();
    let mut out = vec![0.0f32; n];
    let mut counts = vec![0u32; entries];
    for j in 0..n {
        counts.iter_mut().for_each(|c| *c = 0);
        for (k, &ia) in tok.idx.iter().enumerate() {
            let iw = w.idx[k * n + j];
            counts[((ia as usize) << lut.n_w_bits) | iw as usize] += 1;
        }
        // MAC tree: weighted sum of LUT entries by count
        let mut acc = 0.0f32;
        for (e, &c) in counts.iter().enumerate() {
            if c != 0 {
                acc += c as f32 * lut.table[e];
            }
        }
        out[j] = acc * tok.scale * w.col_scales[j];
    }
    out
}

/// Histogram of concatenated indices for one output channel — exposed for
/// the Index-Counter unit tests and the simulator's occupancy stats.
pub fn concat_histogram(
    a_idx: &[u8],
    w_idx_col: impl Iterator<Item = u8>,
    lut: &CartesianLut,
) -> Vec<u32> {
    let mut counts = vec![0u32; lut.entries()];
    for (&ia, iw) in a_idx.iter().zip(w_idx_col) {
        counts[lut.cat(ia, iw)] += 1;
    }
    counts
}

/// Multi-token (M x K) @ (K x N) over the same quantized weights.
pub fn execute_batch(
    toks: &[QuantToken],
    w: &QuantWeights,
    lut: &CartesianLut,
) -> Vec<Vec<f32>> {
    toks.iter().map(|t| execute_direct(t, w, lut)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, Codebook, OutlierCfg};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn setup(seed: u64, k: usize, n: usize) -> (QuantToken, QuantWeights, CartesianLut, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.heavy_tailed_vec(k, 0.01, 10.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.heavy_tailed_vec(k, 0.01, 10.0);
        let tok = quant::quantize_token(&x, &cb_a, cfg);
        let lut = CartesianLut::build(&cb_a, &qw.codebook);
        (tok, qw, lut, x)
    }

    #[test]
    fn direct_equals_histogram() {
        let (tok, qw, lut, _) = setup(1, 128, 32);
        let d = execute_direct(&tok, &qw, &lut);
        let h = execute_histogram(&tok, &qw, &lut);
        crate::util::check::assert_allclose(&d, &h, 1e-4, 1e-4, "direct vs histogram");
    }

    #[test]
    fn equals_dequant_matmul_explicit() {
        let mut rng = Rng::new(3);
        let (k, n) = (64, 16);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(k, 1.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.normal_vec(k, 1.0);
        let tok = quant::quantize_token(&x, &cb_a, cfg);
        let lut = CartesianLut::build(&cb_a, &qw.codebook);

        let got = execute_direct(&tok, &qw, &lut);
        let a_deq = Matrix::from_vec(1, k, tok.dequantize_lookahead(&cb_a));
        let want = a_deq.matmul(&qw.dequantize());
        crate::util::check::assert_allclose(&got, want.row(0), 2e-4, 2e-4, "explicit");
    }

    #[test]
    fn histogram_counts_sum_to_k() {
        let (tok, qw, lut, _) = setup(4, 80, 8);
        for j in 0..qw.n_cols {
            let h = concat_histogram(
                &tok.idx,
                (0..qw.n_rows).map(|k| qw.idx[k * qw.n_cols + j]),
                &lut,
            );
            assert_eq!(h.iter().sum::<u32>() as usize, qw.n_rows);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "weight index out of range")]
    fn corrupt_weight_index_fails_loudly() {
        // 4-bit index stream fed to a 3-bit LUT must not silently alias
        let mut rng = Rng::new(6);
        let cb_a = Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = Codebook::new(rng.normal_vec(8, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        let qw = QuantWeights {
            n_rows: 2,
            n_cols: 1,
            idx: vec![15, 0], // 15 is out of range for the 3-bit codebook
            codebook: cb_w,
            col_scales: vec![1.0],
        };
        let tok = QuantToken { idx: vec![0, 0], scale: 1.0, outliers: vec![] };
        execute_direct(&tok, &qw, &lut);
    }

    #[test]
    fn batch_matches_per_token() {
        let (tok, qw, lut, _) = setup(5, 48, 12);
        let toks = vec![tok.clone(), tok.clone()];
        let b = execute_batch(&toks, &qw, &lut);
        let single = execute_direct(&tok, &qw, &lut);
        assert_eq!(b[0], single);
        assert_eq!(b[1], single);
    }
}
