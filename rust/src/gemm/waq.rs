//! WAQ LUT-GEMM execution — the bit-exact software model of the OASIS main
//! branch (paper Fig 6): concatenate indices (Concat Units), histogram the
//! concatenated indices (Index Counters), and reduce as a weighted sum of
//! Cartesian-Product LUT entries (MAC Tree).
//!
//! Two functionally identical paths are provided and cross-checked:
//! `execute_direct` (per-element LUT lookups, the fast software form) and
//! `execute_histogram` (literal Index-Counter semantics). The cycle-level
//! costs of the hardware pipeline live in `sim::gemm`; this module is the
//! numerics twin.

use super::lut::CartesianLut;
use crate::quant::{QuantToken, QuantWeights};

/// Accumulate reduction rows `[k0, k1)` of the LUT sums into `acc`
/// (unscaled). Two reduction rows per pass: two independent LUT gathers
/// per output element break the load-add dependency chain (EXPERIMENTS.md
/// §Perf iterations 1-2: 768us -> 536us -> measured below on 1024^2).
/// Masking iw elides the per-element bounds check on the LUT row slice
/// in release; debug builds assert in-range first — a wrapped index
/// means corrupt data (e.g. a mixed-bitwidth config feeding 4-bit
/// indices to a 3-bit LUT), which must fail loudly, not alias entries.
fn accum_rows(
    tok: &QuantToken,
    w: &QuantWeights,
    lut: &CartesianLut,
    k0: usize,
    k1: usize,
    acc: &mut [f32],
) {
    let n = w.n_cols;
    let mask = (1usize << lut.n_w_bits) - 1;
    let mut k = k0;
    while k + 1 < k1 {
        let base0 = (tok.idx[k] as usize) << lut.n_w_bits;
        let base1 = (tok.idx[k + 1] as usize) << lut.n_w_bits;
        let lr0 = &lut.table[base0..base0 + mask + 1];
        let lr1 = &lut.table[base1..base1 + mask + 1];
        let w0 = &w.idx[k * n..(k + 1) * n];
        let w1 = &w.idx[(k + 1) * n..(k + 2) * n];
        for ((a, &i0), &i1) in acc.iter_mut().zip(w0).zip(w1) {
            debug_assert!(
                (i0 as usize) <= mask && (i1 as usize) <= mask,
                "weight index out of range for {}-bit LUT: {i0}/{i1} at k={k}",
                lut.n_w_bits
            );
            *a += lr0[i0 as usize & mask] + lr1[i1 as usize & mask];
        }
        k += 2;
    }
    if k < k1 {
        let base = (tok.idx[k] as usize) << lut.n_w_bits;
        let lut_row = &lut.table[base..base + mask + 1];
        let wrow = &w.idx[k * n..(k + 1) * n];
        for (a, &iw) in acc.iter_mut().zip(wrow) {
            debug_assert!(
                (iw as usize) <= mask,
                "weight index out of range for {}-bit LUT: {iw} at k={k}",
                lut.n_w_bits
            );
            *a += lut_row[iw as usize & mask];
        }
    }
}

/// out[n] = a_scale * w_scale[n] * sum_k LUT[cat(a_idx[k], w_idx[k, n])]
/// for one token (M = 1 decode GEMM, the paper's running case). When the
/// weights carry a FineQuant per-group scale grid, each group's partial
/// sum is folded through its factor before the per-column scaling — this
/// function is the bit-exactness reference for every packed/sharded
/// kernel, grouped or not.
pub fn execute_direct(tok: &QuantToken, w: &QuantWeights, lut: &CartesianLut) -> Vec<f32> {
    assert_eq!(tok.idx.len(), w.n_rows, "reduction length mismatch");
    let n = w.n_cols;
    let mut acc = vec![0.0f32; n];
    if w.group_scales.is_empty() {
        accum_rows(tok, w, lut, 0, w.n_rows, &mut acc);
    } else {
        let mut gacc = vec![0.0f32; n];
        for g in 0..w.n_groups() {
            let (k0, k1) = (g * w.group_size, ((g + 1) * w.group_size).min(w.n_rows));
            gacc.fill(0.0);
            accum_rows(tok, w, lut, k0, k1, &mut gacc);
            let gs = &w.group_scales[g * n..(g + 1) * n];
            for ((a, &v), &s) in acc.iter_mut().zip(&gacc).zip(gs) {
                *a += v * s;
            }
        }
    }
    for (j, a) in acc.iter_mut().enumerate() {
        *a *= tok.scale * w.col_scales[j];
    }
    acc
}

/// The Index-Counter path: per output channel, build the histogram of
/// concatenated indices over K, then MAC-tree the counts against the LUT.
/// Bit-exact identical index handling to `execute_direct`; float
/// accumulation groups by LUT entry instead of by k.
pub fn execute_histogram(tok: &QuantToken, w: &QuantWeights, lut: &CartesianLut) -> Vec<f32> {
    assert_eq!(tok.idx.len(), w.n_rows);
    let n = w.n_cols;
    let entries = lut.entries();
    let mut out = vec![0.0f32; n];
    let mut counts = vec![0u32; entries];
    // one histogram per (output channel, scale group); ungrouped weights
    // are one whole-column group with unit factor
    let n_groups = w.n_groups();
    for j in 0..n {
        let mut col = 0.0f32;
        for g in 0..n_groups {
            let (k0, k1) = if w.group_scales.is_empty() {
                (0, w.n_rows)
            } else {
                (g * w.group_size, ((g + 1) * w.group_size).min(w.n_rows))
            };
            counts.iter_mut().for_each(|c| *c = 0);
            for (k, &ia) in tok.idx.iter().enumerate().take(k1).skip(k0) {
                let iw = w.idx[k * n + j];
                counts[((ia as usize) << lut.n_w_bits) | iw as usize] += 1;
            }
            // MAC tree: weighted sum of LUT entries by count
            let mut acc = 0.0f32;
            for (e, &c) in counts.iter().enumerate() {
                if c != 0 {
                    acc += c as f32 * lut.table[e];
                }
            }
            if !w.group_scales.is_empty() {
                acc *= w.group_scales[g * n + j];
            }
            col += acc;
        }
        out[j] = col * tok.scale * w.col_scales[j];
    }
    out
}

/// Histogram of concatenated indices for one output channel — exposed for
/// the Index-Counter unit tests and the simulator's occupancy stats.
pub fn concat_histogram(
    a_idx: &[u8],
    w_idx_col: impl Iterator<Item = u8>,
    lut: &CartesianLut,
) -> Vec<u32> {
    let mut counts = vec![0u32; lut.entries()];
    for (&ia, iw) in a_idx.iter().zip(w_idx_col) {
        counts[lut.cat(ia, iw)] += 1;
    }
    counts
}

/// Multi-token (M x K) @ (K x N) over the same quantized weights.
pub fn execute_batch(
    toks: &[QuantToken],
    w: &QuantWeights,
    lut: &CartesianLut,
) -> Vec<Vec<f32>> {
    toks.iter().map(|t| execute_direct(t, w, lut)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, Codebook, OutlierCfg};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn setup(seed: u64, k: usize, n: usize) -> (QuantToken, QuantWeights, CartesianLut, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.heavy_tailed_vec(k, 0.01, 10.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.heavy_tailed_vec(k, 0.01, 10.0);
        let tok = quant::quantize_token(&x, &cb_a, cfg);
        let lut = CartesianLut::build(&cb_a, &qw.codebook);
        (tok, qw, lut, x)
    }

    #[test]
    fn direct_equals_histogram() {
        let (tok, qw, lut, _) = setup(1, 128, 32);
        let d = execute_direct(&tok, &qw, &lut);
        let h = execute_histogram(&tok, &qw, &lut);
        crate::util::check::assert_allclose(&d, &h, 1e-4, 1e-4, "direct vs histogram");
    }

    #[test]
    fn equals_dequant_matmul_explicit() {
        let mut rng = Rng::new(3);
        let (k, n) = (64, 16);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(k, 1.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.normal_vec(k, 1.0);
        let tok = quant::quantize_token(&x, &cb_a, cfg);
        let lut = CartesianLut::build(&cb_a, &qw.codebook);

        let got = execute_direct(&tok, &qw, &lut);
        let a_deq = Matrix::from_vec(1, k, tok.dequantize_lookahead(&cb_a));
        let want = a_deq.matmul(&qw.dequantize());
        crate::util::check::assert_allclose(&got, want.row(0), 2e-4, 2e-4, "explicit");
    }

    #[test]
    fn histogram_counts_sum_to_k() {
        let (tok, qw, lut, _) = setup(4, 80, 8);
        for j in 0..qw.n_cols {
            let h = concat_histogram(
                &tok.idx,
                (0..qw.n_rows).map(|k| qw.idx[k * qw.n_cols + j]),
                &lut,
            );
            assert_eq!(h.iter().sum::<u32>() as usize, qw.n_rows);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "weight index out of range")]
    fn corrupt_weight_index_fails_loudly() {
        // 4-bit index stream fed to a 3-bit LUT must not silently alias
        let mut rng = Rng::new(6);
        let cb_a = Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = Codebook::new(rng.normal_vec(8, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        let qw = QuantWeights {
            n_rows: 2,
            n_cols: 1,
            idx: vec![15, 0], // 15 is out of range for the 3-bit codebook
            codebook: cb_w,
            col_scales: vec![1.0],
            group_size: 0,
            group_scales: vec![],
        };
        let tok = QuantToken { idx: vec![0, 0], scale: 1.0, outliers: vec![] };
        execute_direct(&tok, &qw, &lut);
    }

    #[test]
    fn grouped_direct_equals_histogram_and_dequant_matmul() {
        let mut rng = Rng::new(7);
        let (k, n) = (70, 12); // ragged final group
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights_grouped(&wmat, None, 3, 32);
        let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(k, 1.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb_a = quant::learn_act_codebook(&refs, None, 4, cfg);
        let x = rng.normal_vec(k, 1.0);
        let tok = quant::quantize_token(&x, &cb_a, cfg);
        let lut = CartesianLut::build(&cb_a, &qw.codebook);

        let got = execute_direct(&tok, &qw, &lut);
        let h = execute_histogram(&tok, &qw, &lut);
        crate::util::check::assert_allclose(&got, &h, 1e-4, 1e-4, "grouped direct vs histogram");
        let a_deq = Matrix::from_vec(1, k, tok.dequantize_lookahead(&cb_a));
        let want = a_deq.matmul(&qw.dequantize());
        crate::util::check::assert_allclose(&got, want.row(0), 2e-4, 2e-4, "grouped explicit");
    }

    #[test]
    fn batch_matches_per_token() {
        let (tok, qw, lut, _) = setup(5, 48, 12);
        let toks = vec![tok.clone(), tok.clone()];
        let b = execute_batch(&toks, &qw, &lut);
        let single = execute_direct(&tok, &qw, &lut);
        assert_eq!(b[0], single);
        assert_eq!(b[1], single);
    }
}
