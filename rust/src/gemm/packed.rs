//! Packed, tiled, multi-threaded WAQ LUT-GEMM — the fast software backend.
//!
//! # Nibble layout
//!
//! Weights arrive as [`PackedWeights`]: the K x N index matrix packed two
//! reduction rows per byte, `pairs[p * N + j] = idx[2p][j] << 4 |
//! idx[2p+1][j]` (row `2p` in the high nibble). An odd final row is a
//! nibble-packed tail. Index traffic is therefore half of the
//! byte-per-index `QuantWeights` form the direct path streams.
//!
//! # Fused pair-LUT
//!
//! For one token, reduction rows `2p` and `2p+1` use activation indices
//! `(ia0, ia1)`. Instead of two Cartesian-LUT gathers per output element,
//! build one fused 256-entry row per pair once:
//!
//! ```text
//! lutF[b] = lut[ia0][b >> 4] + lut[ia1][b & 15]
//! ```
//!
//! and then stream the packed weight bytes: each byte `b` costs a single
//! table lookup and a single accumulate for TWO MACs. The fused row costs
//! 2^(2*nW) adds to build and is amortized over all N (or one column
//! tile's worth of) outputs. Because `lutF[b]` is exactly the
//! `lut[ia0][iw0] + lut[ia1][iw1]` sum the direct path computes before
//! accumulating, every result here is bit-exact with
//! [`super::waq::execute_direct`] (same FP additions in the same order).
//!
//! # Tiling + threads
//!
//! [`execute_batch_tiled`] blocks over N (column ranges, one per worker
//! thread) and over K (pair blocks), iterating tokens inside the K block
//! so a `k_pair_block x n_block`-byte weight tile is re-streamed from
//! cache — not memory — for every token of a continuous-batch decode
//! step. Workers own disjoint column ranges, so parallelism never changes
//! the per-output accumulation order: results are bit-exact for every
//! thread count and tile shape.

use super::lut::CartesianLut;
use crate::quant::{CrumbWeights, PackedWeights, QuantToken};

/// Tile/parallelism configuration for [`execute_batch_tiled`].
#[derive(Clone, Copy, Debug)]
pub struct TileCfg {
    /// Minimum column-range width per worker; also the amortization span
    /// of each fused-row build. Wider = less build overhead, narrower =
    /// more parallelism.
    pub n_block: usize,
    /// Reduction row-pairs per K tile; `k_pair_block * n_block` bytes of
    /// packed weights should sit comfortably in L2.
    pub k_pair_block: usize,
    /// Worker threads over column ranges; 0 = use available parallelism.
    pub threads: usize,
}

impl Default for TileCfg {
    fn default() -> Self {
        TileCfg { n_block: 512, k_pair_block: 128, threads: 0 }
    }
}

impl TileCfg {
    /// Single-threaded variant (bit-exact with every other setting; useful
    /// for deterministic-latency comparisons).
    pub fn single_thread() -> Self {
        TileCfg { threads: 1, ..Self::default() }
    }
}

/// Debug-only guard matching `execute_direct`'s fail-loudly index check: a
/// packed byte whose nibble exceeds the weight codebook means corrupt
/// index data (its fused-table slot is never written) and must not be
/// silently read as a stale/zero entry.
#[inline]
fn debug_assert_nibbles(b: u8, mask: usize) {
    debug_assert!(
        (b >> 4) as usize <= mask && (b & 0x0F) as usize <= mask,
        "packed weight byte {b:#04x} out of range for nibble mask {mask:#x}"
    );
}

/// Build the fused pair row: `fused[b] = lut[ia0][b >> 4] + lut[ia1][b & 15]`
/// for every byte value that can occur with in-range nibbles. Entries whose
/// nibbles exceed the weight codebook are never produced by
/// `PackedWeights` and are left untouched.
#[inline]
fn build_fused_row(fused: &mut [f32; 256], ia0: u8, ia1: u8, lut: &CartesianLut) {
    let mask = (1usize << lut.n_w_bits) - 1;
    let r0 = &lut.table[(ia0 as usize) << lut.n_w_bits..][..mask + 1];
    let r1 = &lut.table[(ia1 as usize) << lut.n_w_bits..][..mask + 1];
    for (hi, &v0) in r0.iter().enumerate() {
        let dst = &mut fused[hi << 4..(hi << 4) + mask + 1];
        for (d, &v1) in dst.iter_mut().zip(r1) {
            *d = v0 + v1;
        }
    }
}

/// Accumulate the odd tail row (when K is odd) exactly like the direct
/// path's scalar tail: one plain LUT-row gather per column.
fn add_tail(acc: &mut [f32], j0: usize, tok: &QuantToken, w: &PackedWeights, lut: &CartesianLut) {
    let Some(tail) = &w.tail else { return };
    let mask = (1usize << lut.n_w_bits) - 1;
    let base = (tok.idx[w.n_rows - 1] as usize) << lut.n_w_bits;
    let row = &lut.table[base..base + mask + 1];
    for (jj, a) in acc.iter_mut().enumerate() {
        let iw = tail.get(j0 + jj) as usize;
        debug_assert!(iw <= mask, "tail weight index {iw} out of range (mask {mask})");
        *a += row[iw & mask];
    }
}

/// Single-token packed GEMM: `out[n] = a_scale * w_scale[n] *
/// sum_k LUT[cat(a_idx[k], w_idx[k, n])]`, bit-exact with
/// `execute_direct`, at half the index traffic and one lookup per two
/// MACs. Two pairs are processed per pass (two independent fused tables)
/// to break the gather->add dependency chain, mirroring the direct path's
/// two-row unroll.
pub fn execute_packed(tok: &QuantToken, w: &PackedWeights, lut: &CartesianLut) -> Vec<f32> {
    assert_eq!(tok.idx.len(), w.n_rows, "reduction length mismatch");
    let n = w.n_cols;
    let np = w.n_pairs();
    let nibble_mask = (1usize << lut.n_w_bits) - 1;
    let mut acc = vec![0.0f32; n];
    let mut f0 = [0.0f32; 256];
    let mut f1 = [0.0f32; 256];
    let mut p = 0;
    while p + 1 < np {
        build_fused_row(&mut f0, tok.idx[2 * p], tok.idx[2 * p + 1], lut);
        build_fused_row(&mut f1, tok.idx[2 * p + 2], tok.idx[2 * p + 3], lut);
        let w0 = &w.pairs[p * n..(p + 1) * n];
        let w1 = &w.pairs[(p + 1) * n..(p + 2) * n];
        for ((a, &b0), &b1) in acc.iter_mut().zip(w0).zip(w1) {
            debug_assert_nibbles(b0, nibble_mask);
            debug_assert_nibbles(b1, nibble_mask);
            *a += f0[b0 as usize];
            *a += f1[b1 as usize];
        }
        p += 2;
    }
    if p < np {
        build_fused_row(&mut f0, tok.idx[2 * p], tok.idx[2 * p + 1], lut);
        let w0 = &w.pairs[p * n..(p + 1) * n];
        for (a, &b) in acc.iter_mut().zip(w0) {
            debug_assert_nibbles(b, nibble_mask);
            *a += f0[b as usize];
        }
    }
    add_tail(&mut acc, 0, tok, w, lut);
    for (j, a) in acc.iter_mut().enumerate() {
        *a *= tok.scale * w.col_scales[j];
    }
    acc
}

/// Accumulate (no scaling) the full column range of `w` for every token
/// into per-token output slices (each at least `w.n_cols` long), K-pair
/// tiles outermost. Per output column the accumulation order is identical
/// to [`execute_batch_tiled`]'s — k pairs ascending, then the odd tail —
/// for every `k_pair_block`, so callers that scale afterwards stay
/// bit-exact with the unsharded kernel. This is the building block the
/// tensor-parallel sharded backend (`gemm::sharded`) drives with each
/// shard's column slice of the packed weights.
pub fn accumulate_tiles(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    k_pair_block: usize,
    outs: &mut [&mut [f32]],
) {
    for t in toks {
        assert_eq!(t.idx.len(), w.n_rows, "reduction length mismatch");
    }
    assert_eq!(toks.len(), outs.len(), "token/output arity mismatch");
    accumulate_range(toks, w, lut, k_pair_block.max(1), 0, w.n_cols, outs);
}

/// Accumulate (no scaling) columns `[j0, j1)` of every token into
/// `outs[t][..j1-j0]`, iterating K-pair tiles outermost and tokens inside
/// so each packed weight tile is reused across the whole batch while hot.
fn accumulate_range(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    k_pair_block: usize,
    j0: usize,
    j1: usize,
    outs: &mut [&mut [f32]],
) {
    let n = w.n_cols;
    let np = w.n_pairs();
    let width = j1 - j0;
    let nibble_mask = (1usize << lut.n_w_bits) - 1;
    let mut fused = [0.0f32; 256];
    let mut pb = 0;
    while pb < np {
        let pe = (pb + k_pair_block).min(np);
        for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
            for p in pb..pe {
                build_fused_row(&mut fused, tok.idx[2 * p], tok.idx[2 * p + 1], lut);
                let wrow = &w.pairs[p * n + j0..p * n + j1];
                for (a, &b) in acc[..width].iter_mut().zip(wrow) {
                    debug_assert_nibbles(b, nibble_mask);
                    *a += fused[b as usize];
                }
            }
        }
        pb = pe;
    }
    if w.tail.is_some() {
        for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
            add_tail(&mut acc[..width], j0, tok, w, lut);
        }
    }
}

/// Debug-only guard for the crumb stream, mirroring
/// [`debug_assert_nibbles`]: a quad byte whose crumb exceeds the weight
/// codebook means corrupt index data and must not silently read an
/// unwritten fused-table slot.
#[inline]
fn debug_assert_crumbs(b: u8, mask: usize) {
    debug_assert!(
        (0..4).all(|r| ((b >> (6 - 2 * r)) & 0x03) as usize <= mask),
        "packed weight byte {b:#04x} out of range for crumb mask {mask:#x}"
    );
}

/// Build a fused crumb-pair row for activation indices `(ia0, ia1)`:
/// `fused[(iw0 << 2) | iw1] = lut[ia0][iw0] + lut[ia1][iw1]` — the crumb
/// analogue of [`build_fused_row`], 16 entries instead of 256. Because
/// each entry is exactly the per-pair sum the direct path computes before
/// accumulating, the crumb kernel stays bit-exact with
/// [`super::waq::execute_direct`]. Entries whose crumbs exceed the weight
/// codebook are never produced by `CrumbWeights` and are left untouched.
#[inline]
fn build_fused_crumb_pair(fused: &mut [f32; 16], ia0: u8, ia1: u8, lut: &CartesianLut) {
    let mask = (1usize << lut.n_w_bits) - 1;
    let r0 = &lut.table[(ia0 as usize) << lut.n_w_bits..][..mask + 1];
    let r1 = &lut.table[(ia1 as usize) << lut.n_w_bits..][..mask + 1];
    for (hi, &v0) in r0.iter().enumerate() {
        let dst = &mut fused[hi << 2..(hi << 2) + mask + 1];
        for (d, &v1) in dst.iter_mut().zip(r1) {
            *d = v0 + v1;
        }
    }
}

/// Accumulate the 1-3 unquaddable tail rows exactly like the direct path:
/// row pairs first (one fused-pair lookup per column, matching the direct
/// kernel's two-row unroll — tail rows start at `4 * n_quads`, an even
/// offset, so the pairing boundary lines up), then a plain LUT-row gather
/// for a final odd row.
fn add_crumb_tail(
    acc: &mut [f32],
    j0: usize,
    tok: &QuantToken,
    w: &CrumbWeights,
    lut: &CartesianLut,
) {
    let base_k = 4 * w.n_quads();
    let mask = (1usize << lut.n_w_bits) - 1;
    let mut fused = [0.0f32; 16];
    let mut t = 0;
    while t + 1 < w.tail.len() {
        build_fused_crumb_pair(&mut fused, tok.idx[base_k + t], tok.idx[base_k + t + 1], lut);
        let (r0, r1) = (&w.tail[t], &w.tail[t + 1]);
        for (jj, a) in acc.iter_mut().enumerate() {
            let (i0, i1) = (r0.get(j0 + jj) as usize, r1.get(j0 + jj) as usize);
            debug_assert!(i0 <= mask && i1 <= mask, "tail crumb {i0}/{i1} out of range");
            *a += fused[(i0 << 2) | i1];
        }
        t += 2;
    }
    if t < w.tail.len() {
        let base = (tok.idx[base_k + t] as usize) << lut.n_w_bits;
        let row = &lut.table[base..base + mask + 1];
        let tail = &w.tail[t];
        for (jj, a) in acc.iter_mut().enumerate() {
            let iw = tail.get(j0 + jj) as usize;
            debug_assert!(iw <= mask, "tail crumb index {iw} out of range (mask {mask})");
            *a += row[iw & mask];
        }
    }
}

/// Accumulate (no scaling) columns `[j0, j1)` of every token over
/// crumb-packed weights, K-quad tiles outermost and tokens inside so each
/// weight tile is reused across the batch while hot — the crumb twin of
/// [`accumulate_range`]. Each quad byte costs two fused-pair lookups for
/// FOUR MACs at half the nibble stream's weight traffic, and the
/// accumulation order per output column (k pairs ascending, then the
/// tail) is identical to the direct path's, so results are bit-exact with
/// `execute_direct` for every tile shape and thread count.
fn accumulate_range_crumbs(
    toks: &[QuantToken],
    w: &CrumbWeights,
    lut: &CartesianLut,
    k_quad_block: usize,
    j0: usize,
    j1: usize,
    outs: &mut [&mut [f32]],
) {
    let n = w.n_cols;
    let nq = w.n_quads();
    let width = j1 - j0;
    let crumb_mask = (1usize << lut.n_w_bits) - 1;
    let mut fhi = [0.0f32; 16];
    let mut flo = [0.0f32; 16];
    let mut qb = 0;
    while qb < nq {
        let qe = (qb + k_quad_block).min(nq);
        for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
            for q in qb..qe {
                build_fused_crumb_pair(&mut fhi, tok.idx[4 * q], tok.idx[4 * q + 1], lut);
                build_fused_crumb_pair(&mut flo, tok.idx[4 * q + 2], tok.idx[4 * q + 3], lut);
                let wrow = &w.quads[q * n + j0..q * n + j1];
                for (a, &b) in acc[..width].iter_mut().zip(wrow) {
                    debug_assert_crumbs(b, crumb_mask);
                    *a += fhi[(b >> 4) as usize];
                    *a += flo[(b & 0x0F) as usize];
                }
            }
        }
        qb = qe;
    }
    if !w.tail.is_empty() {
        for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
            add_crumb_tail(&mut acc[..width], j0, tok, w, lut);
        }
    }
}

/// Accumulate (no scaling) the full column range of crumb-packed `w` for
/// every token — the crumb twin of [`accumulate_tiles`], and the building
/// block the sharded backend drives with each shard's column slice.
/// `k_quad_block` plays `k_pair_block`'s role at quad granularity.
pub fn accumulate_tiles_crumbs(
    toks: &[QuantToken],
    w: &CrumbWeights,
    lut: &CartesianLut,
    k_quad_block: usize,
    outs: &mut [&mut [f32]],
) {
    for t in toks {
        assert_eq!(t.idx.len(), w.n_rows, "reduction length mismatch");
    }
    assert_eq!(toks.len(), outs.len(), "token/output arity mismatch");
    accumulate_range_crumbs(toks, w, lut, k_quad_block.max(1), 0, w.n_cols, outs);
}

/// Multi-token (M x K) @ (K x N) over crumb-packed weights: the 2-bit
/// counterpart of [`execute_batch_tiled`], same tiling/threading scheme
/// (`cfg.k_pair_block` reinterpreted as the K-quad tile depth), bit-exact
/// with per-token `execute_direct` for every tile shape and thread count.
pub fn execute_batch_tiled_crumbs(
    toks: &[QuantToken],
    w: &CrumbWeights,
    lut: &CartesianLut,
    cfg: &TileCfg,
) -> Vec<Vec<f32>> {
    for t in toks {
        assert_eq!(t.idx.len(), w.n_rows, "reduction length mismatch");
    }
    if toks.is_empty() {
        return Vec::new();
    }
    let n = w.n_cols;
    let k_quad_block = cfg.k_pair_block.max(1);
    let ranges = col_ranges(n, cfg);
    let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; n]).collect();

    if ranges.len() <= 1 {
        let mut views: Vec<&mut [f32]> = out.iter_mut().map(Vec::as_mut_slice).collect();
        accumulate_range_crumbs(toks, w, lut, k_quad_block, 0, n, &mut views);
    } else {
        std::thread::scope(|s| {
            let workers: Vec<_> = ranges
                .iter()
                .map(|&(j0, j1)| {
                    s.spawn(move || {
                        let mut local: Vec<Vec<f32>> =
                            toks.iter().map(|_| vec![0.0f32; j1 - j0]).collect();
                        let mut views: Vec<&mut [f32]> =
                            local.iter_mut().map(Vec::as_mut_slice).collect();
                        accumulate_range_crumbs(toks, w, lut, k_quad_block, j0, j1, &mut views);
                        drop(views);
                        (j0, local)
                    })
                })
                .collect();
            for worker in workers {
                let (j0, local) = worker.join().expect("waq gemm worker panicked");
                for (dst, src) in out.iter_mut().zip(local) {
                    dst[j0..j0 + src.len()].copy_from_slice(&src);
                }
            }
        });
    }

    for (tok, row) in toks.iter().zip(out.iter_mut()) {
        for (j, a) in row.iter_mut().enumerate() {
            *a *= tok.scale * w.col_scales[j];
        }
    }
    out
}

/// Split `[0, n)` into `parts` contiguous near-equal ranges (width
/// `ceil(n / parts)`, last range truncated, empty ranges dropped). The
/// ONE chunking definition shared by the tiled kernel's per-thread column
/// ranges and the sharded backend's load-time column split
/// (`gemm::sharded`), so the two paths can never split columns
/// differently.
pub(crate) fn even_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1);
    let width = n.div_ceil(parts);
    (0..parts)
        .map(|i| (i * width, ((i + 1) * width).min(n)))
        .filter(|&(j0, j1)| j0 < j1)
        .collect()
}

/// Split `[0, n)` into per-worker column ranges: at most `threads` ranges,
/// each at least `n_block` wide (so fused-row builds stay amortized).
fn col_ranges(n: usize, cfg: &TileCfg) -> Vec<(usize, usize)> {
    let hw = if cfg.threads > 0 {
        cfg.threads
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    };
    let min_width = cfg.n_block.max(1);
    let t = hw.clamp(1, (n / min_width).max(1));
    even_ranges(n, t)
}

/// Multi-token (M x K) @ (K x N) over packed weights: cache-tiled over N
/// and K with the weight tile reused across every token of the batch, and
/// column ranges fanned out over scoped worker threads. Bit-exact with
/// per-token `execute_direct` for every tile shape and thread count.
pub fn execute_batch_tiled(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    cfg: &TileCfg,
) -> Vec<Vec<f32>> {
    for t in toks {
        assert_eq!(t.idx.len(), w.n_rows, "reduction length mismatch");
    }
    if toks.is_empty() {
        return Vec::new();
    }
    let n = w.n_cols;
    let k_pair_block = cfg.k_pair_block.max(1);
    let ranges = col_ranges(n, cfg);
    let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; n]).collect();

    if ranges.len() <= 1 {
        let mut views: Vec<&mut [f32]> = out.iter_mut().map(Vec::as_mut_slice).collect();
        accumulate_range(toks, w, lut, k_pair_block, 0, n, &mut views);
    } else {
        std::thread::scope(|s| {
            let workers: Vec<_> = ranges
                .iter()
                .map(|&(j0, j1)| {
                    s.spawn(move || {
                        let mut local: Vec<Vec<f32>> =
                            toks.iter().map(|_| vec![0.0f32; j1 - j0]).collect();
                        let mut views: Vec<&mut [f32]> =
                            local.iter_mut().map(Vec::as_mut_slice).collect();
                        accumulate_range(toks, w, lut, k_pair_block, j0, j1, &mut views);
                        drop(views);
                        (j0, local)
                    })
                })
                .collect();
            for worker in workers {
                let (j0, local) = worker.join().expect("waq gemm worker panicked");
                for (dst, src) in out.iter_mut().zip(local) {
                    dst[j0..j0 + src.len()].copy_from_slice(&src);
                }
            }
        });
    }

    // per-token x per-channel scaling, after all accumulation — the same
    // grouping as the direct path
    for (tok, row) in toks.iter().zip(out.iter_mut()) {
        for (j, a) in row.iter_mut().enumerate() {
            *a *= tok.scale * w.col_scales[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::waq;
    use crate::quant::{self, OutlierCfg, QuantWeights};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn setup(
        seed: u64,
        k: usize,
        n: usize,
        a_bits: u32,
        w_bits: u32,
        batch: usize,
    ) -> (Vec<QuantToken>, QuantWeights, CartesianLut) {
        let mut rng = Rng::new(seed);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, w_bits);
        let calib: Vec<Vec<f32>> =
            (0..6).map(|_| rng.heavy_tailed_vec(k, 0.02, 10.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: 0.03 };
        let cb_a = quant::learn_act_codebook(&refs, None, a_bits, cfg);
        let toks: Vec<QuantToken> = (0..batch)
            .map(|_| quant::quantize_token(&rng.heavy_tailed_vec(k, 0.02, 10.0), &cb_a, cfg))
            .collect();
        let lut = CartesianLut::build(&cb_a, &qw.codebook);
        (toks, qw, lut)
    }

    #[test]
    fn packed_bit_exact_with_direct() {
        // even and odd K, including a K=1 tail-only edge
        for &(k, n) in &[(64usize, 24usize), (65, 24), (1, 8), (2, 8), (129, 17)] {
            let (toks, qw, lut) = setup(10 + k as u64, k, n, 4, 4, 1);
            let pw = qw.pack();
            let direct = waq::execute_direct(&toks[0], &qw, &lut);
            let packed = execute_packed(&toks[0], &pw, &lut);
            assert_eq!(packed, direct, "({k},{n}) not bit-exact");
        }
    }

    #[test]
    fn packed_bit_exact_mixed_bitwidths() {
        // 3-bit activations x 4-bit weights and 4x3
        for &(ab, wb) in &[(3u32, 4u32), (4, 3), (3, 3)] {
            let (toks, qw, lut) = setup(77 + ab as u64, 96, 20, ab, wb, 1);
            let pw = qw.pack();
            let direct = waq::execute_direct(&toks[0], &qw, &lut);
            let packed = execute_packed(&toks[0], &pw, &lut);
            assert_eq!(packed, direct, "A{ab}/W{wb} not bit-exact");
        }
    }

    #[test]
    fn tiled_bit_exact_across_tiles_and_threads() {
        let (toks, qw, lut) = setup(5, 97, 41, 4, 4, 5);
        let pw = qw.pack();
        let want: Vec<Vec<f32>> = toks.iter().map(|t| waq::execute_direct(t, &qw, &lut)).collect();
        for threads in [1usize, 2, 3, 8] {
            for (nb, kb) in [(8usize, 3usize), (16, 1), (512, 128), (5, 1000)] {
                let cfg = TileCfg { n_block: nb, k_pair_block: kb, threads };
                let got = execute_batch_tiled(&toks, &pw, &lut, &cfg);
                assert_eq!(got, want, "threads={threads} nb={nb} kb={kb}");
            }
        }
    }

    #[test]
    fn tiled_handles_empty_and_single() {
        let (toks, qw, lut) = setup(6, 32, 8, 4, 4, 1);
        let pw = qw.pack();
        let none: Vec<QuantToken> = Vec::new();
        assert!(execute_batch_tiled(&none, &pw, &lut, &TileCfg::default()).is_empty());
        let got = execute_batch_tiled(&toks, &pw, &lut, &TileCfg::default());
        assert_eq!(got[0], execute_packed(&toks[0], &pw, &lut));
    }

    #[test]
    fn accumulate_tiles_is_the_unscaled_kernel() {
        // the slice-level entry point the sharded backend drives: after
        // applying the same per-token/per-column scaling, it equals the
        // full batched kernel bit-for-bit (odd K exercises the tail row)
        let (toks, qw, lut) = setup(8, 33, 12, 4, 4, 3);
        let pw = qw.pack();
        let mut rows: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; 12]).collect();
        let mut views: Vec<&mut [f32]> = rows.iter_mut().map(Vec::as_mut_slice).collect();
        accumulate_tiles(&toks, &pw, &lut, 4, &mut views);
        drop(views);
        for (tok, row) in toks.iter().zip(rows.iter_mut()) {
            for (a, &s) in row.iter_mut().zip(&pw.col_scales) {
                *a *= tok.scale * s;
            }
        }
        let want = execute_batch_tiled(&toks, &pw, &lut, &TileCfg::single_thread());
        assert_eq!(rows, want);
    }

    #[test]
    fn crumb_kernel_bit_exact_with_direct() {
        // K % 4 in {0,1,2,3} exercises every tail shape, K=2/3 the
        // quad-free edge; outliers don't matter here (compensation is a
        // separate pass) but odd N checks column handling
        for &(k, n) in &[(64usize, 24usize), (65, 24), (66, 17), (67, 9), (2, 8), (3, 8)] {
            let (toks, qw, lut) = setup(40 + k as u64, k, n, 4, 2, 3);
            let cw = qw.pack_crumbs();
            let want: Vec<Vec<f32>> =
                toks.iter().map(|t| waq::execute_direct(t, &qw, &lut)).collect();
            for threads in [1usize, 3] {
                for (nb, kb) in [(8usize, 3usize), (512, 128), (5, 1000)] {
                    let cfg = TileCfg { n_block: nb, k_pair_block: kb, threads };
                    let got = execute_batch_tiled_crumbs(&toks, &cw, &lut, &cfg);
                    assert_eq!(got, want, "({k},{n}) threads={threads} nb={nb} kb={kb}");
                }
            }
        }
    }

    #[test]
    fn crumb_kernel_mixed_activation_bits() {
        // 3-bit activations x 2-bit weights (the draft model pairs a 2-bit
        // weight codebook with whatever activation width the mode sets)
        for ab in [3u32, 4] {
            let (toks, qw, lut) = setup(90 + ab as u64, 48, 12, ab, 2, 2);
            let cw = qw.pack_crumbs();
            let want: Vec<Vec<f32>> =
                toks.iter().map(|t| waq::execute_direct(t, &qw, &lut)).collect();
            let got = execute_batch_tiled_crumbs(&toks, &cw, &lut, &TileCfg::default());
            assert_eq!(got, want, "A{ab}/W2 not bit-exact");
        }
    }

    #[test]
    fn accumulate_tiles_crumbs_is_the_unscaled_kernel() {
        let (toks, qw, lut) = setup(91, 33, 12, 4, 2, 3);
        let cw = qw.pack_crumbs();
        let mut rows: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; 12]).collect();
        let mut views: Vec<&mut [f32]> = rows.iter_mut().map(Vec::as_mut_slice).collect();
        accumulate_tiles_crumbs(&toks, &cw, &lut, 4, &mut views);
        drop(views);
        for (tok, row) in toks.iter().zip(rows.iter_mut()) {
            for (a, &s) in row.iter_mut().zip(&cw.col_scales) {
                *a *= tok.scale * s;
            }
        }
        let want = execute_batch_tiled_crumbs(&toks, &cw, &lut, &TileCfg::single_thread());
        assert_eq!(rows, want);
        // empty batch is a no-op, like the nibble kernel
        let none: Vec<QuantToken> = Vec::new();
        assert!(execute_batch_tiled_crumbs(&none, &cw, &lut, &TileCfg::default()).is_empty());
    }

    #[test]
    fn fused_crumb_pair_matches_two_lookups() {
        let mut rng = Rng::new(92);
        let cb_a = quant::Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = quant::Codebook::new(rng.normal_vec(4, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        let mut fused = [0.0f32; 16];
        build_fused_crumb_pair(&mut fused, 5, 11, &lut);
        for iw0 in 0..4u8 {
            for iw1 in 0..4u8 {
                let b = ((iw0 as usize) << 2) | iw1 as usize;
                assert_eq!(fused[b], lut.lookup(5, iw0) + lut.lookup(11, iw1));
            }
        }
    }

    #[test]
    fn fused_row_matches_two_lookups() {
        let mut rng = Rng::new(9);
        let cb_a = quant::Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = quant::Codebook::new(rng.normal_vec(16, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        let mut fused = [0.0f32; 256];
        build_fused_row(&mut fused, 5, 11, &lut);
        for iw0 in 0..16u8 {
            for iw1 in 0..16u8 {
                let b = ((iw0 as usize) << 4) | iw1 as usize;
                assert_eq!(fused[b], lut.lookup(5, iw0) + lut.lookup(11, iw1));
            }
        }
    }
}
